"""Typed serving configuration: the engine surface as frozen dataclasses.

Eight PRs of feature growth left ``lm.init_decode_state``,
``ServingEngine.__init__`` and the CLI drivers with overlapping kwarg
piles (``layout``, ``page_size``, ``n_pages``, ``snapshots``,
``host_spill``, ``prefill_chunk``, ``prefix_sharing``, …) copied at
every construction site.  This module replaces the pile with two typed,
frozen objects — split along the line the engine already drew:

  * :class:`CacheConfig` — *state shape*: everything
    ``init_decode_state`` needs to allocate the decode caches (KV
    layout, page pool size, snapshot store, host spill tier).  Models
    consume it duck-typed (``lm``/``encdec`` take ``cache=`` without
    importing this module, so ``repro.models`` keeps zero dependency on
    ``repro.serving``).
  * :class:`EngineConfig` — *loop behavior*: scheduling
    (``steps_per_sync``, ``prefill_chunk``, ``prefill_budget``,
    ``prefix_sharing``), sampling (``seed``/``temperature``/``top_k``)
    and speculative decoding (``spec:`` :class:`SpecConfig`).

Validation lives with the data: each config raises on construction with
the *same messages* the kwarg-era code raised at first use, so tests
asserting on error text pass unchanged; combos spanning both objects
(``prefix_sharing`` needs the paged layout; spec decoding needs a
chunked verifier) are checked by :func:`validate_configs`, which the
engine calls once at construction.

Legacy kwargs keep working through one adapter — :func:`from_kwargs`
emits a ``DeprecationWarning`` (once per call site under the default
filters) and returns the equivalent ``(CacheConfig, EngineConfig)``
pair.  CLI drivers share :func:`configs_from_flags` so flag→config
translation exists exactly once instead of per driver.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative decoding: draft K tokens, verify through the chunked
    prefill path, advance each row by its accepted length.

    ``drafter`` picks the proposal source (``repro.serving.drafter``):

    * ``"prompt_lookup"`` — n-gram prompt lookup: the last ``ngram``
      generated/prompt tokens are matched against the row's own earlier
      tokens and the continuation after the most recent match is
      proposed.  Stateless, works for every family, free.
    * ``"hybrid_ssm"`` — the hybrid family's own Mamba layers (shared
      weights, private recurrent drafter state) run as a K-step draft
      model; attention layers are skipped, which is what makes drafting
      cheap.  Hybrid family only.

    Acceptance is greedy-only for now (token-identical to plain decode
    by construction — every emitted token is the verifier's own argmax);
    spec-sampling and tree drafts are ROADMAP follow-ons.
    """

    k: int = 4                       # drafted tokens per verify step
    drafter: str = "prompt_lookup"   # "prompt_lookup" | "hybrid_ssm"
    ngram: int = 2                   # prompt-lookup match length

    def __post_init__(self) -> None:
        if self.k < 1:
            raise ValueError("spec.k must be >= 1")
        if self.drafter not in ("prompt_lookup", "hybrid_ssm"):
            raise ValueError(
                f"unknown drafter {self.drafter!r} "
                "(expected 'prompt_lookup' or 'hybrid_ssm')"
            )
        if self.ngram < 1:
            raise ValueError("spec.ngram must be >= 1")


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """Decode-cache shape: what ``init_decode_state`` allocates.

    ``layout`` picks the KV representation (``"contiguous"`` slab or
    ``"paged"`` pool + block tables — ``repro.serving.pager`` has the
    contract); ``page_size``/``n_pages`` size the pool; ``snapshots``
    adds the page-boundary recurrent-state store (recurrent families);
    ``host_spill`` adds the host tier behind preemption (``None`` lets
    the engine default it to "paged layout only"); ``kv_dtype`` picks
    the pool storage precision (``"f32"`` = the model's compute dtype;
    ``"bf16"`` = half-width storage through the same kernels, which
    upcast K/V tiles to f32 anyway; ``"int8"`` = per-(page, head)-scaled
    int8 payload with f32 scale pools, dequant inside the attention
    kernels).  Sub-f32 storage is a paged-pool feature — the ladder is
    exact: bf16 packs resident KV to 1/2 the f32 bytes, int8 to 1/4
    (half the bf16 cell), plus a per-(page, head) scale pool the byte
    accounting deliberately excludes (<1% at real geometries).
    """

    layout: str = "contiguous"
    page_size: int = 16
    n_pages: Optional[int] = None
    snapshots: bool = False
    host_spill: Optional[bool] = None
    kv_dtype: str = "f32"

    def __post_init__(self) -> None:
        if self.layout not in ("contiguous", "paged"):
            raise ValueError(f"unknown KV-cache layout {self.layout!r}")
        if self.page_size < 1:
            raise ValueError("page_size must be >= 1")
        if self.n_pages is not None and self.n_pages < 1:
            raise ValueError("n_pages must be >= 1 (None = worst case)")
        if self.snapshots and self.layout != "paged":
            raise ValueError(
                "recurrent-state snapshots use page-boundary granularity — "
                "layout='paged' required"
            )
        if self.kv_dtype not in ("f32", "bf16", "int8"):
            raise ValueError(
                f"unknown kv_dtype {self.kv_dtype!r} "
                "(expected 'f32', 'bf16', or 'int8')"
            )
        if self.kv_dtype != "f32" and self.layout != "paged":
            raise ValueError(
                "sub-f32 KV storage is a paged-pool feature (quantized "
                "scales are per page) — layout='paged' required for "
                f"kv_dtype={self.kv_dtype!r}"
            )


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Serving-loop behavior: scheduling, sampling, speculation.

    Field semantics match the engine docstring they replaced:
    ``steps_per_sync`` fused decode steps per harvest sync;
    ``prefill_chunk=C`` chunked prompt ingestion (1 = token-by-token);
    ``prefix_sharing`` page-level prompt sharing (paged layout only —
    cross-checked in :func:`validate_configs`); ``prefill_budget``
    bounds chunk steps per cycle (0 = unbounded); ``seed`` /
    ``temperature`` / ``top_k`` drive per-request sampling (0.0 =
    greedy); ``spec`` enables draft-and-verify decoding.
    """

    steps_per_sync: int = 8
    prefill_chunk: int = 1
    prefix_sharing: bool = False
    prefill_budget: int = 0
    seed: int = 0
    temperature: float = 0.0
    top_k: int = 0
    spec: Optional[SpecConfig] = None

    def __post_init__(self) -> None:
        if self.steps_per_sync < 1:
            raise ValueError("steps_per_sync must be >= 1")
        if self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if self.prefill_budget < 0:
            raise ValueError("prefill_budget must be >= 0 (0 = unbounded)")
        if self.top_k < 0:
            raise ValueError("top_k must be >= 0 (0 = full vocab)")


def validate_configs(cache: CacheConfig, config: EngineConfig) -> None:
    """Cross-object invariants (each config validates itself on
    construction; combos spanning both are checked here, with the same
    messages the kwarg-era engine raised)."""
    if config.prefix_sharing and cache.layout != "paged":
        raise ValueError(
            "prefix sharing needs layout='paged' — pages are the "
            "sharing unit (the contiguous slab has per-row storage)"
        )
    spec = config.spec
    if spec is None:
        return
    if config.prefill_chunk < 2:
        raise ValueError(
            "speculative decoding verifies drafts through the chunked "
            "prefill path — prefill_chunk must be >= 2"
        )
    if config.temperature > 0.0:
        raise ValueError(
            "speculative decoding is greedy-only for now — temperature "
            "must be 0 (spec-sampling is a ROADMAP follow-on)"
        )
    if spec.drafter == "hybrid_ssm" and config.prefix_sharing:
        raise ValueError(
            "drafter='hybrid_ssm' is incompatible with prefix_sharing — "
            "snapshot restore rebuilds the model's recurrence, not the "
            "drafter's private state"
        )


#: keys from the legacy kwarg pile, split by destination object
_CACHE_KEYS = frozenset(
    f.name for f in dataclasses.fields(CacheConfig)
)
_ENGINE_KEYS = frozenset(
    f.name for f in dataclasses.fields(EngineConfig)
)


def from_kwargs(_stacklevel: int = 2, **kwargs):
    """Adapter from the legacy kwarg pile to ``(CacheConfig,
    EngineConfig)``.

    Emits ``DeprecationWarning`` (once per call site under Python's
    default warning filters) pointing at the caller; unknown keys raise
    ``TypeError`` exactly like a bad keyword argument used to.
    ``_stacklevel`` lets the engine's ``**legacy`` path attribute the
    warning to the user's construction site instead of its own frame.
    """
    unknown = set(kwargs) - _CACHE_KEYS - _ENGINE_KEYS
    if unknown:
        raise TypeError(
            f"unknown engine kwargs {sorted(unknown)} — see "
            "repro.serving.config.CacheConfig / EngineConfig"
        )
    if not kwargs:        # nothing legacy about an all-defaults call
        return CacheConfig(), EngineConfig()
    warnings.warn(
        "raw layout/engine kwargs are deprecated — pass "
        "cache=CacheConfig(...) and config=EngineConfig(...) "
        "(repro.serving.config; from_kwargs adapts legacy call sites)",
        DeprecationWarning, stacklevel=_stacklevel,
    )
    cache = CacheConfig(
        **{k: v for k, v in kwargs.items() if k in _CACHE_KEYS}
    )
    config = EngineConfig(
        **{k: v for k, v in kwargs.items() if k in _ENGINE_KEYS}
    )
    return cache, config


def configs_from_flags(args):
    """Build ``(CacheConfig, EngineConfig)`` from an argparse namespace.

    The one flag→config translation shared by ``launch/serve.py``,
    ``examples/serve_batched.py`` and ``benchmarks/serve_engine.py``
    (previously three hand-rolled copies).  Missing attributes fall back
    to the config defaults, so drivers only declare the flags they
    expose; ``--spec-k 0`` (or absent) means no speculation.
    """
    spec = None
    k = int(getattr(args, "spec_k", 0) or 0)
    if k > 0:
        spec = SpecConfig(
            k=k,
            drafter=getattr(args, "spec_drafter", "prompt_lookup"),
            ngram=int(getattr(args, "spec_ngram", 2)),
        )
    cache = CacheConfig(
        layout=getattr(args, "layout", "contiguous"),
        page_size=int(getattr(args, "page_size", 16)),
        n_pages=getattr(args, "n_pages", None),
        snapshots=bool(getattr(args, "snapshots", False)),
        host_spill=getattr(args, "host_spill", None),
        kv_dtype=getattr(args, "kv_dtype", "f32"),
    )
    config = EngineConfig(
        steps_per_sync=int(getattr(args, "steps_per_sync", 8)),
        prefill_chunk=int(getattr(args, "prefill_chunk", 1)),
        prefix_sharing=bool(getattr(args, "prefix_sharing", False)),
        prefill_budget=int(getattr(args, "prefill_budget", 0)),
        seed=int(getattr(args, "seed", 0)),
        temperature=float(getattr(args, "temperature", 0.0)),
        top_k=int(getattr(args, "top_k", 0)),
        spec=spec,
    )
    validate_configs(cache, config)
    return cache, config
