"""Serving-side fault injection — the serving analogue of
``distributed.fault_tolerance.FaultInjector``.

A ``FaultPlan`` is a deterministic script of adverse events keyed by the
engine's *harvest-cycle* index (one ``ServingEngine.step()`` call = one
cycle — the only host-visible clock the engine has, so every injection
lands at a sanctioned host/device sync point and never adds a device
sync of its own).  The engine applies the cycle's events at the top of
``step()``, before admission, so an event's consequences (a preemption
under a shrunken pool, a drain at the following harvest) flow through
the *normal* scheduler paths — the harness proves the production code
survives, it does not grow a parallel code path.

Event kinds:

  ``exhaust_pool``  hold ``pages`` device pages hostage (the engine's
                    reservation ledger sees a pool smaller by that much
                    — a deterministic stand-in for a burst of long
                    requests).  Admission stalls or preempts exactly as
                    it would under real pressure.
  ``release_pool``  release the hostage pages (ends the pressure
                    window).
  ``cancel``        ``engine.cancel(req_id)`` at the chosen cycle —
                    cancel-at-step-k without racing the engine loop.
  ``deadline``      force request ``req_id``'s absolute deadline to
                    ``deadline_ms`` after the event fires (0 = expire at
                    the very next harvest) — a deadline storm is several
                    of these on one cycle.
  ``poison``        mark the row serving ``req_id`` poisoned: its output
                    is declared garbage and the row is drained at the
                    next harvest through the release path, surrendering
                    pages/slots like any cancel (models a corrupted row
                    that must be evicted without wedging the batch).

Determinism contract: the same plan against the same engine config and
submission sequence injects at the same cycles, so failure scenarios are
replayable in CI — assertions about survivor token-identity and page
conservation are exact, not statistical.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

_KINDS = ("exhaust_pool", "release_pool", "cancel", "deadline", "poison")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted event at harvest cycle ``cycle`` (0-based)."""

    cycle: int
    kind: str                       # one of _KINDS
    req_id: Optional[int] = None    # cancel / deadline / poison target
    pages: int = 0                  # exhaust_pool: pages to hold hostage
    deadline_ms: float = 0.0        # deadline: expiry this long after firing

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(
                f"FaultEvent: unknown kind {self.kind!r} (one of {_KINDS})"
            )
        if self.kind in ("cancel", "deadline", "poison") and self.req_id is None:
            raise ValueError(f"FaultEvent({self.kind}): req_id required")
        if self.kind == "exhaust_pool" and self.pages <= 0:
            raise ValueError("FaultEvent(exhaust_pool): pages must be > 0")
        if self.cycle < 0:
            raise ValueError("FaultEvent: cycle must be >= 0")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An ordered script of :class:`FaultEvent`s for one engine run."""

    events: Sequence[FaultEvent] = ()

    def at(self, cycle: int) -> List[FaultEvent]:
        """Events firing at the given harvest cycle, in plan order."""
        return [e for e in self.events if e.cycle == cycle]

    @property
    def last_cycle(self) -> int:
        return max((e.cycle for e in self.events), default=-1)

    def describe(self) -> str:
        return "; ".join(
            f"@{e.cycle} {e.kind}"
            + (f" req={e.req_id}" if e.req_id is not None else "")
            + (f" pages={e.pages}" if e.kind == "exhaust_pool" else "")
            for e in sorted(self.events, key=lambda e: (e.cycle, e.kind))
        ) or "(empty plan)"
