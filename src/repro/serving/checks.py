"""Serving regression checks — the paper's layer-by-layer discipline
applied to the decode path.

``teacher_forced_logits`` / ``decode_logits`` give the two sides of the
parity check previously buried behind ``serve.py --check``: incremental
decode through the cache must reproduce the teacher-forced forward at the
last prompt position.  ``tests/test_serving.py`` runs it as a real test
under both backends; the CLI keeps a ``--check`` flag wired to the same
helper.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.models import lm as LM
from repro.models.model import Model


def teacher_forced_logits(model: Model, params, prompt: jnp.ndarray):
    """Last-position logits from the full (non-cached) forward."""
    h = LM.forward(model.cfg, params, prompt, remat=False)
    return LM.lm_logits(model.cfg, params, h[:, -1:, :])[:, 0]


def decode_logits(model: Model, params, prompt: jnp.ndarray, max_len: int):
    """Last-position logits from incremental decode through the cache."""
    state = model.init_decode_state(prompt.shape[0], max_len)
    got = None
    for i in range(prompt.shape[1]):
        got, state = model.decode_step(params, state, prompt[:, i])
    return got


def assert_decode_matches_teacher_forced(
    model: Model, params, prompt, max_len: int,
    rtol: float = 2e-2, atol: float = 2e-2,
) -> None:
    want = teacher_forced_logits(model, params, prompt)
    got = decode_logits(model, params, prompt, max_len)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=rtol, atol=atol,
    )
