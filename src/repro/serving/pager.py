"""Device-side page allocator for the paged KV-cache layout.

The paper's central lesson is that performance portability comes from
hiding a data structure's *layout* behind one high-level abstraction so the
same calling code targets every backend.  The KV cache has two layouts
behind ``ops.attention_decode`` (the ``KVCacheLayout`` contract):

  contiguous   ``(layers, B, max_len, Hkv, hd)`` slab — token ``p`` of row
               ``b`` lives at ``cache[:, b, p]`` (ring-indexed by
               ``p % window`` for sliding-window archs).  Memory is
               ``B x max_len`` regardless of actual prompt lengths.
  paged        a pool of fixed-size pages plus a per-row block table.
               Memory scales with *live tokens*, not ``B x max_len``.

Block-table layout contract (shared by the jnp reference path and
``flash_decode_paged_pallas`` — keep them in lock-step):

  * page pool      ``(layers, n_pages, page_size, Hkv, hd)`` — one slab per
    layer (or per shared-attention group for the hybrid family), all slabs
    indexed by the same page ids.
  * block table    ``(B, max_blocks)`` int32.  Token at absolute position
    ``p`` of row ``b`` lives in page ``block_table[b, p // page_size]`` at
    slot ``p % page_size``.  ``-1`` marks an unmapped block; readers must
    treat unmapped blocks as fully masked and writers must drop the write.
  * positions are *absolute* (no ring): sliding-window archs mask old
    tokens in attention instead of recycling slots, so a live windowed row
    does not release pages mid-request (documented trade-off — the win is
    cross-request reuse, which dominates at mixed prompt lengths).
  * freed pages are recycled **without zeroing**: a new owner writes
    positions ``0..pos`` sequentially before any read at ``kpos < pos+1``
    can see them, so stale data is never observable.

Allocator state is two device arrays (the free list as a stack), so
allocation and release are pure ``jnp`` and run *inside* jitted steps with
fixed shapes — the same masked-write idiom as the serving engine's slot
refill; nothing retraces:

  * ``free``  ``(n_pages,)`` int32 — entries ``[0, top)`` are free page
    ids; entries above ``top`` are stale (owned by block tables).
  * ``top``   ``()`` int32 — number of free pages.

``alloc_on_write`` maps the block a row is about to write (pop from the
stack top; rows ranked by batch index within one step), ``release_rows``
pushes a completed row's pages back.  Conservation invariant (the
hypothesis property in ``tests/test_pager.py``): the free-list prefix and
the mapped block-table entries always partition ``0..n_pages-1`` with no
page owned twice.

Multi-page-per-step allocation (chunked prefill): a step that writes a
*range* of positions ``start..end`` may straddle several blocks, so
``alloc_range`` maps every block covering the range in one jitted call —
a statically unrolled ladder of single-block ``alloc_on_write`` passes
(``(max_chunk-1)//page_size + 2`` of them), each with the same
rank-by-batch-index pop order, so the conservation invariant and the
fixed-shape/no-retrace discipline are unchanged.  ``write_page_chunk`` is
the matching multi-token scatter: token ``i`` of row ``b`` lands at
``(block_table[b, (start+i)//page_size], (start+i) % page_size)``; chunk
padding (``i >= width``) and inactive rows route to the out-of-bounds
sentinel page and drop.  Admission-time reservation already covers the
worst case (``pages_needed`` counts positions ``0..total_len-2``), so a
chunked step can never find the free list empty for a live request.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class PagerState(NamedTuple):
    """Free-list stack as device arrays (a pytree; jit/donation friendly)."""

    free: jax.Array  # (n_pages,) int32: free[:top] are free page ids
    top: jax.Array   # ()        int32: number of free pages


def init_pager(n_pages: int) -> PagerState:
    return PagerState(
        free=jnp.arange(n_pages, dtype=jnp.int32),
        top=jnp.asarray(n_pages, jnp.int32),
    )


def init_block_table(batch: int, max_blocks: int) -> jax.Array:
    return jnp.full((batch, max_blocks), -1, jnp.int32)


def pages_needed(total_len: int, page_size: int) -> int:
    """Pages a request reserves at admission (host-side accounting).

    A request of ``total_len`` tokens writes cache positions
    ``0..total_len-2`` (the feed at the last position only *predicts*, its
    token is never cached), touching ``ceil((total_len-1)/page_size)``
    blocks.  Admission reserves this worst case so alloc-on-write can never
    find the free list empty mid-request.
    """
    return max(1, -(-(total_len - 1) // page_size))


def alloc_on_write(
    pager: PagerState,
    block_table: jax.Array,          # (B, max_blocks) int32
    idx: jax.Array,                  # () or (B,) int32: position being written
    active: Optional[jax.Array] = None,   # (B,) bool; None = all rows
    *,
    page_size: int,
) -> Tuple[PagerState, jax.Array]:
    """Map the block covering ``idx`` for every row that needs one.

    Pure ``jnp``, fixed shapes: rows needing a page are ranked by batch
    index and pop ``free[top-1-rank]``.  A row whose block is already
    mapped, out of range, or inactive is untouched; if the free list runs
    dry the remaining rows simply stay unmapped (writes to unmapped blocks
    drop — admission-time reservation prevents this for live requests).
    """
    b, max_blocks = block_table.shape
    idx_b = jnp.broadcast_to(jnp.asarray(idx, jnp.int32).reshape(-1), (b,))
    if active is None:
        active = jnp.ones((b,), bool)
    blk = idx_b // page_size
    in_range = blk < max_blocks
    blk_c = jnp.clip(blk, 0, max_blocks - 1)
    cur = jnp.take_along_axis(block_table, blk_c[:, None], axis=1)[:, 0]
    need = active & in_range & (cur < 0)
    rank = jnp.cumsum(need) - 1                     # rank among needy rows
    grant = need & (rank < pager.top)
    n_pages = pager.free.shape[0]
    src = jnp.clip(pager.top - 1 - rank, 0, n_pages - 1)
    page = jnp.where(grant, pager.free[src], cur)
    col = jax.lax.broadcasted_iota(jnp.int32, block_table.shape, 1)
    block_table = jnp.where(
        grant[:, None] & (col == blk_c[:, None]), page[:, None], block_table
    )
    top = pager.top - jnp.sum(grant, dtype=jnp.int32)
    return PagerState(pager.free, top), block_table


def alloc_range(
    pager: PagerState,
    block_table: jax.Array,          # (B, max_blocks) int32
    start: jax.Array,                # () or (B,) int32: first position written
    end: jax.Array,                  # () or (B,) int32: last position written
    active: Optional[jax.Array] = None,   # (B,) bool; None = all rows
    *,
    page_size: int,
    max_chunk: int,
) -> Tuple[PagerState, jax.Array]:
    """Map every block covering positions ``start..end`` (inclusive).

    The multi-page-per-step generalization of ``alloc_on_write`` for
    chunked prefill: ``max_chunk`` statically bounds ``end - start + 1``,
    so the loop unrolls to a fixed ladder of single-block allocations
    (fixed shapes, nothing retraces).  Each rung targets block
    ``start//page_size + k`` and is masked out for rows whose range ends
    earlier, so rows needing fewer blocks allocate fewer pages.
    """
    b = block_table.shape[0]
    start_b = jnp.broadcast_to(jnp.asarray(start, jnp.int32).reshape(-1), (b,))
    end_b = jnp.broadcast_to(jnp.asarray(end, jnp.int32).reshape(-1), (b,))
    if active is None:
        active = jnp.ones((b,), bool)
    for k in range((max_chunk - 1) // page_size + 2):
        idx = start_b + k * page_size        # one position inside block k
        pager, block_table = alloc_on_write(
            pager, block_table, jnp.minimum(idx, end_b),
            active & (idx <= end_b), page_size=page_size,
        )
    return pager, block_table


def release_rows(
    pager: PagerState,
    block_table: jax.Array,   # (B, max_blocks) int32
    mask: jax.Array,          # (B,) bool: rows whose pages return to the pool
) -> Tuple[PagerState, jax.Array]:
    """Push every mapped page of the masked rows back onto the free stack
    and unmap their block-table rows.  Releasing an already-empty row is a
    no-op, so release-on-completion and release-at-admission compose."""
    n_pages = pager.free.shape[0]
    give = mask[:, None] & (block_table >= 0)
    pages = jnp.where(give, block_table, -1).reshape(-1)
    is_page = pages >= 0
    rank = jnp.cumsum(is_page) - 1
    dst = jnp.where(is_page, pager.top + rank, n_pages)   # sentinel: dropped
    free = pager.free.at[dst].set(pages, mode="drop")
    top = pager.top + jnp.sum(is_page, dtype=jnp.int32)
    block_table = jnp.where(mask[:, None], -1, block_table)
    return PagerState(free, top), block_table


def write_page(
    pool: jax.Array,                 # (n_pages, page_size, Hkv, hd)
    new: jax.Array,                  # (B, Hkv, hd): one token per row
    block_table: jax.Array,          # (B, max_blocks) int32
    idx: jax.Array,                  # () or (B,) int32: absolute position
    active: Optional[jax.Array] = None,
) -> jax.Array:
    """Write one token's K or V through the block table.

    One fused scatter: each row lands at (page, slot) =
    (``bt[b, idx//P]``, ``idx % P``); rows that are inactive, out of range,
    or unmapped are routed to an out-of-bounds sentinel page and dropped.
    """
    n_pages, page_size = pool.shape[0], pool.shape[1]
    b, max_blocks = block_table.shape
    idx_b = jnp.broadcast_to(jnp.asarray(idx, jnp.int32).reshape(-1), (b,))
    blk = idx_b // page_size
    blk_c = jnp.clip(blk, 0, max_blocks - 1)
    page = jnp.take_along_axis(block_table, blk_c[:, None], axis=1)[:, 0]
    ok = (blk < max_blocks) & (page >= 0)
    if active is not None:
        ok &= active
    page = jnp.where(ok, page, n_pages)
    return pool.at[page, idx_b % page_size].set(
        new.astype(pool.dtype), mode="drop"
    )


def write_page_chunk(
    pool: jax.Array,                 # (n_pages, page_size, Hkv, hd)
    new: jax.Array,                  # (B, C, Hkv, hd): C tokens per row
    block_table: jax.Array,          # (B, max_blocks) int32
    start: jax.Array,                # () or (B,) int32: pos of chunk token 0
    width: jax.Array,                # () or (B,) int32: real tokens (1..C)
    active: Optional[jax.Array] = None,
) -> jax.Array:
    """Write a chunk of C tokens' K or V through the block table.

    One fused scatter: token ``i`` of row ``b`` lands at (page, slot) =
    (``bt[b, (start+i)//P]``, ``(start+i) % P``); chunk padding
    (``i >= width``), inactive rows, out-of-range and unmapped blocks are
    routed to the out-of-bounds sentinel page and dropped.  Positions are
    distinct within a row and pages are owned by a single row, so the
    scatter never writes one slot twice.
    """
    n_pages, page_size = pool.shape[0], pool.shape[1]
    b, max_blocks = block_table.shape
    c = new.shape[1]
    start_b = jnp.broadcast_to(jnp.asarray(start, jnp.int32).reshape(-1), (b,))
    w_b = jnp.broadcast_to(jnp.asarray(width, jnp.int32).reshape(-1), (b,))
    i = jnp.arange(c, dtype=jnp.int32)[None, :]
    posmat = start_b[:, None] + i                          # (B, C)
    blk = posmat // page_size
    blk_c = jnp.clip(blk, 0, max_blocks - 1)
    page = jnp.take_along_axis(block_table, blk_c, axis=1)  # (B, C)
    ok = (i < w_b[:, None]) & (blk < max_blocks) & (page >= 0)
    if active is not None:
        ok &= active[:, None]
    page = jnp.where(ok, page, n_pages)
    return pool.at[page, posmat % page_size].set(
        new.astype(pool.dtype), mode="drop"
    )
