"""Device-side page allocator for the paged KV-cache layout.

The paper's central lesson is that performance portability comes from
hiding a data structure's *layout* behind one high-level abstraction so the
same calling code targets every backend.  The KV cache has two layouts
behind ``ops.attention_decode`` (the ``KVCacheLayout`` contract):

  contiguous   ``(layers, B, max_len, Hkv, hd)`` slab — token ``p`` of row
               ``b`` lives at ``cache[:, b, p]`` (ring-indexed by
               ``p % window`` for sliding-window archs).  Memory is
               ``B x max_len`` regardless of actual prompt lengths.
  paged        a pool of fixed-size pages plus a per-row block table.
               Memory scales with *live tokens*, not ``B x max_len``.

Block-table layout contract (shared by the jnp reference path and
``flash_decode_paged_pallas`` — keep them in lock-step):

  * page pool      ``(layers, n_pages, page_size, Hkv, hd)`` — one slab per
    layer (or per shared-attention group for the hybrid family), all slabs
    indexed by the same page ids.
  * block table    ``(B, max_blocks)`` int32.  Token at absolute position
    ``p`` of row ``b`` lives in page ``block_table[b, p // page_size]`` at
    slot ``p % page_size``.  ``-1`` marks an unmapped block; readers must
    treat unmapped blocks as fully masked and writers must drop the write.
  * positions are *absolute* (no ring): sliding-window archs mask old
    tokens in attention instead of recycling slots, so a live windowed row
    does not release pages mid-request (documented trade-off — the win is
    cross-request reuse, which dominates at mixed prompt lengths).
  * freed pages are recycled **without zeroing**: a new owner writes
    positions ``0..pos`` sequentially before any read at ``kpos < pos+1``
    can see them, so stale data is never observable.

Allocator state is three device arrays (the free list as a stack plus a
per-page refcount), so allocation, sharing and release are pure ``jnp``
and run *inside* jitted steps with fixed shapes — the same masked-write
idiom as the serving engine's slot refill; nothing retraces:

  * ``free``  ``(n_pages,)`` int32 — entries ``[0, top)`` are free page
    ids; entries above ``top`` are stale (owned by block tables).
  * ``top``   ``()`` int32 — number of free pages.
  * ``rc``    ``(n_pages,)`` int32 — per-page refcount: how many
    block-table entries reference the page.  0 for free pages.

``alloc_on_write`` maps the block a row is about to write (pop from the
stack top; rows ranked by batch index within one step) and sets its
refcount to 1; ``release_rows`` decrements every mapped page of the
released rows and pushes only the pages whose refcount reaches 0 back
onto the stack.  Conservation invariant (the hypothesis property in
``tests/test_pager.py``): the free-list prefix and the pages referenced
by block tables always partition ``0..n_pages-1``, and each referenced
page's refcount equals the number of block-table entries pointing at it
— no page is simultaneously free and mapped, or lost.

Prefix sharing and copy-on-write (the refcount's reason to exist):

  * ``share_prefix`` maps the leading blocks of a *donor* row into a
    newly admitted row's block table and bumps each shared page's
    refcount — the sharer reads the donor's already-written prompt K/V
    without re-running prefill for it.  Shared pages are always *full*
    prompt pages (page-aligned sharing), so the donor never writes them
    again (its write positions only grow).
  * a page with ``rc > 1`` is read-only to everyone.  ``cow_on_write``
    runs before any paged write: a row about to write a page it does not
    exclusively own pops a fresh page, swaps its block-table entry, and
    drops its ref on the shared page (a ref dropped to 0 — every other
    holder CoW'd or released first — sends the page straight back to the
    free list, so simultaneous CoWs cannot leak it).  The caller copies
    the already-written slot prefix with ``copy_page_prefix`` (a jitted
    masked copy — slots at and above the write position are garbage by
    construction and are zeroed, never read).  Because sharing is
    page-aligned, a row can hit CoW at most once: only when its whole
    prompt is shared (the re-fed last prompt token lands in the final
    shared page); engine admission reserves one extra page for it.
  * pop order within one jitted step is deterministic: CoW pops rank
    before the step's ``alloc_on_write``/``alloc_range`` pops, rows
    ranked by batch index inside each — the engine's host mirror relies
    on nothing finer than the reservation totals, but tests do.

Recurrent-state snapshot slots (the same machinery, one level up): the
recurrent families (ssm / hybrid) cannot *skip* prompt positions the way
attention can read a peer's pages — the state at token t depends on every
token before it.  What they can do is *restore*: the decode state grows a
page-boundary snapshot store whose contract deliberately mirrors the
block table's (``lm.init_decode_state(snapshots=True)`` builds it):

  * snapshot pools ``snap_ssm (n_slots, layers, H, P, N)`` f32 and
    ``snap_conv (n_slots, layers, K-1, d_inner)`` — one slot holds a
    row's *full-depth* SSM + conv state captured exactly at a page
    boundary (after feeding ``(j+1) * page_size`` tokens).
  * slot table  ``snap_table (B, max_boundaries)`` int32 — column ``j``
    maps the slot for boundary ``j+1``; ``-1`` = no snapshot.  Boundary
    space is block space with ``page_size == 1``: the *same* allocator
    functions (``alloc_on_write`` / ``share_prefix`` / ``release_rows``)
    manage slots, so the free list / refcount conservation invariant —
    and its property test — carry over verbatim.
  * capture      a step that *ends* exactly at a boundary allocates the
    column's slot and scatters the post-step state into the pools
    (``lm._snap_capture``); the serving engine clips chunk widths so
    every boundary is a step endpoint.  A slot with rc > 1 is read-only
    (the shared-page contract); slots are recycled without zeroing — a
    recycled slot is fully overwritten at its next capture before any
    restore can read it.
  * share/restore  admission maps the donor's leading ``nblk`` slots
    (refcount bumps keep them alive past the donor's release, exactly
    like shared pages) and loads slot ``nblk - 1`` into the row's live
    state (``lm.restore_snapshots``), so prefill resumes at the first
    unshared token with the recurrence already advanced.
  * release      ``reset_decode_rows`` releases a row's slots with its
    pages: refs drop, rc==0 slots return to the free stack, slots still
    held by a sharer stay resident.
  * sizing       the slot pool is built at the worst case
    (``batch x ceil(max_len / page_size)``) so — like the engine's page
    reservation ledger — capture can never find the free list dry.

Two-tier paging — host spill / restore (the memory-pressure escape
valve): the engine's preemption path moves a victim row's pages out of
the device pool instead of dropping the request.  The host tier is a
*second* (pool, table, free list, refcount) quadruple managed by the
same allocator primitives, so the conservation invariant generalizes
instead of forking:

  * ``spill_rows`` pops one host slot per mapped block of each victim
    row, records it in the row's host table, and *releases* the device
    pages (``release_rows`` — a page still referenced by a
    prefix-sharing peer stays resident; the victim gets a private host
    copy either way).  The returned ``(src, dst)`` id vectors drive the
    data move (``copy_pages``) device-pool → host-pool inside the same
    jitted call — release never zeroes pool data, so copying after the
    bookkeeping is safe.
  * ``restore_rows`` is the exact mirror: pop fresh device pages for
    every host-table entry, copy host-pool → device-pool, release the
    host slots.  A restored row owns its pages privately (rc == 1) even
    where it used to share — sharing is re-established only through
    future admissions, never assumed across a spill.
  * sizing & dryness: the host pool is built at the worst case
    (``batch x max_blocks`` slots — every row fully resident, all
    spilled), so a spill can never find the host free list dry; restore
    pops are gated by the engine's reservation ledger (the row's
    worst-case page count re-enters the ledger before ``restore_rows``
    runs), so they can never find the *device* free list dry.  Both are
    the same "reservation prevents this" convention as ``alloc_on_write``
    — a dry pop degrades to a skipped block, never to corruption.
  * conservation (the generalized property in ``tests/test_pager.py``):
    within each tier, the free-list prefix and the pages referenced by
    that tier's tables partition ``0..n-1`` and rc equals reference
    multiplicity — "free + device-resident" and "free + host-resident"
    each partition their pool, with host rc always 1 (host copies are
    private by construction).
  * snapshot slots ride the same functions: for recurrent families the
    engine spills the victim's snapshot table through ``spill_rows`` on
    boundary space against a host snapshot pool (``copy_pages`` with
    ``axis=0`` — snapshot pools are slot-major), so shared boundary
    state survives the victim's eviction exactly like shared KV pages.
  * placement note: in this repro the host pools are ordinary arrays —
    the two-tier *accounting* is the contract.  On a real TPU they would
    be pinned-host buffers (``memory_kind="pinned_host"``); nothing in
    the bookkeeping changes.

Quantized pools (``CacheConfig(kv_dtype="int8")``): the KV payload is
stored as symmetric int8 (``q = clip(round(x / s), -127, 127)``) with
one f32 scale per (page, kv-head) in a companion *scale pool* —
``(stacks, n_pages, Hkv)`` beside the ``(stacks, n_pages, page_size,
Hkv, hd)`` payload, ``(stacks, n_slots, Hkv)`` on the host tier.  The
scale is the page's running amax over its written slots divided by 127:
``write_page_quant`` / ``write_page_chunk_quant`` *reset* it when they
write slot 0 of a page (sequential writes enter every fresh page at
slot 0, so a recycled page's stale payload+scale never leak into a new
owner) and otherwise merge by max, requantizing the already-written
payload in the same scatter when the scale grows.  Scales are
page-indexed bookkeeping exactly like refcounts:

  * spill/restore — the scale pools ride the same ``(src, dst)`` id
    vectors through ``copy_pages`` (page axis 1, like the payload), so
    the host tier stores the *quantized* form and spill bandwidth
    halves along with residency;
  * CoW — ``copy_page_scale`` moves the donor page's scales onto the
    fresh page alongside ``copy_page_prefix``, so the copied slot
    prefix keeps dequantizing bit-identically;
  * share/release — no scale work: scales travel with the page id, and
    the slot-0 reset on the next owner's first write retires stale
    entries.

Attention accumulation is unaffected: the payload dequantizes to f32
inside the kernels (``flash_*_paged_quant_pallas``) and the scale pools
themselves stay f32 end to end (lint rule R007).

Multi-page-per-step allocation (chunked prefill): a step that writes a
*range* of positions ``start..end`` may straddle several blocks, so
``alloc_range`` maps every block covering the range in one jitted call —
a statically unrolled ladder of single-block ``alloc_on_write`` passes
(``(max_chunk-1)//page_size + 2`` of them), each with the same
rank-by-batch-index pop order, so the conservation invariant and the
fixed-shape/no-retrace discipline are unchanged.  ``write_page_chunk`` is
the matching multi-token scatter: token ``i`` of row ``b`` lands at
``(block_table[b, (start+i)//page_size], (start+i) % page_size)``; chunk
padding (``i >= width``) and inactive rows route to the out-of-bounds
sentinel page and drop.  Admission-time reservation already covers the
worst case (``pages_needed`` counts positions ``0..total_len-2``), so a
chunked step can never find the free list empty for a live request.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class PagerState(NamedTuple):
    """Free-list stack + per-page refcounts as device arrays (a pytree;
    jit/donation friendly)."""

    free: jax.Array  # (n_pages,) int32: free[:top] are free page ids
    top: jax.Array   # ()        int32: number of free pages
    rc: jax.Array    # (n_pages,) int32: block-table refs per page (0 = free)


def init_pager(n_pages: int) -> PagerState:
    return PagerState(
        free=jnp.arange(n_pages, dtype=jnp.int32),
        top=jnp.asarray(n_pages, jnp.int32),
        rc=jnp.zeros((n_pages,), jnp.int32),
    )


def _push_freed(free: jax.Array, top: jax.Array,
                freed: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Push the pages selected by the (n_pages,) bool mask onto the stack
    (ascending page id — any deterministic order works; readers only ever
    pop from the top)."""
    n_pages = free.shape[0]
    page_ids = jnp.arange(n_pages, dtype=jnp.int32)
    rank = jnp.cumsum(freed) - 1
    dst = jnp.where(freed, top + rank, n_pages)       # sentinel: dropped
    free = free.at[dst].set(page_ids, mode="drop")
    return free, top + jnp.sum(freed, dtype=jnp.int32)


def init_block_table(batch: int, max_blocks: int) -> jax.Array:
    return jnp.full((batch, max_blocks), -1, jnp.int32)


def pages_needed(total_len: int, page_size: int) -> int:
    """Pages a request reserves at admission (host-side accounting).

    A request of ``total_len`` tokens writes cache positions
    ``0..total_len-2`` (the feed at the last position only *predicts*, its
    token is never cached), touching ``ceil((total_len-1)/page_size)``
    blocks.  Admission reserves this worst case so alloc-on-write can never
    find the free list empty mid-request.
    """
    return max(1, -(-(total_len - 1) // page_size))


def alloc_on_write(
    pager: PagerState,
    block_table: jax.Array,          # (B, max_blocks) int32
    idx: jax.Array,                  # () or (B,) int32: position being written
    active: Optional[jax.Array] = None,   # (B,) bool; None = all rows
    *,
    page_size: int,
) -> Tuple[PagerState, jax.Array]:
    """Map the block covering ``idx`` for every row that needs one.

    Pure ``jnp``, fixed shapes: rows needing a page are ranked by batch
    index and pop ``free[top-1-rank]``.  A row whose block is already
    mapped, out of range, or inactive is untouched; if the free list runs
    dry the remaining rows simply stay unmapped (writes to unmapped blocks
    drop — admission-time reservation prevents this for live requests).
    """
    b, max_blocks = block_table.shape
    idx_b = jnp.broadcast_to(jnp.asarray(idx, jnp.int32).reshape(-1), (b,))
    if active is None:
        active = jnp.ones((b,), bool)
    blk = idx_b // page_size
    in_range = blk < max_blocks
    blk_c = jnp.clip(blk, 0, max_blocks - 1)
    cur = jnp.take_along_axis(block_table, blk_c[:, None], axis=1)[:, 0]
    need = active & in_range & (cur < 0)
    rank = jnp.cumsum(need) - 1                     # rank among needy rows
    grant = need & (rank < pager.top)
    n_pages = pager.free.shape[0]
    src = jnp.clip(pager.top - 1 - rank, 0, n_pages - 1)
    page = jnp.where(grant, pager.free[src], cur)
    col = jax.lax.broadcasted_iota(jnp.int32, block_table.shape, 1)
    block_table = jnp.where(
        grant[:, None] & (col == blk_c[:, None]), page[:, None], block_table
    )
    top = pager.top - jnp.sum(grant, dtype=jnp.int32)
    rc = pager.rc.at[jnp.where(grant, page, n_pages)].set(1, mode="drop")
    return PagerState(pager.free, top, rc), block_table


def alloc_range(
    pager: PagerState,
    block_table: jax.Array,          # (B, max_blocks) int32
    start: jax.Array,                # () or (B,) int32: first position written
    end: jax.Array,                  # () or (B,) int32: last position written
    active: Optional[jax.Array] = None,   # (B,) bool; None = all rows
    *,
    page_size: int,
    max_chunk: int,
) -> Tuple[PagerState, jax.Array]:
    """Map every block covering positions ``start..end`` (inclusive).

    The multi-page-per-step generalization of ``alloc_on_write`` for
    chunked prefill: ``max_chunk`` statically bounds ``end - start + 1``,
    so the loop unrolls to a fixed ladder of single-block allocations
    (fixed shapes, nothing retraces).  Rung ``k`` targets block
    ``start//page_size + k`` and is masked out for rows whose range ends
    in an earlier block, so rows needing fewer blocks allocate fewer
    pages.  The gate compares *block indices*, not positions: a range
    starting mid-page (speculative-decoding verify chunks start at
    arbitrary positions) can cross into its next block fewer than
    ``page_size`` positions after ``start``, so gating on
    ``start + k*page_size <= end`` would skip a block the chunk writes.
    """
    b = block_table.shape[0]
    start_b = jnp.broadcast_to(jnp.asarray(start, jnp.int32).reshape(-1), (b,))
    end_b = jnp.broadcast_to(jnp.asarray(end, jnp.int32).reshape(-1), (b,))
    if active is None:
        active = jnp.ones((b,), bool)
    start_blk = start_b // page_size
    end_blk = end_b // page_size
    for k in range((max_chunk - 1) // page_size + 2):
        blk = start_blk + k
        # first position of block ``blk`` inside the range (== start for
        # the first rung, the block's base position after that)
        idx = jnp.maximum(start_b, blk * page_size)
        pager, block_table = alloc_on_write(
            pager, block_table, jnp.minimum(idx, end_b),
            active & (blk <= end_blk), page_size=page_size,
        )
    return pager, block_table


def release_rows(
    pager: PagerState,
    block_table: jax.Array,   # (B, max_blocks) int32
    mask: jax.Array,          # (B,) bool: rows whose pages return to the pool
) -> Tuple[PagerState, jax.Array]:
    """Drop the masked rows' refs on every page they map, push the pages
    whose refcount reaches 0 back onto the free stack, and unmap the rows.
    A page still referenced by a prefix-sharing peer stays resident (its
    content outlives the row that first wrote it).  Releasing an
    already-empty row is a no-op, so release-on-completion and
    release-at-admission compose."""
    n_pages = pager.free.shape[0]
    give = mask[:, None] & (block_table >= 0)
    pages = jnp.where(give, block_table, n_pages).reshape(-1)
    # per-page ref drops (duplicates accumulate: two released sharers of
    # one page decrement it twice in this single call)
    dec = jnp.zeros((n_pages,), jnp.int32).at[pages].add(1, mode="drop")
    rc = pager.rc - dec
    freed = (pager.rc > 0) & (rc <= 0) & (dec > 0)
    rc = jnp.maximum(rc, 0)
    free, top = _push_freed(pager.free, pager.top, freed)
    block_table = jnp.where(mask[:, None], -1, block_table)
    return PagerState(free, top, rc), block_table


def release_tail(
    pager: PagerState,
    block_table: jax.Array,   # (B, max_blocks) int32
    frontier: jax.Array,      # (B,) int32: highest live position + 1
    mask: jax.Array,          # (B,) bool: rows to roll back
    *,
    page_size: int,
) -> Tuple[PagerState, jax.Array]:
    """Release the masked rows' blocks strictly *beyond* their write
    frontier (speculative-decoding rollback).

    The verify step of draft-and-verify allocates pages for the full
    drafted chunk before knowing how much survives acceptance; a row
    that accepts fewer tokens keeps its blocks up to and including the
    one covering position ``frontier - 1`` (the last *written* cache
    position is ``frontier - 1`` — the feed at ``frontier`` only
    predicts) and returns the over-allocated tail to the pool.  Same
    refcount discipline as ``release_rows`` (the tail pages of a
    verify-chunk are freshly allocated and private, but the masked
    decrement keeps the conservation invariant unconditional), and a
    row whose tail is empty is a no-op — so calling it every spec step
    composes with release-on-completion."""
    n_pages = pager.free.shape[0]
    b, max_blocks = block_table.shape
    fr = jnp.broadcast_to(jnp.asarray(frontier, jnp.int32).reshape(-1), (b,))
    keep_blk = (jnp.maximum(fr, 1) - 1) // page_size
    col = jax.lax.broadcasted_iota(jnp.int32, block_table.shape, 1)
    give = (mask[:, None] & (block_table >= 0)
            & (col > keep_blk[:, None]))
    pages = jnp.where(give, block_table, n_pages).reshape(-1)
    dec = jnp.zeros((n_pages,), jnp.int32).at[pages].add(1, mode="drop")
    rc = pager.rc - dec
    freed = (pager.rc > 0) & (rc <= 0) & (dec > 0)
    rc = jnp.maximum(rc, 0)
    free, top = _push_freed(pager.free, pager.top, freed)
    block_table = jnp.where(give, -1, block_table)
    return PagerState(free, top, rc), block_table


def spill_rows(
    pager: PagerState,
    table: jax.Array,         # (B, max_blocks) int32: device-tier table
    hpager: PagerState,       # host-tier allocator (n_slots entries)
    htable: jax.Array,        # (B, max_blocks) int32: host-tier table
    mask: jax.Array,          # (B,) bool: victim rows
) -> Tuple[PagerState, jax.Array, PagerState, jax.Array, jax.Array, jax.Array]:
    """Move the masked rows' mapped blocks from the device tier to the
    host tier (preemption).

    For every mapped block of a victim row: pop a host slot (rows and
    blocks ranked in flattened row-major order — the same deterministic
    pop discipline as ``alloc_on_write``), record it in the host table,
    then release the device pages (``release_rows`` — a page a
    prefix-sharing peer still references stays resident; the victim gets
    a private host copy regardless, so restore never depends on the peer
    outliving the spill).

    Returns ``(pager, table, hpager, htable, src, dst)``.  ``src`` /
    ``dst`` are flattened ``(B * max_blocks,)`` id vectors — device page
    to read, host slot to fill — with out-of-bounds sentinels for
    blocks that did not move; feed them to ``copy_pages`` *in the same
    jitted call* (release touches only bookkeeping, never pool data, so
    copying after the release is safe).  Host-pool dryness is prevented
    by worst-case sizing (see the module docstring); a dry pop skips the
    block, never corrupts."""
    b, max_blocks = table.shape
    n_pages = pager.free.shape[0]
    n_slots = hpager.free.shape[0]
    give = mask[:, None] & (table >= 0) & (htable < 0)
    flat = give.reshape(-1)
    rank = jnp.cumsum(flat) - 1
    grant = flat & (rank < hpager.top)
    sidx = jnp.clip(hpager.top - 1 - rank, 0, n_slots - 1)
    slot = jnp.where(grant, hpager.free[sidx], n_slots)
    h_top = hpager.top - jnp.sum(grant, dtype=jnp.int32)
    h_rc = hpager.rc.at[slot].set(1, mode="drop")   # host copies are private
    htable = jnp.where(
        grant.reshape(b, max_blocks), slot.reshape(b, max_blocks), htable
    )
    src = jnp.where(grant, table.reshape(-1), n_pages)
    dst = slot
    pager, table = release_rows(pager, table, mask)
    return pager, table, PagerState(hpager.free, h_top, h_rc), htable, src, dst


def restore_rows(
    pager: PagerState,
    table: jax.Array,         # (B, max_blocks) int32: device-tier table
    hpager: PagerState,       # host-tier allocator (n_slots entries)
    htable: jax.Array,        # (B, max_blocks) int32: host-tier table
    mask: jax.Array,          # (B,) bool: rows to bring back on device
) -> Tuple[PagerState, jax.Array, PagerState, jax.Array, jax.Array, jax.Array]:
    """The exact mirror of ``spill_rows``: re-allocate device pages for
    every host-table entry of the masked rows, then release the host
    slots (always rc == 1 — host copies are private by construction).

    A restored row owns its pages privately (rc == 1) even where it used
    to share.  Returns ``(pager, table, hpager, htable, src, dst)`` with
    ``src`` = host slots to read, ``dst`` = fresh device pages to fill
    (flattened, sentinel = skip) for ``copy_pages``.  Device-pool
    dryness is prevented by the engine's reservation ledger (the row's
    worst-case page count re-enters the ledger before this runs)."""
    b, max_blocks = table.shape
    n_pages = pager.free.shape[0]
    n_slots = hpager.free.shape[0]
    take = mask[:, None] & (htable >= 0) & (table < 0)
    flat = take.reshape(-1)
    rank = jnp.cumsum(flat) - 1
    grant = flat & (rank < pager.top)
    pidx = jnp.clip(pager.top - 1 - rank, 0, n_pages - 1)
    page = jnp.where(grant, pager.free[pidx], n_pages)
    top = pager.top - jnp.sum(grant, dtype=jnp.int32)
    rc = pager.rc.at[page].set(1, mode="drop")
    table = jnp.where(
        grant.reshape(b, max_blocks), page.reshape(b, max_blocks), table
    )
    src = jnp.where(grant, htable.reshape(-1), n_slots)
    dst = page
    hpager, htable = release_rows(hpager, htable, mask)
    return PagerState(pager.free, top, rc), table, hpager, htable, src, dst


def copy_pages(
    dst_pool: jax.Array,
    src_pool: jax.Array,
    src: jax.Array,     # (M,) int32 ids into src_pool's page axis
    dst: jax.Array,     # (M,) int32 ids into dst_pool's page axis
    *,
    axis: int = 1,
) -> jax.Array:
    """Bulk whole-page move between pools (the spill/restore data plane).

    Gathers page ``src[i]`` from ``src_pool`` and scatters it to page
    ``dst[i]`` of ``dst_pool``; out-of-bounds sentinels drop.  ``axis``
    selects the page axis: 1 for KV pools (``(stacks, n_pages, ...)``),
    0 for snapshot pools (slot-major ``(n_slots, ...)``).  Whole pages
    are copied — slots beyond the written prefix carry garbage on both
    sides of the move, which the sequential-write contract already makes
    unobservable."""
    n_src = src_pool.shape[axis]
    content = jnp.take(src_pool, jnp.clip(src, 0, n_src - 1), axis=axis)
    content = content.astype(dst_pool.dtype)
    if axis == 0:
        return dst_pool.at[dst].set(content, mode="drop")
    if axis == 1:
        return dst_pool.at[:, dst].set(content, mode="drop")
    raise ValueError(f"copy_pages: unsupported page axis {axis}")


def share_prefix(
    pager: PagerState,
    block_table: jax.Array,   # (B, max_blocks) int32
    src: jax.Array,           # (B,) int32: donor row per admitted row
    nblk: jax.Array,          # (B,) int32: leading blocks to share (0 = none)
    mask: jax.Array,          # (B,) bool: rows being admitted
) -> Tuple[PagerState, jax.Array]:
    """Map the donor rows' leading blocks into the masked rows and bump the
    shared pages' refcounts.

    Pure ``jnp``, fixed shapes — runs inside the engine's jitted ``_admit``
    (``nblk == 0`` rows are untouched, so the non-sharing admission path is
    the same trace).  The caller (the engine's host-side prefix index)
    guarantees the donor is a live row outside ``mask`` whose first
    ``nblk`` blocks are mapped and fully written; unmapped donor entries
    are skipped defensively.  Duplicate bumps accumulate: two rows
    admitted in one call sharing the same donor page raise its refcount
    by two."""
    b = block_table.shape[0]
    n_pages = pager.free.shape[0]
    src_c = jnp.clip(jnp.asarray(src, jnp.int32).reshape(-1), 0, b - 1)
    donor = block_table[src_c]                          # (B, max_blocks)
    col = jax.lax.broadcasted_iota(jnp.int32, block_table.shape, 1)
    nblk_b = jnp.broadcast_to(jnp.asarray(nblk, jnp.int32).reshape(-1), (b,))
    take = mask[:, None] & (col < nblk_b[:, None]) & (donor >= 0)
    block_table = jnp.where(take, donor, block_table)
    pages = jnp.where(take, donor, n_pages).reshape(-1)
    inc = jnp.zeros((n_pages,), jnp.int32).at[pages].add(1, mode="drop")
    return PagerState(pager.free, pager.top, pager.rc + inc), block_table


def cow_on_write(
    pager: PagerState,
    block_table: jax.Array,          # (B, max_blocks) int32
    idx: jax.Array,                  # () or (B,) int32: position being written
    active: Optional[jax.Array] = None,   # (B,) bool; None = all rows
    *,
    page_size: int,
) -> Tuple[PagerState, jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Copy-on-write step: un-share the page each row is about to write.

    For every active row whose target block maps a page with ``rc > 1``
    (readable by someone else), pop a fresh page, swap the block-table
    entry, set the fresh page's refcount to 1 and drop the row's ref on
    the shared page — pages whose refcount reaches 0 (simultaneous CoWs
    by every remaining holder) go straight back to the free list, so
    nothing leaks.  Returns ``(pager, block_table, src, dst, limit,
    moved)``: the caller must copy slots ``[0, limit)`` of each moved
    row's old page into the new one in every pool slab
    (``copy_page_prefix``) *before* writing position ``idx``.  ``src`` /
    ``dst`` are ``n_pages`` sentinels for rows that did not move.

    Rows needing a fresh page rank by batch index, same pop discipline as
    ``alloc_on_write``; admission-time reservation (one spare page per
    fully-shared prompt) keeps the free list from running dry here, and a
    dry pop leaves the row on the shared page (same "reservation prevents
    this" convention as a denied alloc)."""
    b, max_blocks = block_table.shape
    n_pages = pager.free.shape[0]
    idx_b = jnp.broadcast_to(jnp.asarray(idx, jnp.int32).reshape(-1), (b,))
    if active is None:
        active = jnp.ones((b,), bool)
    blk = idx_b // page_size
    blk_c = jnp.clip(blk, 0, max_blocks - 1)
    cur = jnp.take_along_axis(block_table, blk_c[:, None], axis=1)[:, 0]
    shared = (
        active & (blk < max_blocks) & (cur >= 0)
        & (pager.rc[jnp.clip(cur, 0, n_pages - 1)] > 1)
    )
    rank = jnp.cumsum(shared) - 1
    grant = shared & (rank < pager.top)
    slot = jnp.clip(pager.top - 1 - rank, 0, n_pages - 1)
    fresh = jnp.where(grant, pager.free[slot], cur)
    col = jax.lax.broadcasted_iota(jnp.int32, block_table.shape, 1)
    block_table = jnp.where(
        grant[:, None] & (col == blk_c[:, None]), fresh[:, None], block_table
    )
    top = pager.top - jnp.sum(grant, dtype=jnp.int32)
    old = jnp.where(grant, cur, n_pages)
    dec = jnp.zeros((n_pages,), jnp.int32).at[old].add(1, mode="drop")
    rc = pager.rc - dec
    orphaned = (pager.rc > 0) & (rc <= 0) & (dec > 0)
    rc = jnp.maximum(rc, 0)
    rc = rc.at[jnp.where(grant, fresh, n_pages)].set(1, mode="drop")
    free, top = _push_freed(pager.free, top, orphaned)
    limit = idx_b % page_size
    dst = jnp.where(grant, fresh, n_pages)
    return PagerState(free, top, rc), block_table, old, dst, limit, grant


def copy_page_prefix(
    pool: jax.Array,    # (stacks, n_pages, page_size, Hkv, hd)
    src: jax.Array,     # (B,) int32 page ids (n_pages sentinel = skip row)
    dst: jax.Array,     # (B,) int32 page ids (n_pages sentinel = skip row)
    limit: jax.Array,   # (B,) int32: copy slots [0, limit)
) -> jax.Array:
    """The CoW data move: copy each moved row's already-written slot
    prefix from its old page to its fresh page across every layer slab in
    one masked gather/scatter.  Slots at and above ``limit`` hold garbage
    by the sequential-write contract and are zeroed, never read."""
    n_pages, page_size = pool.shape[1], pool.shape[2]
    content = pool[:, jnp.clip(src, 0, n_pages - 1)]   # (stacks, B, S, ...)
    keep = jnp.arange(page_size, dtype=jnp.int32)[None, :] < limit[:, None]
    content = jnp.where(
        keep[None, :, :, None, None], content, jnp.zeros((), pool.dtype)
    )
    return pool.at[:, dst].set(content, mode="drop")


def write_page(
    pool: jax.Array,                 # (n_pages, page_size, Hkv, hd)
    new: jax.Array,                  # (B, Hkv, hd): one token per row
    block_table: jax.Array,          # (B, max_blocks) int32
    idx: jax.Array,                  # () or (B,) int32: absolute position
    active: Optional[jax.Array] = None,
) -> jax.Array:
    """Write one token's K or V through the block table.

    One fused scatter: each row lands at (page, slot) =
    (``bt[b, idx//P]``, ``idx % P``); rows that are inactive, out of range,
    or unmapped are routed to an out-of-bounds sentinel page and dropped.
    """
    n_pages, page_size = pool.shape[0], pool.shape[1]
    b, max_blocks = block_table.shape
    idx_b = jnp.broadcast_to(jnp.asarray(idx, jnp.int32).reshape(-1), (b,))
    blk = idx_b // page_size
    blk_c = jnp.clip(blk, 0, max_blocks - 1)
    page = jnp.take_along_axis(block_table, blk_c[:, None], axis=1)[:, 0]
    ok = (blk < max_blocks) & (page >= 0)
    if active is not None:
        ok &= active
    page = jnp.where(ok, page, n_pages)
    return pool.at[page, idx_b % page_size].set(
        new.astype(pool.dtype), mode="drop"
    )


def write_page_chunk(
    pool: jax.Array,                 # (n_pages, page_size, Hkv, hd)
    new: jax.Array,                  # (B, C, Hkv, hd): C tokens per row
    block_table: jax.Array,          # (B, max_blocks) int32
    start: jax.Array,                # () or (B,) int32: pos of chunk token 0
    width: jax.Array,                # () or (B,) int32: real tokens (1..C)
    active: Optional[jax.Array] = None,
) -> jax.Array:
    """Write a chunk of C tokens' K or V through the block table.

    One fused scatter: token ``i`` of row ``b`` lands at (page, slot) =
    (``bt[b, (start+i)//P]``, ``(start+i) % P``); chunk padding
    (``i >= width``), inactive rows, out-of-range and unmapped blocks are
    routed to the out-of-bounds sentinel page and dropped.  Positions are
    distinct within a row and pages are owned by a single row, so the
    scatter never writes one slot twice.
    """
    n_pages, page_size = pool.shape[0], pool.shape[1]
    b, max_blocks = block_table.shape
    c = new.shape[1]
    start_b = jnp.broadcast_to(jnp.asarray(start, jnp.int32).reshape(-1), (b,))
    w_b = jnp.broadcast_to(jnp.asarray(width, jnp.int32).reshape(-1), (b,))
    i = jnp.arange(c, dtype=jnp.int32)[None, :]
    posmat = start_b[:, None] + i                          # (B, C)
    blk = posmat // page_size
    blk_c = jnp.clip(blk, 0, max_blocks - 1)
    page = jnp.take_along_axis(block_table, blk_c, axis=1)  # (B, C)
    ok = (i < w_b[:, None]) & (blk < max_blocks) & (page >= 0)
    if active is not None:
        ok &= active[:, None]
    page = jnp.where(ok, page, n_pages)
    return pool.at[page, posmat % page_size].set(
        new.astype(pool.dtype), mode="drop"
    )


# ---------------------------------------------------------------------------
# Quantized writes (kv_dtype="int8"): int8 payload + per-(page, head)
# f32 scales.  Contract in the module docstring ("Quantized pools").
# ---------------------------------------------------------------------------

_QMAX = 127.0


def _quant_safe(scale: jax.Array) -> jax.Array:
    """Divide-safe scale: a zero scale encodes an all-zero payload, so any
    positive stand-in quantizes it to exact zeros."""
    return jnp.where(scale > 0, scale, 1.0)


def write_page_quant(
    pool: jax.Array,                 # (n_pages, page_size, Hkv, hd) int8
    scale: jax.Array,                # (n_pages, Hkv) f32
    new: jax.Array,                  # (B, Hkv, hd): one token per row
    block_table: jax.Array,          # (B, max_blocks) int32
    idx: jax.Array,                  # () or (B,) int32: absolute position
    active: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """``write_page`` for the quantized pool: returns ``(pool, scale)``.

    The target page's scale is reset at slot 0 and max-merged after; when
    it grows, the page's already-written slots are requantized in the
    same whole-page scatter that lands the new token.  Masking matches
    ``write_page`` exactly — a dropped payload write drops its scale
    update too, so the two pools can never disagree about a page.
    """
    n_pages, page_size = pool.shape[0], pool.shape[1]
    b, max_blocks = block_table.shape
    idx_b = jnp.broadcast_to(jnp.asarray(idx, jnp.int32).reshape(-1), (b,))
    blk = idx_b // page_size
    blk_c = jnp.clip(blk, 0, max_blocks - 1)
    page = jnp.take_along_axis(block_table, blk_c[:, None], axis=1)[:, 0]
    ok = (blk < max_blocks) & (page >= 0)
    if active is not None:
        ok &= active
    page_c = jnp.clip(page, 0, n_pages - 1)
    tgt = jnp.where(ok, page, n_pages)
    slot = idx_b % page_size

    newf = new.astype(jnp.float32)                       # (B, Hkv, hd)
    s_cand = jnp.max(jnp.abs(newf), axis=-1) / _QMAX     # (B, Hkv)
    s_old = scale[page_c]                                # (B, Hkv)
    fresh = (slot == 0)[:, None]
    s_new = jnp.where(fresh, s_cand, jnp.maximum(s_old, s_cand))
    # requantize the already-written slots when the scale grew; a fresh
    # page's stale payload rescales to zero (never read either way)
    ratio = jnp.where(fresh, 0.0, s_old / _quant_safe(s_new))
    content = pool[page_c].astype(jnp.float32)           # (B, S, Hkv, hd)
    merged = jnp.round(content * ratio[:, None, :, None])
    q_tok = jnp.round(newf / _quant_safe(s_new)[:, :, None])
    sl = jnp.arange(page_size, dtype=jnp.int32)[None, :, None, None]
    merged = jnp.where(sl == slot[:, None, None, None], q_tok[:, None],
                       merged)
    merged = jnp.clip(merged, -_QMAX, _QMAX)
    pool = pool.at[tgt].set(merged.astype(pool.dtype), mode="drop")
    scale = scale.at[tgt].set(s_new, mode="drop")
    return pool, scale


def write_page_chunk_quant(
    pool: jax.Array,                 # (n_pages, page_size, Hkv, hd) int8
    scale: jax.Array,                # (n_pages, Hkv) f32
    new: jax.Array,                  # (B, C, Hkv, hd): C tokens per row
    block_table: jax.Array,          # (B, max_blocks) int32
    start: jax.Array,                # () or (B,) int32: pos of chunk token 0
    width: jax.Array,                # () or (B,) int32: real tokens (1..C)
    active: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """``write_page_chunk`` for the quantized pool: ``(pool, scale)``.

    The f32 chunk write is one fused scatter, but the per-page scale must
    be updated once per *page* the chunk touches, so this unrolls the
    same ``(C-1)//page_size + 2``-rung ladder as ``alloc_range`` — rung
    ``k`` quantizes the sub-chunk landing in block ``start//P + k``
    against that page's merged scale (reset when the rung covers the
    page's slot 0, i.e. ``blk*P >= start``).  Rungs touch disjoint pages
    per row and masking matches ``write_page_chunk``.
    """
    n_pages, page_size = pool.shape[0], pool.shape[1]
    b, max_blocks = block_table.shape
    c = new.shape[1]
    start_b = jnp.broadcast_to(jnp.asarray(start, jnp.int32).reshape(-1), (b,))
    w_b = jnp.broadcast_to(jnp.asarray(width, jnp.int32).reshape(-1), (b,))
    if active is None:
        active = jnp.ones((b,), bool)
    i = jnp.arange(c, dtype=jnp.int32)[None, :]
    posmat = start_b[:, None] + i                        # (B, C)
    end_blk = (start_b + jnp.maximum(w_b, 1) - 1) // page_size
    start_blk = start_b // page_size
    newf = new.astype(jnp.float32)                       # (B, C, Hkv, hd)
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]
    for k in range((c - 1) // page_size + 2):
        blk = start_blk + k
        on = active & (w_b > 0) & (blk <= end_blk) & (blk < max_blocks)
        blk_c = jnp.clip(blk, 0, max_blocks - 1)
        page = jnp.take_along_axis(block_table, blk_c[:, None], axis=1)[:, 0]
        on &= page >= 0
        page_c = jnp.clip(page, 0, n_pages - 1)
        tgt = jnp.where(on, page, n_pages)
        in_rung = (posmat // page_size == blk[:, None]) & (i < w_b[:, None])
        amax = jnp.max(
            jnp.where(in_rung[:, :, None, None], jnp.abs(newf), 0.0),
            axis=(1, 3),
        )                                                # (B, Hkv)
        s_cand = amax / _QMAX
        s_old = scale[page_c]
        fresh = (blk * page_size >= start_b)[:, None]
        s_new = jnp.where(fresh, s_cand, jnp.maximum(s_old, s_cand))
        ratio = jnp.where(fresh, 0.0, s_old / _quant_safe(s_new))
        content = pool[page_c].astype(jnp.float32)       # (B, S, Hkv, hd)
        merged = jnp.round(content * ratio[:, None, :, None])
        q_tok = jnp.round(newf / _quant_safe(s_new)[:, None, :, None])
        sl = jnp.where(in_rung, posmat % page_size, page_size)
        merged = merged.at[rows, sl].set(q_tok, mode="drop")
        merged = jnp.clip(merged, -_QMAX, _QMAX)
        pool = pool.at[tgt].set(merged.astype(pool.dtype), mode="drop")
        scale = scale.at[tgt].set(s_new, mode="drop")
    return pool, scale


def copy_page_scale(
    scales: jax.Array,  # (stacks, n_pages, Hkv) f32
    src: jax.Array,     # (B,) int32 page ids (n_pages sentinel = skip row)
    dst: jax.Array,     # (B,) int32 page ids (n_pages sentinel = skip row)
) -> jax.Array:
    """The CoW scale move: the fresh page inherits its donor's
    per-(page, head) scales so the prefix ``copy_page_prefix`` moved
    keeps dequantizing bit-identically.  Same ``n_pages`` sentinels as
    ``copy_page_prefix`` — rows that did not move drop."""
    n_pages = scales.shape[1]
    content = scales[:, jnp.clip(src, 0, n_pages - 1)]   # (stacks, B, Hkv)
    return scales.at[:, dst].set(content, mode="drop")
