"""Host-side request queue for the serving engine.

The queue is the only part of serving that legitimately lives on the host:
requests arrive from the outside world with ragged prompt lengths.  The
moment a request is admitted into a batch slot it becomes fixed-shape
device state (`SlotState`) and never crosses back until it is finished —
the anti-pattern the paper's §4.3 measures (a host crossing per layer per
step) is confined to admission time.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Deque, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    req_id: int
    tokens: np.ndarray        # (prompt_len,) int32
    max_new_tokens: int

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.max_new_tokens


class RequestQueue:
    """FIFO of pending requests; thread-safe submit (serving workers)."""

    def __init__(self, max_len: Optional[int] = None) -> None:
        self._q: Deque[Request] = deque()
        self._next_id = 0
        self._lock = threading.Lock()
        self.max_len = max_len

    def submit(self, tokens: Sequence[int], max_new_tokens: int) -> int:
        toks = np.asarray(tokens, np.int32).reshape(-1)
        if toks.size == 0:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.max_len is not None and toks.size + max_new_tokens > self.max_len:
            raise ValueError(
                f"request needs {toks.size + max_new_tokens} slots "
                f"> engine max_len {self.max_len}"
            )
        with self._lock:
            rid = self._next_id
            self._next_id += 1
            self._q.append(Request(rid, toks, int(max_new_tokens)))
        return rid

    def peek(self) -> Optional[Request]:
        """Head of the queue without removing it (admission-control look:
        the engine checks page availability *before* committing a pop)."""
        with self._lock:
            return self._q[0] if self._q else None

    def pop(self) -> Request:
        with self._lock:
            return self._q.popleft()

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return len(self._q) > 0
