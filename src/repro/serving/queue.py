"""Host-side request queue for the serving engine.

The queue is the only part of serving that legitimately lives on the host:
requests arrive from the outside world with ragged prompt lengths.  The
moment a request is admitted into a batch slot it becomes fixed-shape
device state (`SlotState`) and never crosses back until it is finished —
the anti-pattern the paper's §4.3 measures (a host crossing per layer per
step) is confined to admission time.

Ordering (SLO-aware admission): the queue is kept sorted by
``(priority desc, deadline budget asc, arrival asc)`` — a higher
``priority`` request is always admitted first; within a priority class a
tighter ``deadline_ms`` budget goes first; ties fall back to arrival
order (``req_id`` is monotonic), so the default
``priority=0, deadline_ms=None`` workload degenerates to exactly the old
FIFO.  The *budget* (not an absolute wall-clock instant) keys the sort so
ordering is deterministic and testable; the engine tracks the absolute
expiry (``submit time + deadline_ms``) for actual timeout enforcement.

Thread-safety: every accessor — including ``__len__``/``__bool__``, which
a worker thread may race against a concurrent ``submit`` — takes the
lock.  ``cancel(req_id)`` removes a still-queued request under the same
lock; requests already admitted to a device slot are past the queue and
cancel through the engine's harvest drain instead.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import threading
from typing import List, Optional, Sequence, Tuple

import numpy as np


class QueueEmpty(LookupError):
    """``pop()`` on an empty queue (typed so callers can distinguish a
    drained queue from a genuine indexing bug)."""


class QueueFullError(RuntimeError):
    """``submit()`` against a queue at ``max_pending`` capacity —
    backpressure, not a bug; callers should retry or shed load."""


@dataclasses.dataclass(frozen=True)
class Request:
    req_id: int
    tokens: np.ndarray        # (prompt_len,) int32
    max_new_tokens: int
    priority: int = 0         # larger = more important (default 0)
    deadline_ms: Optional[float] = None   # SLO budget from submit; None = no SLO

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.max_new_tokens

    @classmethod
    def spec(cls, tokens: Sequence[int], max_new_tokens: int, *,
             priority: int = 0,
             deadline_ms: Optional[float] = None) -> "Request":
        """Build an unsubmitted request spec for ``ServingEngine.submit``
        (``req_id`` is a sentinel — the queue assigns the real id at
        submission; passing a spec to ``queue.submit`` is not supported,
        only the engine unpacks it)."""
        return cls(
            req_id=-1,
            tokens=np.asarray(tokens, np.int32).reshape(-1),
            max_new_tokens=int(max_new_tokens),
            priority=int(priority),
            deadline_ms=deadline_ms,
        )


def _sort_key(req: Request) -> Tuple[int, float, int]:
    return (
        -req.priority,
        req.deadline_ms if req.deadline_ms is not None else math.inf,
        req.req_id,
    )


class RequestQueue:
    """Priority/deadline-ordered pending requests; thread-safe throughout
    (serving workers submit/cancel concurrently with the engine loop)."""

    def __init__(self, max_len: Optional[int] = None,
                 max_pending: Optional[int] = None) -> None:
        self._q: List[Request] = []
        self._keys: List[Tuple[int, float, int]] = []   # parallel sort keys
        self._next_id = 0
        self._lock = threading.Lock()
        self.max_len = max_len
        self.max_pending = max_pending

    def submit(
        self, tokens: Sequence[int], max_new_tokens: int, *,
        priority: int = 0, deadline_ms: Optional[float] = None,
    ) -> int:
        toks = np.asarray(tokens, np.int32).reshape(-1)
        with self._lock:
            # every rejection names the id the request *would* get, but the
            # counter only advances on success: a rejected submit has no
            # side effect and the live id space stays dense
            rid = self._next_id
            if toks.size == 0:
                raise ValueError(f"request {rid}: empty prompt")
            if max_new_tokens < 1:
                raise ValueError(
                    f"request {rid}: max_new_tokens must be >= 1"
                )
            if (self.max_len is not None
                    and toks.size + max_new_tokens > self.max_len):
                raise ValueError(
                    f"request {rid}: needs {toks.size + max_new_tokens} "
                    f"slots > engine max_len {self.max_len}"
                )
            if (self.max_pending is not None
                    and len(self._q) >= self.max_pending):
                raise QueueFullError(
                    f"request {rid}: queue full ({len(self._q)} pending >= "
                    f"max_pending {self.max_pending})"
                )
            self._next_id += 1
            req = Request(rid, toks, int(max_new_tokens), int(priority),
                          deadline_ms)
            key = _sort_key(req)
            i = bisect.bisect_right(self._keys, key)
            self._keys.insert(i, key)
            self._q.insert(i, req)
        return rid

    def peek(self) -> Optional[Request]:
        """Head of the queue without removing it (admission-control look:
        the engine checks page availability *before* committing a pop)."""
        with self._lock:
            return self._q[0] if self._q else None

    def pop(self) -> Request:
        with self._lock:
            if not self._q:
                raise QueueEmpty("pop() on an empty RequestQueue")
            self._keys.pop(0)
            return self._q.pop(0)

    def cancel(self, req_id: int) -> Optional[Request]:
        """Remove a still-queued request; returns it, or None if the id is
        not in the queue (already admitted, finished, or unknown)."""
        with self._lock:
            for i, req in enumerate(self._q):
                if req.req_id == req_id:
                    self._keys.pop(i)
                    return self._q.pop(i)
        return None

    def pending_ids(self) -> List[int]:
        """Snapshot of queued request ids (deadline sweeps)."""
        with self._lock:
            return [r.req_id for r in self._q]

    def peek_next_id(self) -> int:
        """The id the next ``submit`` will be assigned (error context for
        pre-queue validation in the engine)."""
        with self._lock:
            return self._next_id

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)

    def __bool__(self) -> bool:
        with self._lock:
            return len(self._q) > 0
