"""Draft proposers for speculative decoding.

Draft-and-verify decoding needs a cheap source of K candidate tokens per
row per step; the engine then scores all K+1 positions in one
``prefill_chunk`` call (the chunked-prefill machinery *is* the verifier)
and keeps the leading run that matches the verifier's own argmax.  A
drafter therefore never affects *correctness* — a bad draft only lowers
the accept rate — which is what lets both implementations here cut
corners aggressively.

Two drafters, one contract (``repro.serving.config.SpecConfig`` picks):

  * :class:`PromptLookupDrafter` (``drafter="prompt_lookup"``) — n-gram
    prompt lookup: match the last ``ngram`` tokens ending at the row's
    current position against the row's own earlier tokens and propose
    the continuation after the most recent match.  Stateless (pure
    function of the token buffer), family-agnostic, and essentially
    free — the classic win on repetitive suffixes (code, quotations,
    summarization).
  * :class:`HybridSSMDrafter` (``drafter="hybrid_ssm"``) — the ssm half
    of a hybrid drafting for the attention layers: the hybrid family's
    own Mamba blocks (shared weights — ``params["groups"]`` reshaped to
    the stacked-layer form) run as a K-step draft model, skipping the
    shared attention/MLP block that makes full steps expensive.  The
    drafter carries *private* recurrent state (``drf_ssm``/``drf_conv``/
    ``drf_pos`` keys in the decode-state dict — the hidden trajectory
    without attention differs from the model's own, so the model's
    ``ssm`` state cannot be borrowed), advanced only on *committed*
    tokens: proposal steps run on a discarded copy, because an SSM
    cannot roll back a rejected suffix.

Both run entirely inside the engine's jitted ``_spec_n`` (fixed shapes,
no host syncs — R002 scopes this file); the ``stateful`` flag tells the
engine whether to allocate drafter state and ingest committed tokens
(``ingest`` keeps the invariant ``drf_pos <= progress``, catching up
lazily with a statically-bounded chunk).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import components as C


class PromptLookupDrafter:
    """N-gram prompt lookup: propose the continuation after the most
    recent earlier occurrence of the last ``ngram`` tokens."""

    stateful = False

    def __init__(self, spec) -> None:
        self.k = int(spec.k)
        self.ngram = int(spec.ngram)

    def init_state(self, batch: int):
        return {}

    def ingest(self, params, state, tokens, upto, chunk: int):
        return state

    def propose(self, params, state, tokens, progress, active):
        """(B, K) drafts for every row; pure gathers over the token
        buffer (positions ``<= progress`` are real — prompt then
        committed tokens; anything drafted from beyond the frontier is
        garbage the verifier simply rejects)."""
        b, max_len = tokens.shape
        n, k = self.ngram, self.k
        prog = jnp.clip(progress, 0, max_len - 1)
        col = jax.lax.broadcasted_iota(jnp.int32, (b, max_len), 1)
        # key: the n tokens ending at the current position
        koff = jnp.arange(n, dtype=jnp.int32) - (n - 1)
        kidx = jnp.clip(prog[:, None] + koff[None, :], 0, max_len - 1)
        key = jnp.take_along_axis(tokens, kidx, axis=1)        # (B, n)
        # window equality: does the n-gram ending at column i match?
        eq = jnp.ones((b, max_len), bool)
        for j in range(n):
            widx = jnp.clip(col + (j - (n - 1)), 0, max_len - 1)
            eq &= (
                jnp.take_along_axis(tokens, widx, axis=1)
                == key[:, j][:, None]
            )
        # candidate windows must lie fully inside the committed prefix
        match = eq & (col >= n - 1) & (col < progress[:, None])
        i_best = jnp.max(jnp.where(match, col, -1), axis=1)    # (B,)
        found = i_best >= 0
        # the committed suffix ``i_best+1 .. prog`` is the continuation of
        # the most recent match; reading it modulo its length keeps short
        # cycles (period < K, e.g. a converged constant) proposing the
        # cycle instead of running past the frontier into garbage
        period = jnp.maximum(prog - i_best, 1)
        offs = jnp.arange(k, dtype=jnp.int32)[None, :]
        didx = jnp.clip(
            i_best[:, None] + 1 + offs % period[:, None],
            0, max_len - 1,
        )
        drafts = jnp.take_along_axis(tokens, didx, axis=1)     # (B, K)
        # no match: repeat the current token (worst case: accept rate 0)
        cur = jnp.take_along_axis(tokens, prog[:, None], axis=1)
        drafts = jnp.where(found[:, None], drafts, cur)
        return drafts.astype(jnp.int32), state


class HybridSSMDrafter:
    """The hybrid family's Mamba layers as a weight-shared draft model.

    ``params["groups"]`` leaves are ``(g, attn_every, ...)``; reshaping
    the leading two axes gives the ssm-family stacked-layer form, so the
    draft model is one ``lax.scan`` of ``mamba_decode_block`` over all
    ``n_layers`` Mamba blocks plus the shared final norm and head —
    attention (and its KV traffic) is exactly what gets skipped.
    """

    stateful = True

    def __init__(self, spec, cfg) -> None:
        if cfg.family != "hybrid":
            raise ValueError(
                "drafter='hybrid_ssm' drafts with the hybrid family's "
                f"Mamba layers — family 'hybrid' required, got "
                f"{cfg.family!r}"
            )
        self.k = int(spec.k)
        self.cfg = cfg

    def init_state(self, batch: int):
        """Private drafter recurrence (``lm.reset_decode_rows`` zeroes
        these with the row's other caches; spill/restore leaves them in
        the lane like the live ``ssm``/``conv`` state)."""
        cfg = self.cfg
        return {
            "drf_ssm": jnp.zeros(
                (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                 cfg.ssm_state), jnp.float32,
            ),
            "drf_conv": jnp.zeros(
                (cfg.n_layers, batch, cfg.ssm_conv - 1, cfg.d_inner),
                cfg.dtype_(),
            ),
            "drf_pos": jnp.zeros((batch,), jnp.int32),
        }

    def _layers(self, params):
        # (g, attn_every, ...) group leaves -> (n_layers, ...) stacked
        return jax.tree_util.tree_map(
            lambda leaf: leaf.reshape(
                leaf.shape[0] * leaf.shape[1], *leaf.shape[2:]
            ),
            params["groups"],
        )

    def ingest(self, params, state, tokens, upto, chunk: int):
        """Advance the drafter recurrence over committed tokens
        ``drf_pos .. upto-1`` (one masked SSD prefill of static width
        ``chunk`` — rows already caught up, or frozen/spilled rows whose
        ``upto`` has not moved, get width 0 and are no-ops)."""
        cfg = self.cfg
        b, max_len = tokens.shape
        dpos = state["drf_pos"]
        w = jnp.clip(upto - dpos, 0, chunk)
        offs = jnp.arange(chunk, dtype=jnp.int32)
        gidx = jnp.clip(dpos[:, None] + offs[None, :], 0, max_len - 1)
        toks = jnp.take_along_axis(tokens, gidx, axis=1)
        x = params["embed"][toks].astype(cfg.dtype_())
        valid = offs[None, :] < w[:, None]

        def body(x, inp):
            p, s_ssm, s_conv = inp
            x, s_ssm, s_conv = C.mamba_prefill_block(
                cfg, p["mamba"], x, s_ssm, s_conv, valid
            )
            return x, (s_ssm, s_conv)

        _, (ssm, conv) = jax.lax.scan(
            body, x,
            (self._layers(params), state["drf_ssm"], state["drf_conv"]),
        )
        return {**state, "drf_ssm": ssm, "drf_conv": conv,
                "drf_pos": dpos + w}

    def propose(self, params, state, tokens, progress, active):
        """Catch the recurrence up to ``progress`` (committing that
        advance), then run K greedy draft steps on a *discarded* copy —
        rejected proposals must leave no trace in a state that cannot
        roll back."""
        state = self.ingest(params, state, tokens, progress, self.k + 1)
        cfg = self.cfg
        b, max_len = tokens.shape
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        layers = self._layers(params)
        tok0 = jnp.take_along_axis(
            tokens, jnp.clip(progress, 0, max_len - 1)[:, None], axis=1
        )[:, 0]

        def step(carry, _):
            ssm, conv, tok = carry
            x = params["embed"][tok].astype(cfg.dtype_())

            def body(x, inp):
                p, s_ssm, s_conv = inp
                x, s_ssm, s_conv = C.mamba_decode_block(
                    cfg, p["mamba"], x, s_ssm, s_conv
                )
                return x, (s_ssm, s_conv)

            x, (ssm, conv) = jax.lax.scan(body, x, (layers, ssm, conv))
            h = C.norm(cfg, params["ln_f"], x)
            nxt = jnp.argmax(C.dense(h, head), axis=-1).astype(jnp.int32)
            return (ssm, conv, nxt), nxt

        _, drafts = jax.lax.scan(
            step, (state["drf_ssm"], state["drf_conv"], tok0),
            None, length=self.k,
        )
        return drafts.T, state                                  # (B, K)


def make_drafter(spec, cfg):
    """Drafter factory for ``SpecConfig.drafter`` (family-validated)."""
    if spec.drafter == "prompt_lookup":
        return PromptLookupDrafter(spec)
    if spec.drafter == "hybrid_ssm":
        return HybridSSMDrafter(spec, cfg)
    raise ValueError(
        f"unknown drafter {spec.drafter!r} "
        "(expected 'prompt_lookup' or 'hybrid_ssm')"
    )
