"""Continuous-batching serving subsystem (device-side control state)."""
from repro.serving.engine import (
    ServingEngine,
    SlotState,
    engine_step,
    init_slots,
    serve_all,
)
from repro.serving.pager import (
    PagerState,
    alloc_on_write,
    alloc_range,
    copy_page_prefix,
    cow_on_write,
    init_block_table,
    init_pager,
    pages_needed,
    release_rows,
    share_prefix,
    write_page,
    write_page_chunk,
)
from repro.serving.queue import Request, RequestQueue

__all__ = [
    "PagerState",
    "Request",
    "RequestQueue",
    "ServingEngine",
    "SlotState",
    "alloc_on_write",
    "alloc_range",
    "copy_page_prefix",
    "cow_on_write",
    "engine_step",
    "init_block_table",
    "init_pager",
    "init_slots",
    "pages_needed",
    "release_rows",
    "serve_all",
    "share_prefix",
    "write_page",
    "write_page_chunk",
]
