"""Continuous-batching serving subsystem (device-side control state)."""
from repro.serving.engine import (
    ServingEngine,
    SlotState,
    engine_step,
    init_slots,
    serve_all,
)
from repro.serving.pager import (
    PagerState,
    alloc_on_write,
    alloc_range,
    init_block_table,
    init_pager,
    pages_needed,
    release_rows,
    write_page,
    write_page_chunk,
)
from repro.serving.queue import Request, RequestQueue

__all__ = [
    "PagerState",
    "Request",
    "RequestQueue",
    "ServingEngine",
    "SlotState",
    "alloc_on_write",
    "alloc_range",
    "engine_step",
    "init_block_table",
    "init_pager",
    "init_slots",
    "pages_needed",
    "release_rows",
    "serve_all",
    "write_page",
    "write_page_chunk",
]
