"""Continuous-batching serving subsystem (device-side control state)."""
from repro.serving.engine import (
    ServingEngine,
    SlotState,
    engine_step,
    init_slots,
    serve_all,
)
from repro.serving.queue import Request, RequestQueue

__all__ = [
    "Request",
    "RequestQueue",
    "ServingEngine",
    "SlotState",
    "engine_step",
    "init_slots",
    "serve_all",
]
