"""Device-side continuous-batching serving engine.

The paper's §4.3 lesson is that a *partial* port pays for every boundary
crossing between the ported device domain and the host orchestrator.  The
previous serving loop was exactly that pathology: per-row Python decided
prompt-vs-generated feeding and completion with an ``int()`` host sync per
row per decode step.  Here the whole control state lives on-device:

  * ``SlotState`` — per-row token buffer, progress counters, and phase
    flags as fixed-shape device arrays (the prompt/generated distinction is
    a *comparison*, not a branch: generated tokens are written into the
    same buffer the prompt occupies, so feeding is one gather).
  * ``engine_step`` — one fused jit step: token selection, decode, greedy
    sampling, generated-token scatter, done-detection — all ``jnp`` ops.
    ``steps_per_sync`` steps run back-to-back inside one jit call, so
    there is (at most) one host sync per *batch of steps*.
  * slot refill — a jitted masked-write ``admit`` with fixed shapes: new
    requests enter free rows without retracing anything.

Supported families: dense / moe / ssm / hybrid (everything whose decode
state supports per-row positions; VLM cross-caches would additionally need
a per-row vision prefill at admission).

MoE caveat: with capacity dropping (``capacity_factor`` below no-drop) a
row's output depends on which other rows share its decode batch — standard
MoE serving semantics, not an engine artifact.  Token-exact parity with
isolated decode holds when ``capacity_factor >= n_experts``.
"""
from __future__ import annotations

import time
from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serving.queue import Request, RequestQueue


class SlotState(NamedTuple):
    """Per-row serving control state — all device arrays, fixed shapes."""

    tokens: jax.Array      # (B, max_len) int32: prompt then generated tokens
    prompt_len: jax.Array  # (B,) int32
    total_len: jax.Array   # (B,) int32: prompt_len + max_new_tokens
    progress: jax.Array    # (B,) int32: tokens fed to the model so far
    active: jax.Array      # (B,) bool: row currently serving a request


def init_slots(batch: int, max_len: int) -> SlotState:
    return SlotState(
        tokens=jnp.zeros((batch, max_len), jnp.int32),
        prompt_len=jnp.ones((batch,), jnp.int32),
        total_len=jnp.ones((batch,), jnp.int32),
        progress=jnp.zeros((batch,), jnp.int32),
        active=jnp.zeros((batch,), bool),
    )


def engine_step(model: Model, params, mstate, slots: SlotState):
    """One decode step for every row — no host interaction.

    Feeding: row b feeds ``tokens[b, progress[b]]``; because generated
    tokens are scattered into the buffer as they are produced, this single
    gather covers both the prompt phase and the generate phase.
    A row is done after the step that produces its last generated token
    (``progress`` reaches ``total_len - 1``: position t's feed predicts
    position t+1, and positions ``prompt_len .. total_len-1`` are
    generated).  Inactive rows still occupy their lane (fixed shapes) but
    never advance and never write.
    """
    b, max_len = slots.tokens.shape
    feed_idx = jnp.clip(slots.progress, 0, max_len - 1)
    tok = jnp.take_along_axis(slots.tokens, feed_idx[:, None], axis=1)[:, 0]
    logits, mstate = model.decode_step(params, mstate, tok)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    wpos = slots.progress + 1
    # scatter the sampled token where the next feed position is generated
    writes = slots.active & (wpos >= slots.prompt_len) & (wpos < max_len)
    col = jax.lax.broadcasted_iota(jnp.int32, (b, max_len), 1)
    tokens = jnp.where(
        writes[:, None] & (col == wpos[:, None]), nxt[:, None], slots.tokens
    )
    progress = slots.progress + slots.active.astype(jnp.int32)
    active = slots.active & (progress < slots.total_len - 1)
    return mstate, SlotState(
        tokens=tokens,
        prompt_len=slots.prompt_len,
        total_len=slots.total_len,
        progress=progress,
        active=active,
    )


class ServingEngine:
    """Fixed-shape continuous-batching engine over a ``Model``.

    >>> eng = ServingEngine(model, params, batch=4, max_len=64)
    >>> rid = eng.submit([3, 17, 5], max_new_tokens=16)
    >>> outs = eng.run()          # {rid: np.ndarray of generated tokens}
    """

    def __init__(
        self,
        model: Model,
        params,
        *,
        batch: int,
        max_len: int,
        steps_per_sync: int = 8,
    ) -> None:
        if model.cfg.family not in ("dense", "moe", "ssm", "hybrid"):
            raise NotImplementedError(
                f"serving engine: unsupported family {model.cfg.family!r}"
            )
        if steps_per_sync < 1:
            raise ValueError("steps_per_sync must be >= 1")
        self.model = model
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.steps_per_sync = steps_per_sync
        self.queue = RequestQueue(max_len=max_len)

        self._mstate = model.init_decode_state(batch, max_len,
                                               per_row_pos=True)
        self._slots = init_slots(batch, max_len)
        # host mirror: which request occupies each row (None = free)
        self._slot_req: List[Optional[Request]] = [None] * batch
        self.outputs: Dict[int, np.ndarray] = {}
        self.steps = 0          # decode steps executed (all rows per step)
        self.generated = 0      # tokens returned to callers

        def _step_n(params, mstate, slots):
            def body(_, carry):
                ms, sl = carry
                return engine_step(model, params, ms, sl)
            return jax.lax.fori_loop(
                0, steps_per_sync, body, (mstate, slots)
            )

        def _admit(mstate, slots, new_tokens, new_plen, new_total, mask):
            mstate = model.reset_decode_rows(mstate, mask)
            return mstate, SlotState(
                tokens=jnp.where(mask[:, None], new_tokens, slots.tokens),
                prompt_len=jnp.where(mask, new_plen, slots.prompt_len),
                total_len=jnp.where(mask, new_total, slots.total_len),
                progress=jnp.where(mask, 0, slots.progress),
                active=slots.active | mask,
            )

        self._step_n = jax.jit(_step_n, donate_argnums=(1, 2))
        self._admit = jax.jit(_admit, donate_argnums=(0, 1))

    # -- request intake ------------------------------------------------------

    def submit(self, tokens, max_new_tokens: int) -> int:
        return self.queue.submit(tokens, max_new_tokens)

    def _refill(self) -> int:
        """Admit queued requests into free rows (one jitted masked write)."""
        free = [b for b, r in enumerate(self._slot_req) if r is None]
        n = min(len(free), len(self.queue))
        if n == 0:
            return 0
        new_tokens = np.zeros((self.batch, self.max_len), np.int32)
        new_plen = np.ones((self.batch,), np.int32)
        new_total = np.ones((self.batch,), np.int32)
        mask = np.zeros((self.batch,), bool)
        for b in free[:n]:
            req = self.queue.pop()
            self._slot_req[b] = req
            new_tokens[b, : req.prompt_len] = req.tokens
            new_plen[b] = req.prompt_len
            new_total[b] = req.total_len
            mask[b] = True
        self._mstate, self._slots = self._admit(
            self._mstate, self._slots,
            jnp.asarray(new_tokens), jnp.asarray(new_plen),
            jnp.asarray(new_total), jnp.asarray(mask),
        )
        return n

    # -- serving loop --------------------------------------------------------

    def step(self) -> int:
        """One sync cycle: refill, ``steps_per_sync`` fused decode steps,
        then a single host readback to harvest finished rows.  Returns the
        number of requests completed this cycle."""
        self._refill()
        if not any(r is not None for r in self._slot_req):
            return 0
        self._mstate, self._slots = self._step_n(
            self.params, self._mstate, self._slots
        )
        self.steps += self.steps_per_sync
        # the one host sync of the cycle
        active, tokens = jax.device_get(
            (self._slots.active, self._slots.tokens)
        )
        finished = 0
        for b, req in enumerate(self._slot_req):
            if req is None or active[b]:
                continue
            out = tokens[b, req.prompt_len : req.total_len].copy()
            self.outputs[req.req_id] = out
            self.generated += out.size
            self._slot_req[b] = None
            finished += 1
        return finished

    def run(self) -> Dict[int, np.ndarray]:
        """Serve until queue and slots drain; returns {req_id: generated}."""
        while self.queue or any(r is not None for r in self._slot_req):
            self.step()
        return self.outputs

    def stats(self) -> Dict[str, float]:
        return {
            "decode_steps": float(self.steps),
            "generated_tokens": float(self.generated),
            "batch": float(self.batch),
        }


def serve_all(
    model: Model,
    params,
    requests,
    *,
    batch: int,
    max_len: int,
    steps_per_sync: int = 8,
) -> Dict[int, np.ndarray]:
    """Convenience: submit ``[(tokens, max_new_tokens), ...]`` and drain.

    Returns outputs keyed by submission order (0..n-1)."""
    eng = ServingEngine(
        model, params, batch=batch, max_len=max_len,
        steps_per_sync=steps_per_sync,
    )
    for tokens, gen in requests:
        eng.submit(tokens, gen)
    return eng.run()
