"""Device-side continuous-batching serving engine.

The paper's §4.3 lesson is that a *partial* port pays for every boundary
crossing between the ported device domain and the host orchestrator.  The
previous serving loop was exactly that pathology: per-row Python decided
prompt-vs-generated feeding and completion with an ``int()`` host sync per
row per decode step.  Here the whole control state lives on-device:

  * ``SlotState`` — per-row token buffer, progress counters, and phase
    flags as fixed-shape device arrays (the prompt/generated distinction is
    a *comparison*, not a branch: generated tokens are written into the
    same buffer the prompt occupies, so feeding is one gather).
  * ``engine_step`` — one fused jit step: token selection, decode, greedy
    sampling, generated-token scatter, done-detection — all ``jnp`` ops.
    ``steps_per_sync`` steps run back-to-back inside one jit call, so
    there is (at most) one host sync per *batch of steps*.
  * slot refill — a jitted masked-write ``admit`` with fixed shapes: new
    requests enter free rows without retracing anything.
  * KV layout — ``layout="paged"`` swaps the dense per-row cache slab for
    a page pool + block table + device-side free list (contract in
    ``repro.serving.pager``).  Admission reserves pages (host arithmetic,
    no sync), decode allocates them lazily on first write, harvest
    releases them — so resident KV tracks live tokens, and the pool may be
    much smaller than ``batch x max_len``.  All of it is the same
    masked-write, fixed-shape discipline: nothing retraces.

Supported families: dense / moe / ssm / hybrid (everything whose decode
state supports per-row positions; VLM cross-caches would additionally need
a per-row vision prefill at admission).

MoE caveat: with capacity dropping (``capacity_factor`` below no-drop) a
row's output depends on which other rows share its decode batch — standard
MoE serving semantics, not an engine artifact.  Token-exact parity with
isolated decode holds when ``capacity_factor >= n_experts``.
"""
from __future__ import annotations

import time
from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serving.queue import Request, RequestQueue


class SlotState(NamedTuple):
    """Per-row serving control state — all device arrays, fixed shapes."""

    tokens: jax.Array      # (B, max_len) int32: prompt then generated tokens
    prompt_len: jax.Array  # (B,) int32
    total_len: jax.Array   # (B,) int32: prompt_len + max_new_tokens
    progress: jax.Array    # (B,) int32: tokens fed to the model so far
    active: jax.Array      # (B,) bool: row currently serving a request
    rng: jax.Array         # (B, 2) uint32: per-row PRNG key (sampling)


def init_slots(batch: int, max_len: int) -> SlotState:
    return SlotState(
        tokens=jnp.zeros((batch, max_len), jnp.int32),
        prompt_len=jnp.ones((batch,), jnp.int32),
        total_len=jnp.ones((batch,), jnp.int32),
        progress=jnp.zeros((batch,), jnp.int32),
        active=jnp.zeros((batch,), bool),
        rng=jnp.zeros((batch, 2), jnp.uint32),
    )


def _sample(logits, slots: SlotState, *, temperature: float, top_k: int):
    """Next-token choice + advanced per-row keys.

    ``temperature``/``top_k`` are trace-time constants (engine config), so
    the greedy path compiles to exactly the pre-sampling graph.  Each
    sampling row consumes a subkey and carries the successor, so the token
    stream of a row depends only on its admission-time key — refills and
    batch composition cannot perturb it.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), slots.rng
    keys = jax.vmap(jax.random.split)(slots.rng)      # (B, 2, 2)
    carry, sub = keys[:, 0], keys[:, 1]
    lg = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        kth = jax.lax.top_k(lg, top_k)[0][:, -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    nxt = jax.vmap(jax.random.categorical)(sub, lg).astype(jnp.int32)
    return nxt, carry


def engine_step(model: Model, params, mstate, slots: SlotState,
                *, temperature: float = 0.0, top_k: int = 0):
    """One decode step for every row — no host interaction.

    Feeding: row b feeds ``tokens[b, progress[b]]``; because generated
    tokens are scattered into the buffer as they are produced, this single
    gather covers both the prompt phase and the generate phase.
    A row is done after the step that produces its last generated token
    (``progress`` reaches ``total_len - 1``: position t's feed predicts
    position t+1, and positions ``prompt_len .. total_len-1`` are
    generated).  Inactive rows still occupy their lane (fixed shapes) but
    never advance, never write their caches, and — under the paged KV
    layout — never allocate pages (the ``active`` mask flows down through
    ``decode_step``).
    """
    b, max_len = slots.tokens.shape
    feed_idx = jnp.clip(slots.progress, 0, max_len - 1)
    tok = jnp.take_along_axis(slots.tokens, feed_idx[:, None], axis=1)[:, 0]
    logits, mstate = model.decode_step(params, mstate, tok,
                                       active=slots.active)
    nxt, rng = _sample(logits, slots, temperature=temperature, top_k=top_k)

    wpos = slots.progress + 1
    # scatter the sampled token where the next feed position is generated
    writes = slots.active & (wpos >= slots.prompt_len) & (wpos < max_len)
    col = jax.lax.broadcasted_iota(jnp.int32, (b, max_len), 1)
    tokens = jnp.where(
        writes[:, None] & (col == wpos[:, None]), nxt[:, None], slots.tokens
    )
    progress = slots.progress + slots.active.astype(jnp.int32)
    active = slots.active & (progress < slots.total_len - 1)
    return mstate, SlotState(
        tokens=tokens,
        prompt_len=slots.prompt_len,
        total_len=slots.total_len,
        progress=progress,
        active=active,
        rng=rng,
    )


class ServingEngine:
    """Fixed-shape continuous-batching engine over a ``Model``.

    >>> eng = ServingEngine(model, params, batch=4, max_len=64)
    >>> rid = eng.submit([3, 17, 5], max_new_tokens=16)
    >>> outs = eng.run()          # {rid: np.ndarray of generated tokens}

    ``layout="paged"`` swaps the KV cache for the page-pool representation
    (``repro.serving.pager``): admission reserves ``ceil((total_len-1)/
    page_size)`` pages per request (host-side accounting — no device sync),
    pages are *allocated* lazily as tokens are written, and a finished
    row's pages return to the pool at harvest, before its slot is even
    refilled.  Resident KV therefore scales with live tokens; ``n_pages``
    may be far below the contiguous ``batch * max_len / page_size``.

    ``temperature > 0`` enables on-device sampling (optionally top-k
    truncated); each admitted request gets its own PRNG key derived from
    the engine seed (host-side draw — the admission path stays sync-free),
    so outputs are reproducible per request regardless of batch
    composition.  The default (0) is greedy argmax, byte-identical to the
    pre-sampling engine.
    """

    def __init__(
        self,
        model: Model,
        params,
        *,
        batch: int,
        max_len: int,
        steps_per_sync: int = 8,
        layout: str = "contiguous",
        page_size: int = 16,
        n_pages: Optional[int] = None,
        temperature: float = 0.0,
        top_k: int = 0,
        seed: int = 0,
    ) -> None:
        if model.cfg.family not in ("dense", "moe", "ssm", "hybrid"):
            raise NotImplementedError(
                f"serving engine: unsupported family {model.cfg.family!r}"
            )
        if steps_per_sync < 1:
            raise ValueError("steps_per_sync must be >= 1")
        self.model = model
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.steps_per_sync = steps_per_sync
        self.layout = layout
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self.queue = RequestQueue(max_len=max_len)

        self._mstate = model.init_decode_state(
            batch, max_len, per_row_pos=True,
            layout=layout, page_size=page_size, n_pages=n_pages,
        )
        # attention-free families have no pages regardless of the flag
        self._paged = "block_table" in self._mstate
        self.page_size = page_size
        self.n_pages = (
            int(self._mstate["page_free"].shape[0]) if self._paged else 0
        )
        # host-side reservation ledger: worst-case pages per occupied row.
        # Guarantees alloc-on-write never finds the free list empty, so no
        # device sync is needed on the admission path.
        self._row_pages: List[int] = [0] * batch
        self._pages_reserved = 0
        self.peak_pages_in_use = 0

        self._slots = init_slots(batch, max_len)
        # per-request key *data* is drawn host-side (no device round-trip
        # on the admission path); rows feed it to jax.random as a raw
        # uint32 key only when sampling is on
        self._host_rng = np.random.Generator(np.random.Philox(seed))
        # host mirror: which request occupies each row (None = free)
        self._slot_req: List[Optional[Request]] = [None] * batch
        self.outputs: Dict[int, np.ndarray] = {}
        self.steps = 0          # decode steps executed (all rows per step)
        self.generated = 0      # tokens returned to callers

        def _step_n(params, mstate, slots):
            def body(_, carry):
                ms, sl = carry
                return engine_step(model, params, ms, sl,
                                   temperature=self.temperature,
                                   top_k=self.top_k)
            return jax.lax.fori_loop(
                0, steps_per_sync, body, (mstate, slots)
            )

        def _admit(mstate, slots, new_tokens, new_plen, new_total, new_rng,
                   mask):
            mstate = model.reset_decode_rows(mstate, mask)
            return mstate, SlotState(
                tokens=jnp.where(mask[:, None], new_tokens, slots.tokens),
                prompt_len=jnp.where(mask, new_plen, slots.prompt_len),
                total_len=jnp.where(mask, new_total, slots.total_len),
                progress=jnp.where(mask, 0, slots.progress),
                active=slots.active | mask,
                rng=jnp.where(mask[:, None], new_rng, slots.rng),
            )

        self._step_n = jax.jit(_step_n, donate_argnums=(1, 2))
        self._admit = jax.jit(_admit, donate_argnums=(0, 1))
        # harvest-time page release (and cache scrub) for finished rows
        self._release = jax.jit(model.reset_decode_rows, donate_argnums=(0,))

    # -- request intake ------------------------------------------------------

    def submit(self, tokens, max_new_tokens: int) -> int:
        if self._paged:
            need = self._pages_needed(len(tokens) + max_new_tokens)
            if need > self.n_pages:
                # reject now: the FIFO would otherwise starve behind a
                # request that can never reserve enough pages
                raise ValueError(
                    f"request needs {need} pages > pool size {self.n_pages}"
                )
        return self.queue.submit(tokens, max_new_tokens)

    def _pages_needed(self, total_len: int) -> int:
        from repro.serving.pager import pages_needed
        return pages_needed(total_len, self.page_size)

    def _refill(self) -> int:
        """Admit queued requests into free rows (one jitted masked write).

        Paged layout: a request is admitted only if its worst-case page
        count fits under the pool reservation; otherwise admission stops
        (FIFO — no reordering past a starving request).  Contiguous
        layout: slot availability alone gates admission, as before.
        """
        free = [b for b, r in enumerate(self._slot_req) if r is None]
        if not free or not self.queue:
            return 0
        new_tokens = np.zeros((self.batch, self.max_len), np.int32)
        new_plen = np.ones((self.batch,), np.int32)
        new_total = np.ones((self.batch,), np.int32)
        new_rng = np.zeros((self.batch, 2), np.uint32)
        mask = np.zeros((self.batch,), bool)
        n = 0
        for b in free:
            req = self.queue.peek()
            if req is None:
                break
            need = self._pages_needed(req.total_len) if self._paged else 0
            if self._paged and self._pages_reserved + need > self.n_pages:
                break
            self.queue.pop()
            self._slot_req[b] = req
            self._row_pages[b] = need
            self._pages_reserved += need
            new_tokens[b, : req.prompt_len] = req.tokens
            new_plen[b] = req.prompt_len
            new_total[b] = req.total_len
            new_rng[b] = self._host_rng.integers(
                0, 2 ** 32, size=2, dtype=np.uint32
            )
            mask[b] = True
            n += 1
        if n == 0:
            return 0
        self._mstate, self._slots = self._admit(
            self._mstate, self._slots,
            jnp.asarray(new_tokens), jnp.asarray(new_plen),
            jnp.asarray(new_total), jnp.asarray(new_rng),
            jnp.asarray(mask),
        )
        return n

    # -- serving loop --------------------------------------------------------

    def step(self) -> int:
        """One sync cycle: refill, ``steps_per_sync`` fused decode steps,
        then a single host readback to harvest finished rows.  Returns the
        number of requests completed this cycle."""
        self._refill()
        if not any(r is not None for r in self._slot_req):
            return 0
        self._mstate, self._slots = self._step_n(
            self.params, self._mstate, self._slots
        )
        self.steps += self.steps_per_sync
        # the one host sync of the cycle (page_top rides along — no extra)
        if self._paged:
            active, tokens, page_top = jax.device_get(
                (self._slots.active, self._slots.tokens,
                 self._mstate["page_top"])
            )
            self.peak_pages_in_use = max(
                self.peak_pages_in_use, self.n_pages - int(page_top)
            )
        else:
            active, tokens = jax.device_get(
                (self._slots.active, self._slots.tokens)
            )
        finished = 0
        release = np.zeros((self.batch,), bool)
        for b, req in enumerate(self._slot_req):
            if req is None or active[b]:
                continue
            out = tokens[b, req.prompt_len : req.total_len].copy()
            self.outputs[req.req_id] = out
            self.generated += out.size
            self._slot_req[b] = None
            self._pages_reserved -= self._row_pages[b]
            self._row_pages[b] = 0
            release[b] = True
            finished += 1
        if finished and self._paged:
            # free-on-completion: the finished rows' pages return to the
            # pool now, not when the slot happens to be refilled
            self._mstate = self._release(self._mstate, jnp.asarray(release))
        return finished

    def run(self) -> Dict[int, np.ndarray]:
        """Serve until queue and slots drain; returns {req_id: generated}."""
        while self.queue or any(r is not None for r in self._slot_req):
            self.step()
        return self.outputs

    def kv_bytes_per_page(self) -> int:
        """Bytes one page occupies across all layer slabs (K and V)."""
        if not self._paged:
            return 0
        kp = self._mstate["kp"]
        stacks, _, page, hkv, hd = kp.shape
        return 2 * kp.dtype.itemsize * stacks * page * hkv * hd

    def kv_resident_bytes(self, *, peak: bool = False) -> int:
        """Resident KV-cache footprint: allocated bytes under the paged
        layout (current or peak), the full slab under contiguous."""
        if self._paged:
            pages = (
                self.peak_pages_in_use if peak
                else self.n_pages - int(self._mstate["page_top"])
            )
            return pages * self.kv_bytes_per_page()
        total = 0
        for key in ("k", "v", "xk", "xv"):
            if key in self._mstate:
                arr = self._mstate[key]
                total += arr.dtype.itemsize * int(np.prod(arr.shape))
        return total

    def stats(self) -> Dict[str, float]:
        out = {
            "decode_steps": float(self.steps),
            "generated_tokens": float(self.generated),
            "batch": float(self.batch),
        }
        if self._paged:
            out["kv_pages"] = float(self.n_pages)
            out["kv_pages_peak"] = float(self.peak_pages_in_use)
            out["kv_resident_bytes_peak"] = float(
                self.kv_resident_bytes(peak=True)
            )
        return out


def serve_all(
    model: Model,
    params,
    requests,
    *,
    batch: int,
    max_len: int,
    steps_per_sync: int = 8,
    **engine_kwargs,
) -> Dict[int, np.ndarray]:
    """Convenience: submit ``[(tokens, max_new_tokens), ...]`` and drain.

    Returns outputs keyed by submission order (0..n-1)."""
    eng = ServingEngine(
        model, params, batch=batch, max_len=max_len,
        steps_per_sync=steps_per_sync, **engine_kwargs,
    )
    for tokens, gen in requests:
        eng.submit(tokens, gen)
    return eng.run()
