"""Device-side continuous-batching serving engine.

The paper's §4.3 lesson is that a *partial* port pays for every boundary
crossing between the ported device domain and the host orchestrator.  The
previous serving loop was exactly that pathology: per-row Python decided
prompt-vs-generated feeding and completion with an ``int()`` host sync per
row per decode step.  Here the whole control state lives on-device:

  * ``SlotState`` — per-row token buffer, progress counters, and phase
    flags as fixed-shape device arrays (the prompt/generated distinction is
    a *comparison*, not a branch: generated tokens are written into the
    same buffer the prompt occupies, so feeding is one gather).
  * ``engine_step`` — one fused jit step: token selection, decode, greedy
    sampling, generated-token scatter, done-detection — all ``jnp`` ops.
    ``steps_per_sync`` steps run back-to-back inside one jit call, so
    there is (at most) one host sync per *batch of steps*.
  * slot refill — a jitted masked-write ``admit`` with fixed shapes: new
    requests enter free rows without retracing anything.
  * KV layout — ``layout="paged"`` swaps the dense per-row cache slab for
    a page pool + block table + device-side free list (contract in
    ``repro.serving.pager``).  Admission reserves pages (host arithmetic,
    no sync), decode allocates them lazily on first write, harvest
    releases them — so resident KV tracks live tokens, and the pool may be
    much smaller than ``batch x max_len``.  All of it is the same
    masked-write, fixed-shape discipline: nothing retraces.
  * chunked prefill — ``prefill_chunk=C`` ingests up to C prompt tokens
    per step (``model.prefill_chunk`` + ``ops.attention_prefill_chunk``),
    so TTFT stops scaling linearly in prompt length.  The scheduler is a
    *host mirror*: per-row progress is a deterministic function of
    (prompt_len, total_len, steps run), so choosing prefill-vs-decode
    steps, chunk widths, TTFT stamps and ingestion counts never needs a
    device sync.  Decode-phase rows ride along in prefill steps with
    width 1; both jitted entry points stay at cache size 1.
  * prefix sharing — ``prefix_sharing=True`` (paged layout only) keeps a
    host-side index of page-aligned prompt chunks (chained hashes, exact
    token verification — deterministic, no device sync).  Admission maps
    a matching resident row's prompt pages into the new row's block
    table by *donor slot id* (``pager.share_prefix`` bumps refcounts on
    device; the host never needs physical page ids) and starts chunked
    prefill at the first unshared token.  Writes into a still-shared
    page copy-on-write to a private page inside the jitted step
    (``pager.cow_on_write``), so ``_admit``/``_prefill``/``_step_n`` all
    stay at jit cache size 1 and outputs are token-identical to the
    no-sharing engine.
  * recurrent-state snapshots — sharing is first-class for the recurrent
    families too (ssm, and the hybrid family's Mamba blocks): their
    decode state holds a page-boundary snapshot store (per-(row,
    boundary) SSM+conv slots, same refcounted allocator as KV pages —
    ``repro.serving.pager`` documents the contract).  Steps that end
    exactly at a boundary capture the post-step state (the engine clips
    prefill chunk widths so every boundary is an endpoint); admission of
    a matching prompt restores the donor's boundary state
    (``lm.restore_snapshots``) and resumes prefill at the first unshared
    token — the recurrence is *restored*, never skipped.  Shared depth is
    capped below the prompt's last token so the resume point is always a
    snapshotted boundary (recurrent sharing therefore never needs CoW).
  * speculative decoding — ``config.spec`` (a ``SpecConfig``) turns the
    decode phase into draft-and-verify: a drafter
    (``repro.serving.drafter`` — n-gram prompt lookup, or the hybrid
    family's own Mamba layers) proposes K tokens per row, and one
    ``model.prefill_chunk`` call at width K+1 scores every slot
    (``logits_all=True``) against the paged cache.  Each row keeps its
    leading run of drafts that match the verifier's own argmax and
    advances by the per-row accepted length — the same non-dividing-
    width masking chunked prefill already uses — so acceptance is
    greedy and *token-identical to plain decode by construction* (every
    emitted token is the verifier's argmax).  Rejected suffixes roll
    back: attention families rewind ``pos`` and release tail pages
    (``pager.release_tail``); recurrent families score on a discarded
    state and re-advance the original by the exact accepted width
    (nothing to roll back).  All of it lives in one jitted ``_spec_n``
    at cache size 1, with a device-side accept counter riding the
    harvest sync.
  * pressure — the engine survives a pool smaller than its working set:
    when the queue head cannot reserve pages, the host-mirror scheduler
    preempts victim rows (lowest priority, then least progress),
    spilling their pages — and, for recurrent families, their snapshot
    slots — to a host-side tier through a jitted ``_spill`` (two-tier
    contract in ``repro.serving.pager``) and restoring them when pages
    free up.  ``cancel()`` and per-request deadlines drain rows through
    the same jitted release path at the next harvest, and
    ``repro.serving.faults.FaultPlan`` scripts deterministic pressure
    (pool exhaustion, cancels, deadline storms, poisoned rows) against
    the harvest-cycle clock for the CI harness.

Supported families: dense / moe / ssm / hybrid (everything whose decode
state supports per-row positions; VLM cross-caches would additionally need
a per-row vision prefill at admission).

MoE caveat: with capacity dropping (``capacity_factor`` below no-drop) a
row's output depends on which other rows share its decode batch — standard
MoE serving semantics, not an engine artifact.  Token-exact parity with
isolated decode holds when ``capacity_factor >= n_experts``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, NamedTuple, Optional, Set

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serving.config import (
    CacheConfig, EngineConfig, from_kwargs, validate_configs,
)
from repro.serving.drafter import make_drafter
from repro.serving.faults import FaultPlan
from repro.serving.queue import Request, RequestQueue


class SlotState(NamedTuple):
    """Per-row serving control state — all device arrays, fixed shapes."""

    tokens: jax.Array      # (B, max_len) int32: prompt then generated tokens
    prompt_len: jax.Array  # (B,) int32
    total_len: jax.Array   # (B,) int32: prompt_len + max_new_tokens
    progress: jax.Array    # (B,) int32: tokens fed to the model so far
    active: jax.Array      # (B,) bool: row currently serving a request
    rng: jax.Array         # (B, 2) uint32: per-row PRNG key (sampling)


def init_slots(batch: int, max_len: int) -> SlotState:
    return SlotState(
        tokens=jnp.zeros((batch, max_len), jnp.int32),
        prompt_len=jnp.ones((batch,), jnp.int32),
        total_len=jnp.ones((batch,), jnp.int32),
        progress=jnp.zeros((batch,), jnp.int32),
        active=jnp.zeros((batch,), bool),
        rng=jnp.zeros((batch, 2), jnp.uint32),
    )


def _sample(logits, slots: SlotState, wpos, *, temperature: float,
            top_k: int):
    """Next-token choice.

    ``temperature``/``top_k`` are trace-time constants (engine config), so
    the greedy path compiles to exactly the pre-sampling graph.  Each
    sampled token's subkey is ``fold_in(admission key, position)`` —
    ``wpos`` is where the token lands — so a row's token stream depends
    only on its admission-time key and the positions themselves: refills,
    batch composition, ``steps_per_sync`` and the prefill chunk schedule
    (which changes how many *steps* reach a given position) cannot
    perturb it.  The key is never consumed, so ``slots.rng`` is carried
    unchanged.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    sub = jax.vmap(jax.random.fold_in)(slots.rng, wpos)
    lg = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        kth = jax.lax.top_k(lg, top_k)[0][:, -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    return jax.vmap(jax.random.categorical)(sub, lg).astype(jnp.int32)


def engine_step(model: Model, params, mstate, slots: SlotState,
                *, temperature: float = 0.0, top_k: int = 0,
                chunk: int = 1, cow: bool = False, snap_every: int = 0):
    """One decode (or chunked-prefill) step for every row — no host
    interaction.

    ``chunk == 1`` (the decode step): row b feeds ``tokens[b,
    progress[b]]``; because generated tokens are scattered into the buffer
    as they are produced, this single gather covers both the prompt phase
    and the generate phase.  A row is done after the step that produces
    its last generated token (``progress`` reaches ``total_len - 1``:
    position t's feed predicts position t+1, and positions ``prompt_len ..
    total_len-1`` are generated).  Inactive rows still occupy their lane
    (fixed shapes) but never advance, never write their caches, and —
    under the paged KV layout — never allocate pages (the ``active`` mask
    flows down through ``decode_step``).

    ``chunk > 1`` (the prefill step): prompt-phase rows feed up to
    ``chunk`` prompt tokens at once through ``model.prefill_chunk`` —
    per-row width ``clip(prompt_len - progress, 1, chunk)``, so the chunk
    never crosses into generated positions and the *last* prefill chunk
    ends exactly at ``prompt_len - 1``, whose logits produce the first
    generated token.  Decode-phase rows ride along with width 1 (their
    gather covers the generated buffer), so a mixed batch needs no second
    dispatch point.  Everything else — sampling, token scatter,
    done-detection — is the same arithmetic with a per-row stride.

    ``snap_every`` (trace-time constant; recurrent snapshot engines) does
    two things: chunk widths are additionally clipped so no chunk crosses
    a page boundary without *ending* on it (every boundary becomes a step
    endpoint, so every boundary state gets captured — the availability
    invariant the host-side prefix index relies on), and the model steps
    capture/carry the snapshot store.  The host mirror in ``step()``
    replays the same clip.
    """
    b, max_len = slots.tokens.shape
    if chunk > 1:
        limit = jnp.full((b,), chunk, jnp.int32)
        if snap_every:
            limit = jnp.minimum(
                limit, snap_every - slots.progress % snap_every
            )
        width = jnp.clip(slots.prompt_len - slots.progress, 1, limit)
        gidx = jnp.clip(
            slots.progress[:, None]
            + jnp.arange(chunk, dtype=jnp.int32)[None, :],
            0, max_len - 1,
        )
        toks = jnp.take_along_axis(slots.tokens, gidx, axis=1)
        logits, mstate = model.prefill_chunk(params, mstate, toks, width,
                                             active=slots.active, cow=cow,
                                             snap_every=snap_every)
        stride = width
    else:
        feed_idx = jnp.clip(slots.progress, 0, max_len - 1)
        tok = jnp.take_along_axis(
            slots.tokens, feed_idx[:, None], axis=1
        )[:, 0]
        logits, mstate = model.decode_step(params, mstate, tok,
                                           active=slots.active, cow=cow,
                                           snap_every=snap_every)
        stride = jnp.ones((b,), jnp.int32)

    wpos = slots.progress + stride
    nxt = _sample(logits, slots, wpos, temperature=temperature, top_k=top_k)
    # scatter the sampled token where the next feed position is generated
    writes = slots.active & (wpos >= slots.prompt_len) & (wpos < max_len)
    col = jax.lax.broadcasted_iota(jnp.int32, (b, max_len), 1)
    tokens = jnp.where(
        writes[:, None] & (col == wpos[:, None]), nxt[:, None], slots.tokens
    )
    progress = slots.progress + stride * slots.active.astype(jnp.int32)
    active = slots.active & (progress < slots.total_len - 1)
    return mstate, SlotState(
        tokens=tokens,
        prompt_len=slots.prompt_len,
        total_len=slots.total_len,
        progress=progress,
        active=active,
        rng=slots.rng,
    )


class RequestHandle(int):
    """What ``submit`` returns: the request id, plus the request-scoped
    surface (``handle.cancel()``, ``handle.rid``).

    Subclasses ``int`` so every pre-handle idiom keeps working — handles
    index ``outputs``/``ttft`` dicts, format into messages, and compare
    equal to the raw id.  The engine reference only powers the
    convenience methods; the id alone remains a full citizen everywhere
    the engine API takes one.
    """

    def __new__(cls, rid: int, engine: "ServingEngine"):
        self = super().__new__(cls, rid)
        self._engine = engine
        return self

    @property
    def rid(self) -> int:
        """The request id as a plain ``int``."""
        return int(self)

    def cancel(self) -> bool:
        """Cancel this request (``ServingEngine.cancel`` semantics)."""
        return self._engine.cancel(self.rid)


class ServingEngine:
    """Fixed-shape continuous-batching engine over a ``Model``.

    >>> eng = ServingEngine(model, params, batch=4, max_len=64,
    ...                     cache=CacheConfig(layout="paged"),
    ...                     config=EngineConfig(prefill_chunk=8))
    >>> h = eng.submit([3, 17, 5], max_new_tokens=16)   # RequestHandle
    >>> outs = eng.run()          # {rid: np.ndarray of generated tokens}

    Configuration is two frozen objects (``repro.serving.config``):
    ``cache=CacheConfig(...)`` shapes the decode state (KV layout, page
    pool, snapshot store, host spill tier) and ``config=EngineConfig(...)``
    drives the loop (scheduling, sampling, speculation).  The raw kwargs
    of earlier revisions (``layout=``, ``page_size=``, ``prefill_chunk=``,
    …) still work through one adapter — ``config.from_kwargs`` — which
    emits a ``DeprecationWarning`` per call site; mixing both styles is a
    ``TypeError``.  All validation messages are unchanged from the
    kwarg era (they moved into the config constructors and
    ``validate_configs``).

    ``config.spec=SpecConfig(k=K, drafter=...)`` enables speculative
    decoding (greedy-only; requires ``prefill_chunk >= 2`` and
    ``temperature == 0``): each fused decode interval drafts K tokens
    per row and verifies them through the chunked-prefill path in one
    jitted step, advancing every row by its accepted length.  Outputs
    are token-identical to plain greedy decode (module docstring has the
    argument); ``stats()`` gains ``spec_proposed`` / ``spec_accepted`` /
    ``spec_emitted`` / ``spec_accept_rate``.  ``drafter="prompt_lookup"``
    works for every supported family; ``drafter="hybrid_ssm"`` (the
    hybrid family's Mamba layers as a weight-shared draft model) needs
    ``family == "hybrid"`` and is incompatible with ``prefix_sharing``
    (snapshot restore rebuilds the model's recurrence, not the drafter's
    private state).

    ``layout="paged"`` swaps the KV cache for the page-pool representation
    (``repro.serving.pager``): admission reserves ``ceil((total_len-1)/
    page_size)`` pages per request (host-side accounting — no device sync),
    pages are *allocated* lazily as tokens are written, and a finished
    row's pages return to the pool at harvest, before its slot is even
    refilled.  Resident KV therefore scales with live tokens; ``n_pages``
    may be far below the contiguous ``batch * max_len / page_size``.

    ``temperature > 0`` enables on-device sampling (optionally top-k
    truncated); each admitted request gets its own PRNG key derived from
    the engine seed (host-side draw — the admission path stays sync-free),
    so outputs are reproducible per request regardless of batch
    composition.  The default (0) is greedy argmax, byte-identical to the
    pre-sampling engine.

    ``prefill_chunk=C`` (default 1 = token-by-token) turns prompt
    ingestion into chunked multi-token steps: a row with R prompt tokens
    left feeds ``min(C, R)`` of them in one fused step, so a P-token
    prompt costs ``ceil(P/C)`` steps instead of P.  Outputs are
    token-identical to the unchunked path; per-request ``ttft`` (seconds
    to first generated token, stamped at the harvest sync) and
    ``prompt_tokens`` are tracked either way.  MoE caveat: with capacity
    dropping, chunked steps route B*C tokens where decode routes B, so
    drops — and therefore tokens — can differ from the unchunked path;
    parity holds at ``capacity_factor >= n_experts`` (see module
    docstring).  Sliding-window archs need ``layout="paged"`` for
    chunking (absolute positions; the contiguous ring recycles slots the
    chunk still reads).

    ``prefix_sharing=True`` (paged layout only): a new request whose
    prompt starts with page-aligned chunks already written by a resident
    row maps that row's pages instead of recomputing them — prefill
    starts at the first unshared token, the shared pages' refcounts keep
    them alive past the donor's completion, and the one write that can
    land in a shared page (the re-fed last prompt token of a fully
    shared prompt) copies-on-write to a private page.  Outputs are
    token-identical to the no-sharing engine; what changes is TTFT and
    resident KV bytes (shared pages are resident once, not per row).
    Recurrent decode state (ssm, and the hybrid family's Mamba blocks)
    shares through the page-boundary snapshot store: admission restores
    the donor's captured SSM/conv state at the last shared boundary
    instead of re-running the recurrence, with shared depth capped below
    the prompt's final token so the resume point is always a snapshotted
    boundary (recurrent sharing never CoWs; see the module docstring).
    Snapshot engines clip prefill chunk widths to end at page
    boundaries, so every boundary state is captured as it is first
    reached.  MoE caveat as for chunked prefill: sharing changes which
    tokens batch into a routing step, so parity needs
    ``capacity_factor >= n_experts``.  Admission reserves the worst-case
    page count *without* subtracting shared pages (plus the one CoW
    spare for attention families): a donor may finish first, leaving the
    sharer sole holder, so the conservative ledger is what keeps
    alloc-on-write sync-free and never dry.  The snapshot-slot pool is
    sized to the same worst case at construction (every row can
    snapshot every boundary it can reach), so it needs no ledger at all.

    Scheduler contract (preemption / deadlines / cancellation).
    Admission orders the queue by (priority desc, deadline budget asc,
    arrival asc).  When the head cannot reserve its worst-case pages
    under the ledger, the scheduler spills victims — resident rows of
    *strictly lower* priority, lowest priority first, then least
    progress (least work lost), then oldest — through the jitted
    ``_spill``: a spill moves the row's KV pages (and snapshot slots)
    to the host tier, keeps its ``SlotState`` lane and live recurrent
    state in place, and returns its reservation to the pool.  Victims
    are committed only if they actually admit the head (no thrashing
    spills).  Spilled rows restore (highest priority, then oldest,
    first) as soon as their worst-case reservation fits again — the
    reservation gate is what guarantees the jitted restore's device
    pops never find the free list dry — deferring to a strictly-
    higher-priority queue head that could itself fit.  ``cancel(
    req_id)`` and deadline expiry (absolute time ``submit +
    deadline_ms``, measured against ``time.perf_counter`` — the
    monotonic host clock — at every harvest sync and every
    queued-request sweep) take effect at the next harvest: still-queued
    requests leave the queue immediately; resident, mid-prefill, and
    spilled rows drain through the jitted release path, surrendering
    pages and slots in every tier with no payload recorded.
    ``prefill_budget`` bounds chunked-prefill steps per cycle so a
    long prompt cannot monopolize a harvest interval (TTFT
    interference control); leftover prompt tokens continue next cycle
    or token-by-token inside the fused decode call.  A ``FaultPlan``
    (``fault_plan=`` or ``set_fault_plan``) scripts pool exhaustion,
    cancels, deadline storms, and poisoned rows against the
    harvest-cycle clock — injections ride the normal scheduler paths
    above, never a parallel code path.
    """

    def __init__(
        self,
        model: Model,
        params,
        *,
        batch: int,
        max_len: int,
        cache: Optional[CacheConfig] = None,
        config: Optional[EngineConfig] = None,
        fault_plan: Optional[FaultPlan] = None,
        **legacy,
    ) -> None:
        if legacy:
            if cache is not None or config is not None:
                raise TypeError(
                    "pass cache=CacheConfig(...) / config=EngineConfig(...) "
                    "or the legacy kwargs, not both"
                )
            # one adapter owns the kwarg->config translation; the
            # stacklevel points the DeprecationWarning at the caller's
            # construction site, not this frame
            cache, config = from_kwargs(_stacklevel=3, **legacy)
        cache = cache if cache is not None else CacheConfig()
        config = config if config is not None else EngineConfig()
        validate_configs(cache, config)
        if model.cfg.family not in ("dense", "moe", "ssm", "hybrid"):
            raise NotImplementedError(
                f"serving engine: unsupported family {model.cfg.family!r}"
            )
        if (config.prefill_chunk > 1 and model.cfg.window
                and cache.layout != "paged"
                and model.cfg.family in ("dense", "moe", "hybrid")):
            raise ValueError(
                "chunked prefill on a sliding-window arch needs "
                "layout='paged' (the contiguous ring cache recycles slots "
                "the in-chunk queries still read)"
            )
        steps_per_sync = config.steps_per_sync
        prefill_chunk = config.prefill_chunk
        page_size = cache.page_size
        self.model = model
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.cache = cache
        self.config = config
        self.spec = config.spec
        # flat attribute surface preserved from the kwarg era (tests and
        # benchmark drivers read these)
        self.steps_per_sync = steps_per_sync
        self.layout = cache.layout
        self.prefill_chunk = prefill_chunk
        self.prefix_sharing = config.prefix_sharing
        self.temperature = config.temperature
        self.top_k = config.top_k
        self.prefill_budget = config.prefill_budget
        self.queue = RequestQueue(max_len=max_len)

        host_spill = cache.host_spill
        if host_spill is None:
            # preemption only makes sense where there are pages to spill
            host_spill = cache.layout == "paged"
        # the engine's own construction dogfoods the typed config: the
        # snapshot store exists when asked for explicitly or implied by
        # prefix sharing on a recurrent family
        self._mstate = model.init_decode_state(
            batch, max_len, per_row_pos=True,
            cache=dataclasses.replace(
                cache,
                snapshots=cache.snapshots or config.prefix_sharing,
                host_spill=host_spill,
            ),
        )
        # speculative decoding: build the drafter before the jitted
        # closures (the prefill closure ingests for a stateful drafter);
        # its private recurrent state merges into the decode-state dict
        # so reset/spill/restore/donation treat it as lane state
        self._drafter = None
        if self.spec is not None:
            self._drafter = make_drafter(self.spec, model.cfg)
            if self._drafter.stateful:
                self._mstate = {
                    **self._mstate,
                    **self._drafter.init_state(batch),
                }
        # attention-free families have no pages regardless of the flag
        self._paged = "block_table" in self._mstate
        # recurrent families carry a page-boundary snapshot store exactly
        # when sharing is on (lm.init_decode_state adds it)
        self._snap = "snap_table" in self._mstate
        self._recurrent = model.cfg.family in ("ssm", "hybrid")
        self.page_size = page_size
        self.n_pages = (
            int(self._mstate["page_free"].shape[0]) if self._paged else 0
        )
        self.n_snap_slots = (
            int(self._mstate["snap_free"].shape[0]) if self._snap else 0
        )
        # host-side reservation ledger: worst-case pages per occupied row.
        # Guarantees alloc-on-write never finds the free list empty, so no
        # device sync is needed on the admission path.
        self._row_pages: List[int] = [0] * batch
        self._pages_reserved = 0
        self.peak_pages_in_use = 0
        self.peak_snaps_in_use = 0
        # two-tier pager (preemption): present exactly when the state has
        # a host tier (paged layout + a family with KV pages)
        self._spillable = "host_table" in self._mstate
        # spill mirrors: a spilled row keeps its SlotState lane (tokens,
        # progress, live recurrent state) — only pool residency and the
        # reservation move; _spill_need remembers the worst-case pages to
        # re-reserve at restore
        self._row_spilled: List[bool] = [False] * batch
        self._spill_need: List[int] = [0] * batch
        self.preemptions = 0
        self.restores = 0
        # every family shares: dense/moe through aliased KV pages, ssm
        # through restored state snapshots, hybrid through both
        self._share_eligible = self.prefix_sharing and (
            self._paged or self._snap
        )
        # host-side prefix index: chained chunk hash -> (slot, epoch).
        # Epochs invalidate entries when their slot's request is released;
        # matches are verified token-exact against the donor's prompt, so
        # a hash collision can never map the wrong pages.
        self._prefix_index: Dict[int, tuple] = {}
        self._slot_epoch: List[int] = [0] * batch
        self._slot_hashes: List[List[int]] = [[] for _ in range(batch)]
        self.shared_prompt_tokens = 0   # prompt tokens skipped via sharing
        self.cow_pages = 0              # CoW copies (host-predicted)

        # KV byte arithmetic is shape-only — freeze it here instead of
        # re-walking the state pytree on every stats()/resident-bytes call
        if self._paged:
            kp = self._mstate["kp"]
            stacks, _, page, hkv, hd = kp.shape
            self._kv_bytes_per_page = (
                2 * kp.dtype.itemsize * stacks * page * hkv * hd
            )
            self._contig_kv_bytes = 0
        else:
            self._kv_bytes_per_page = 0
            self._contig_kv_bytes = sum(
                self._mstate[key].dtype.itemsize
                * int(np.prod(self._mstate[key].shape))
                for key in ("k", "v", "xk", "xv") if key in self._mstate
            )

        self._slots = init_slots(batch, max_len)
        # per-request key *data* is derived host-side from (engine seed,
        # req_id) — no device round-trip on the admission path, and the
        # stream is a pure function of the request's identity, so
        # admission *order* (which priorities and preemption reshuffle)
        # cannot perturb any row's tokens
        self._seed = int(config.seed)
        # host mirror: which request occupies each row (None = free)
        self._slot_req: List[Optional[Request]] = [None] * batch
        # host mirror of per-row progress: the step schedule (chunk widths,
        # prompt-vs-decode phase) is a deterministic function of
        # (prompt_len, total_len, steps run), so the prefill scheduler and
        # the TTFT/ingestion accounting never need a device sync
        self._row_progress: List[int] = [0] * batch
        self.outputs: Dict[int, np.ndarray] = {}
        self.steps = 0          # decode steps executed (all rows per step)
        self.prefill_steps = 0  # chunked-prefill steps executed
        self.generated = 0      # tokens returned to callers
        self.prompt_tokens = 0  # prompt tokens ingested (host arithmetic)
        # speculation counters: host mirrors of the device accumulator
        # (refreshed at each harvest sync — never a dedicated round-trip)
        self.spec_proposed = 0  # verifiable draft positions scored
        self.spec_accepted = 0  # drafts that matched the verifier argmax
        self.spec_emitted = 0   # tokens emitted by spec steps (incl. bonus)
        self.ttft: Dict[int, float] = {}        # req_id -> seconds
        self._t_submit: Dict[int, float] = {}
        # SLO / cancellation ledgers (host mirror; enforcement happens at
        # refill for queued requests and at harvest for resident rows)
        self._deadline: Dict[int, float] = {}   # req_id -> absolute expiry
        self._cancel_req: Set[int] = set()      # resident, pending drain
        self._poison_req: Set[int] = set()
        self.cancelled: Set[int] = set()        # records (never completed)
        self.expired: Set[int] = set()
        self.poisoned: Set[int] = set()
        # fault-injection harness: harvest-cycle clock + hostage pages
        self.fault_plan = fault_plan
        self._cycle = 0
        self._fault_hold_pages = 0

        # the CoW pass only exists in traces that can ever share a page
        # (static per engine): non-sharing paged engines keep the plain
        # allocator's decode trace.  Recurrent sharing never writes into
        # a shared page (resume points sit on unshared boundaries), so
        # snapshot-only (ssm) engines skip the CoW pass too.
        cow = self._share_eligible and self._paged
        # snapshot capture + boundary-aligned chunk clipping only exist in
        # traces that own a snapshot store (static per engine)
        snap_every = page_size if self._snap else 0
        self._snap_every = snap_every

        def _step_n(params, mstate, slots, run):
            # ``run`` freezes rows for this fused call without touching
            # their lanes: a ``prefill_budget`` stop leaves rows mid-
            # prompt, and advancing them token-by-token here would shift
            # their remaining chunk boundaries off the unpressured
            # schedule (chunk partitioning changes reduction order, so
            # logits — and near-tie argmaxes — would drift).  Frozen rows
            # resume chunked prefill next cycle on the exact baseline
            # widths; ``run`` is data, so the trace stays at cache size 1.
            def body(_, carry):
                ms, sl = carry
                return engine_step(model, params, ms, sl,
                                   temperature=self.temperature,
                                   top_k=self.top_k, cow=cow,
                                   snap_every=snap_every)
            frozen = slots.active & ~run
            mstate, out = jax.lax.fori_loop(
                0, steps_per_sync, body,
                (mstate, slots._replace(active=slots.active & run)),
            )
            return mstate, out._replace(active=out.active | frozen)

        paged = self._paged
        snap = self._snap

        def _admit(mstate, slots, new_tokens, new_plen, new_total, new_rng,
                   mask, new_start, share_src, share_nblk):
            # release the rows' old pages, zero their recurrent state, and
            # place their decode clock at the first unshared token
            mstate = model.reset_decode_rows(mstate, mask, start=new_start)
            if paged:
                # map the donor rows' shared prompt pages (refcount bump);
                # share_nblk == 0 everywhere makes this the plain
                # admission trace — same jit cache entry either way
                from repro.serving import pager as PG

                pstate, bt = PG.share_prefix(
                    PG.PagerState(mstate["page_free"], mstate["page_top"],
                                  mstate["page_rc"]),
                    mstate["block_table"], share_src, share_nblk, mask,
                )
                mstate = {**mstate, "block_table": bt,
                          "page_free": pstate.free, "page_top": pstate.top,
                          "page_rc": pstate.rc}
            if snap:
                # recurrent families: map the donor's snapshot slots and
                # load its state at the last shared boundary, so prefill
                # resumes there with the recurrence already advanced
                mstate = model.restore_snapshots(
                    mstate, mask, share_src, share_nblk
                )
            return mstate, SlotState(
                tokens=jnp.where(mask[:, None], new_tokens, slots.tokens),
                prompt_len=jnp.where(mask, new_plen, slots.prompt_len),
                total_len=jnp.where(mask, new_total, slots.total_len),
                progress=jnp.where(mask, new_start, slots.progress),
                active=slots.active | mask,
                rng=jnp.where(mask[:, None], new_rng, slots.rng),
            )

        def _release(mstate, slots, mask):
            # harvest drain: scrub the rows' caches and release their
            # pages/slots in *every* tier (device, host, snapshots), and
            # deactivate the lanes — a cancelled or expired row may still
            # be device-active; a finished one already is not
            return model.reset_decode_rows(mstate, mask), slots._replace(
                active=slots.active & ~mask
            )

        self._step_n = jax.jit(_step_n, donate_argnums=(1, 2))
        self._admit = jax.jit(_admit, donate_argnums=(0, 1))
        self._release = jax.jit(_release, donate_argnums=(0, 1))

        if self._spillable:
            # preemption data plane: pool residency moves tiers; the row's
            # SlotState lane and live recurrent state stay put (a spilled
            # row is just an inactive lane to the decode step, which is
            # why ``decode_step`` masks recurrent-state writes by
            # ``active`` — see ``mamba_decode_block``'s ``valid``)
            def _spill(mstate, slots, mask):
                return model.spill_rows(mstate, mask), slots._replace(
                    active=slots.active & ~mask
                )

            def _restore(mstate, slots, mask):
                return model.restore_rows(mstate, mask), slots._replace(
                    active=slots.active | mask
                )

            self._spill = jax.jit(_spill, donate_argnums=(0, 1))
            self._restore = jax.jit(_restore, donate_argnums=(0, 1))
        else:
            self._spill = None
            self._restore = None

        drafter = self._drafter
        if prefill_chunk > 1:
            def _prefill_step(params, mstate, slots):
                mstate, out = engine_step(model, params, mstate, slots,
                                          temperature=self.temperature,
                                          top_k=self.top_k,
                                          chunk=prefill_chunk,
                                          cow=cow, snap_every=snap_every)
                if drafter is not None and drafter.stateful:
                    # keep the drafter's ingestion clock within one chunk
                    # of the rows it will draft for: decode-phase rows
                    # ride prefill steps at width 1 while ingestion
                    # absorbs up to ``prefill_chunk`` committed tokens,
                    # so the lag entering ``_spec_n`` is bounded by the
                    # last spec stride (<= K+1, the catch-up chunk there)
                    mstate = drafter.ingest(params, mstate, out.tokens,
                                            out.progress, prefill_chunk)
                return mstate, out
            self._prefill = jax.jit(_prefill_step, donate_argnums=(1, 2))
        else:
            self._prefill = None

        if self.spec is not None:
            spec_k = self.spec.k
            recurrent = self._recurrent

            def _spec_step(params, mstate, slots):
                bsz, buf_len = slots.tokens.shape
                act = slots.active
                prog = slots.progress
                drafts, mstate = drafter.propose(
                    params, mstate, slots.tokens, prog, act
                )
                # verify chunk: the current feed token plus the K drafts
                cur = jnp.take_along_axis(
                    slots.tokens,
                    jnp.clip(prog, 0, buf_len - 1)[:, None], axis=1,
                )
                chunk = jnp.concatenate([cur, drafts], axis=1)
                # per-row verify width: never past the row's last token,
                # never across a snapshot boundary without ending on it
                limit = jnp.full((bsz,), spec_k + 1, jnp.int32)
                if snap_every:
                    limit = jnp.minimum(
                        limit, snap_every - prog % snap_every
                    )
                w = jnp.clip(slots.total_len - 1 - prog, 1, limit)
                if recurrent:
                    # scored pass on a *discarded* state: the recurrence
                    # cannot roll back a rejected suffix, so the commit
                    # is a second, exact-width pass on the original
                    # state below (its page allocations are discarded
                    # with it — the pager arrays are functional)
                    logits, _ = model.prefill_chunk(
                        params, mstate, chunk, w, active=act,
                        cow=False, snap_every=0, logits_all=True,
                    )
                else:
                    logits, ms2 = model.prefill_chunk(
                        params, mstate, chunk, w, active=act,
                        cow=cow, snap_every=snap_every, logits_all=True,
                    )
                # greedy acceptance: keep the leading run of drafts that
                # equal the verifier's own argmax — in-chunk causality
                # makes slot j's logits exact whenever slots 0..j hold
                # true tokens, so induction gives token-identity with
                # plain greedy decode
                g = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                ok = (g[:, :spec_k] == chunk[:, 1:]) & (
                    jnp.arange(spec_k, dtype=jnp.int32)[None, :]
                    < (w - 1)[:, None]
                )
                acc_n = jnp.sum(
                    jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1
                )
                stride = jnp.where(act, acc_n + 1, 0)
                if recurrent:
                    _, mstate = model.prefill_chunk(
                        params, mstate, chunk,
                        jnp.maximum(stride, 1), active=act,
                        cow=cow, snap_every=snap_every,
                    )
                else:
                    # attention caches need no second pass: garbage KV
                    # beyond the accepted frontier is never attended
                    # (causal masking by position) — rewind ``pos`` and,
                    # under the paged layout, give back the tail blocks
                    mstate = {**ms2, "pos": prog + stride}
                    if paged:
                        from repro.serving import pager as PG

                        pstate, bt = PG.release_tail(
                            PG.PagerState(
                                mstate["page_free"], mstate["page_top"],
                                mstate["page_rc"],
                            ),
                            mstate["block_table"], prog + stride, act,
                            page_size=page_size,
                        )
                        mstate = {**mstate, "block_table": bt,
                                  "page_free": pstate.free,
                                  "page_top": pstate.top,
                                  "page_rc": pstate.rc}
                # scatter the accepted tokens g[:, 0..acc_n] at
                # positions prog+1 .. prog+1+acc_n (all generated:
                # spec rows always satisfy prog >= prompt_len - 1)
                col = jax.lax.broadcasted_iota(
                    jnp.int32, (bsz, buf_len), 1
                )
                rel = col - (prog + 1)[:, None]
                sel = (act[:, None] & (rel >= 0) & (rel <= acc_n[:, None])
                       & (col >= slots.prompt_len[:, None]))
                val = jnp.take_along_axis(
                    g, jnp.clip(rel, 0, spec_k), axis=1
                )
                tokens = jnp.where(sel, val, slots.tokens)
                progress = prog + stride
                active = act & (progress < slots.total_len - 1)
                inc = jnp.stack([
                    jnp.sum(jnp.where(act, acc_n, 0)),
                    jnp.sum(jnp.where(act, w - 1, 0)),
                    jnp.sum(stride),
                ]).astype(jnp.int32)
                return mstate, SlotState(
                    tokens=tokens,
                    prompt_len=slots.prompt_len,
                    total_len=slots.total_len,
                    progress=progress,
                    active=active,
                    rng=slots.rng,
                ), inc

            def _spec_n(params, mstate, slots, acc, run):
                # same freeze contract as ``_step_n`` (budget-stopped
                # rows keep their chunk boundaries); ``acc`` is the
                # cumulative device counter [accepted, proposed, emitted]
                frozen = slots.active & ~run

                def body(_, carry):
                    ms, sl, ac = carry
                    ms, sl, inc = _spec_step(params, ms, sl)
                    return ms, sl, ac + inc

                mstate, out, acc = jax.lax.fori_loop(
                    0, steps_per_sync, body,
                    (mstate, slots._replace(active=slots.active & run),
                     acc),
                )
                return (mstate, out._replace(active=out.active | frozen),
                        acc)

            self._spec_n = jax.jit(_spec_n, donate_argnums=(1, 2, 3))
            self._acc = jnp.zeros((3,), jnp.int32)
        else:
            self._spec_n = None
            self._acc = None

    # -- request intake ------------------------------------------------------

    def submit(self, tokens, max_new_tokens: Optional[int] = None, *,
               priority: int = 0,
               deadline_ms: Optional[float] = None) -> "RequestHandle":
        """Queue a request; returns a :class:`RequestHandle` (an ``int``
        subclass — the request id everywhere an id is expected, plus
        ``.cancel()``).

        Accepts either the positional form (``submit(tokens,
        max_new_tokens, ...)``) or a prebuilt spec: ``submit(
        Request.spec(tokens, max_new_tokens, priority=..., ...))``.
        ``priority`` (larger = more important) and ``deadline_ms`` (SLO
        budget from now, against the monotonic clock; None = none) feed
        the scheduler contract in the class docstring.  Rejections —
        over-length, empty, pool-impossible, queue-full — always name
        the request id they rejected."""
        if isinstance(tokens, Request):
            req = tokens
            if max_new_tokens is not None:
                raise TypeError(
                    "submit(Request, ...) takes the whole spec from the "
                    "Request — max_new_tokens must not also be passed"
                )
            tokens = req.tokens
            max_new_tokens = req.max_new_tokens
            priority = req.priority
            deadline_ms = req.deadline_ms
        elif max_new_tokens is None:
            raise TypeError(
                "submit() needs max_new_tokens unless a Request spec "
                "is passed"
            )
        if self._paged:
            need = self._pages_needed(len(tokens) + max_new_tokens)
            if need > self.n_pages:
                # reject now: the queue would otherwise starve behind a
                # request that can never reserve enough pages
                rid = self.queue.peek_next_id()
                raise ValueError(
                    f"request {rid}: needs {need} pages > pool size "
                    f"{self.n_pages} (prompt {len(tokens)} + "
                    f"{max_new_tokens} new, page_size {self.page_size})"
                )
        rid = self.queue.submit(tokens, max_new_tokens, priority=priority,
                                deadline_ms=deadline_ms)
        now = time.perf_counter()
        self._t_submit[rid] = now
        if deadline_ms is not None:
            self._deadline[rid] = now + deadline_ms / 1e3
        return RequestHandle(rid, self)

    def cancel(self, req_id: int) -> bool:
        """Cancel a request wherever it lives.  Still-queued: removed
        immediately.  Resident — device-active, mid-prefill, or spilled
        to the host tier: marked, then drained through the jitted release
        path at the next harvest (pages and snapshot slots return to
        their pools in every tier; no output is recorded).  Returns False
        when the id is unknown or already finished."""
        req = self.queue.cancel(req_id)
        if req is not None:
            self.cancelled.add(req_id)
            self._deadline.pop(req_id, None)
            self._t_submit.pop(req_id, None)
            return True
        for r in self._slot_req:
            if r is not None and r.req_id == req_id:
                self._cancel_req.add(req_id)
                return True
        return False

    def set_fault_plan(self, plan: Optional[FaultPlan]) -> None:
        """Arm a plan with the harvest-cycle clock rewound to 0 (benchmark
        drivers arm after compile warm-up so event cycles land on a
        schedule-stable clock)."""
        self.fault_plan = plan
        self._cycle = 0

    def _apply_faults(self) -> None:
        """Fire the armed plan's events for the current harvest cycle.
        Every injection flows through the normal scheduler paths
        (``repro.serving.faults`` documents the kinds)."""
        if self.fault_plan is None:
            return
        for e in self.fault_plan.at(self._cycle):
            if e.kind == "exhaust_pool":
                self._fault_hold_pages = min(e.pages, self.n_pages)
            elif e.kind == "release_pool":
                self._fault_hold_pages = 0
            elif e.kind == "cancel":
                self.cancel(e.req_id)
            elif e.kind == "deadline":
                self._deadline[e.req_id] = (
                    time.perf_counter() + e.deadline_ms / 1e3
                )
            elif e.kind == "poison":
                self._poison_req.add(e.req_id)

    def _effective_pages(self) -> int:
        """Pool size the reservation ledger admits against — shrunk while
        an ``exhaust_pool`` fault holds pages hostage."""
        return self.n_pages - self._fault_hold_pages

    def _req_key(self, req_id: int) -> np.ndarray:
        """Per-request sampling key, a pure function of (engine seed,
        req_id) — see the ``_seed`` comment in ``__init__``."""
        gen = np.random.Generator(
            np.random.Philox(np.random.SeedSequence((self._seed, req_id)))
        )
        return gen.integers(0, 2 ** 32, size=2, dtype=np.uint32)

    def _pages_needed(self, total_len: int) -> int:
        from repro.serving.pager import pages_needed
        return pages_needed(total_len, self.page_size)

    # -- host-side prefix index (no device sync anywhere) --------------------

    def _prefix_chain(self, tokens: np.ndarray):
        """Chained hashes of the page-aligned full prompt chunks: chunk i's
        hash folds in chunk i-1's, so a hit at depth i certifies the whole
        prefix — the page's K/V depends on everything before it, not just
        its own tokens."""
        s = self.page_size
        h = 0x51ED2701
        for i in range(len(tokens) // s):
            h = hash((h, tokens[i * s:(i + 1) * s].tobytes()))
            yield i, h

    def _register_prefix(self, b: int, tokens: np.ndarray) -> None:
        ep = self._slot_epoch[b]
        for _, h in self._prefix_chain(tokens):
            ent = self._prefix_index.get(h)
            if ent is not None:
                src, src_ep = ent
                if (src_ep == self._slot_epoch[src]
                        and self._slot_req[src] is not None
                        and not self._row_spilled[src]):
                    # a live row already serves this chunk: keep it (a
                    # sharer overwriting its donor would take the entry
                    # to its own — likely earlier — grave, leaving the
                    # still-resident donor unmatchable)
                    continue
            self._prefix_index[h] = (b, ep)
            self._slot_hashes[b].append(h)

    def _evict_prefix(self, b: int) -> None:
        """Invalidate slot b's index entries (request released).  The epoch
        bump is what guarantees staleness; the deletes just keep the index
        bounded by resident prompts."""
        old = (b, self._slot_epoch[b])
        self._slot_epoch[b] += 1
        dropped = False
        for h in self._slot_hashes[b]:
            if self._prefix_index.get(h) == old:
                del self._prefix_index[h]
                dropped = True
        self._slot_hashes[b] = []
        if dropped:
            # hand the dropped chunks to surviving holders: a sharer keeps
            # the donor's pages resident (refcounts), so it can donate them
            # onward — without this, a shared prefix would go unmatchable
            # the moment its original donor finishes, even though the
            # pages live on (re-registration only fills gaps; entries that
            # still point at live rows are kept).  Spilled rows don't
            # donate — their pages are on the host tier.
            for s, req in enumerate(self._slot_req):
                if req is not None and not self._row_spilled[s]:
                    self._register_prefix(s, req.tokens)

    def _match_prefix(self, tokens: np.ndarray):
        """Longest page-aligned shared prefix among resident rows: returns
        (donor slot, shared block count), (0, 0) when nothing matches.

        A hit is honored only if the donor still holds its request (epoch
        check), its host-mirror progress shows the chunk fully *written*
        (mapped pages alone could still be mid-prefill), the chunk is all
        prompt (never a donor's generated tokens), and the tokens compare
        equal — the hash only routes, equality decides.

        The same progress check certifies *snapshot* availability for the
        recurrent families: snapshot engines clip chunk widths so every
        boundary a row passes is a step endpoint (captured), and shared
        slots travel with their boundaries, so boundary ``k`` has a
        snapshot exactly when the donor's progress has reached ``k *
        page_size`` — the index records availability without any extra
        bookkeeping."""
        if not self._share_eligible:
            return 0, 0
        best = (0, 0)
        s = self.page_size
        for i, h in self._prefix_chain(tokens):
            ent = self._prefix_index.get(h)
            if ent is None:
                continue
            src, ep = ent
            end = (i + 1) * s
            req = self._slot_req[src]
            if (ep != self._slot_epoch[src] or req is None
                    or self._row_spilled[src]
                    or req.prompt_len < end
                    or self._row_progress[src] < end
                    or not np.array_equal(tokens[:end], req.tokens[:end])):
                continue
            best = (src, i + 1)
        return best

    def _plan_admission(self, req: Request):
        """Host-side admission plan for one request: prefix match, shared
        depth, CoW spare, worst-case page need — pure mirror arithmetic,
        re-runnable after a preemption changes the donor set (the match,
        and so the need, can only shrink)."""
        src, nblk = self._match_prefix(req.tokens)
        if self._recurrent:
            # recurrent families resume *from a restored snapshot*, so
            # the resume point must be a boundary strictly inside the
            # prompt (the re-fed last token then always lands in an
            # unshared page — recurrent sharing never CoWs)
            nblk = min(nblk, (req.prompt_len - 1) // self.page_size)
        shared = nblk * self.page_size
        # always re-feed at least the last prompt token: its logits
        # seed generation (a fully shared attention prompt re-feeds
        # exactly one token, whose write CoWs the final shared page)
        start = min(shared, req.prompt_len - 1)
        cow = 1 if shared > start else 0
        if self._paged:
            need = self._pages_needed(req.total_len) + cow
            if need > self.n_pages:
                # the CoW spare would overflow the pool: serve unshared
                src = nblk = start = cow = 0
                need = self._pages_needed(req.total_len)
        else:
            need = 0
        return src, nblk, start, cow, need

    def _expire_queued(self, now: float) -> None:
        """Deadline sweep over still-queued requests (resident rows expire
        at harvest, where the device sync already happened)."""
        if not self._deadline:
            return
        for rid in self.queue.pending_ids():
            t = self._deadline.get(rid)
            if t is not None and now >= t:
                if self.queue.cancel(rid) is not None:
                    self.expired.add(rid)
                    self._deadline.pop(rid, None)
                    self._t_submit.pop(rid, None)

    def _try_preempt(self, req: Request, need: int, protected) -> bool:
        """Spill strictly-lower-priority victims until ``req``'s
        reservation fits; commit only if the chosen set actually admits
        it (no thrashing spills).  Victim order: lowest priority first,
        then least progress (least work lost), then oldest.
        ``protected`` rows (this refill's pending admissions and their
        prefix donors) are never victims — spilling a pending donor
        would tear pages out from under the _admit mapping below."""
        if self._spill is None:
            return False
        victims = [
            b for b, r in enumerate(self._slot_req)
            if r is not None and not self._row_spilled[b]
            and r.priority < req.priority
            and b not in protected
            and r.req_id not in self._cancel_req
            and r.req_id not in self._poison_req
        ]
        victims.sort(key=lambda b: (self._slot_req[b].priority,
                                    self._row_progress[b],
                                    self._slot_req[b].req_id))
        chosen = []
        freed = 0
        for b in victims:
            if (self._pages_reserved - freed + need
                    <= self._effective_pages()):
                break
            chosen.append(b)
            freed += self._row_pages[b]
        if (not chosen
                or self._pages_reserved - freed + need
                > self._effective_pages()):
            return False
        mask = np.zeros((self.batch,), bool)
        for b in chosen:
            mask[b] = True
            self._spill_need[b] = self._row_pages[b]
            self._pages_reserved -= self._row_pages[b]
            self._row_pages[b] = 0
            self._row_spilled[b] = True
            self.preemptions += 1
            # a spilled row's pages leave the device: it stops donating
            # (sharers keep already-mapped pages alive via refcounts;
            # only *new* matches are ruled out)
            self._evict_prefix(b)
        self._mstate, self._slots = self._spill(
            self._mstate, self._slots, jnp.asarray(mask)
        )
        return True

    def _try_restore(self, now: float) -> int:
        """Bring spilled rows back while their worst-case reservation fits
        (the reservation gate is exactly what guarantees the jitted
        restore's device-side pops never find the free list dry).
        Highest priority first, then oldest; a spilled row defers to a
        strictly-higher-priority queue head that could itself fit, and
        doomed rows (pending cancel/poison, past deadline) stay spilled
        — the harvest drains their host-tier slots directly."""
        if self._restore is None:
            return 0
        spilled = [b for b in range(self.batch) if self._row_spilled[b]]
        if not spilled:
            return 0
        head = self.queue.peek()
        head_fits = (
            head is not None
            and self._pages_needed(head.total_len)
            <= self._effective_pages()
        )
        spilled.sort(key=lambda b: (-self._slot_req[b].priority,
                                    self._slot_req[b].req_id))
        mask = np.zeros((self.batch,), bool)
        n = 0
        for b in spilled:
            req = self._slot_req[b]
            rid = req.req_id
            if rid in self._cancel_req or rid in self._poison_req:
                continue
            t = self._deadline.get(rid)
            if t is not None and now >= t:
                continue
            if head_fits and head.priority > req.priority:
                continue
            need = self._spill_need[b]
            if self._pages_reserved + need > self._effective_pages():
                continue
            mask[b] = True
            self._row_spilled[b] = False
            self._row_pages[b] = need
            self._spill_need[b] = 0
            self._pages_reserved += need
            self.restores += 1
            n += 1
        if n == 0:
            return 0
        self._mstate, self._slots = self._restore(
            self._mstate, self._slots, jnp.asarray(mask)
        )
        if self._share_eligible:
            # device-resident again: the row may donate its prefix anew
            for b in spilled:
                if mask[b]:
                    self._register_prefix(b, self._slot_req[b].tokens)
        return n

    def _refill(self) -> int:
        """Admit queued requests into free rows (one jitted masked write).

        Scheduler order per cycle: expire queued deadlines, restore
        spilled rows that fit, then admit the queue head while a free
        row and (paged layout) a worst-case page reservation exist —
        preempting strictly-lower-priority victims when the head cannot
        reserve (class docstring has the full contract).  Admission
        stops at the first unadmittable head — no reordering past a
        starving request beyond what the priority queue itself encodes.

        Prefix sharing: each admitted prompt is matched against the
        host-side index; on a hit the donor's leading blocks are mapped
        (``share_prefix`` inside ``_admit``) and the row starts at the
        first unshared token.  Reservation stays the *full* worst case
        plus one CoW spare for a fully shared prompt — a donor may finish
        first and leave the sharer sole holder of the shared pages, so
        subtracting them would let the pool over-commit (see class
        docstring); the sharing win is resident bytes and TTFT, not
        admission capacity.
        """
        now = time.perf_counter()
        self._expire_queued(now)
        self._try_restore(now)
        free = [b for b, r in enumerate(self._slot_req) if r is None]
        if not free or not self.queue:
            return 0
        new_tokens = np.zeros((self.batch, self.max_len), np.int32)
        new_plen = np.ones((self.batch,), np.int32)
        new_total = np.ones((self.batch,), np.int32)
        new_rng = np.zeros((self.batch, 2), np.uint32)
        mask = np.zeros((self.batch,), bool)
        new_start = np.zeros((self.batch,), np.int32)
        share_src = np.zeros((self.batch,), np.int32)
        share_nblk = np.zeros((self.batch,), np.int32)
        registrations = []
        pending: Set[int] = set()   # rows admitted in this refill
        donors: Set[int] = set()    # their prefix donors
        n = 0
        for b in free:
            req = self.queue.peek()
            if req is None:
                break
            src, nblk, start, cow, need = self._plan_admission(req)
            if (self._paged
                    and self._pages_reserved + need
                    > self._effective_pages()):
                if not self._try_preempt(req, need, pending | donors):
                    break
                # victims left the donor set: re-plan (the match can only
                # shrink, so the committed preemption still fits)
                src, nblk, start, cow, need = self._plan_admission(req)
            self.queue.pop()
            self._slot_req[b] = req
            self._row_progress[b] = start
            self._row_pages[b] = need
            self._pages_reserved += need
            new_tokens[b, : req.prompt_len] = req.tokens
            new_plen[b] = req.prompt_len
            new_total[b] = req.total_len
            new_rng[b] = self._req_key(req.req_id)
            mask[b] = True
            new_start[b] = start
            share_src[b] = src
            share_nblk[b] = nblk
            self.shared_prompt_tokens += start
            self.cow_pages += cow
            if self._share_eligible:
                registrations.append((b, req.tokens))
            pending.add(b)
            if nblk > 0:
                donors.add(src)
            n += 1
        if n == 0:
            return 0
        self._mstate, self._slots = self._admit(
            self._mstate, self._slots,
            jnp.asarray(new_tokens), jnp.asarray(new_plen),
            jnp.asarray(new_total), jnp.asarray(new_rng),
            jnp.asarray(mask), jnp.asarray(new_start),
            jnp.asarray(share_src), jnp.asarray(share_nblk),
        )
        # register *after* the device mapping exists: rows admitted in this
        # same batch must not pick each other as donors (their shared
        # blocks only materialize in the _admit call above)
        for b, toks in registrations:
            self._register_prefix(b, toks)
        return n

    # -- serving loop --------------------------------------------------------

    def _advance_mirror(self, widths: List[int]) -> List[int]:
        """Replay one device step's progress update on the host mirror.

        ``widths[b]`` is the stride row b advanced (a chunk width for a
        prefill step, ``steps_per_sync`` for a fused decode call — the
        decode case over-counts past done-detection, which the
        ``total_len - 1`` clamp absorbs exactly like the device's
        ``active`` mask).  Accumulates prompt-ingestion counts and returns
        the req_ids whose first generated token was produced by this step
        (TTFT is stamped by the caller at the next device sync, when that
        token actually exists).
        """
        crossed: List[int] = []
        for b, req in enumerate(self._slot_req):
            if req is None or self._row_spilled[b]:
                # a spilled row's lane is device-inactive: no progress
                continue
            p = self._row_progress[b]
            if p >= req.total_len - 1:
                continue
            np_ = min(p + widths[b], req.total_len - 1)
            self.prompt_tokens += (
                min(np_, req.prompt_len) - min(p, req.prompt_len)
            )
            if p < req.prompt_len <= np_:
                crossed.append(req.req_id)
            self._row_progress[b] = np_
        return crossed

    def _chunk_limit(self, progress: int) -> int:
        """Host mirror of ``engine_step``'s chunk-width cap: snapshot
        engines clip chunks to end at page boundaries so every boundary
        state is captured (the two formulas must stay identical — the
        mirror's TTFT/ingestion ledger depends on it)."""
        if self._snap_every:
            return min(self.prefill_chunk,
                       self._snap_every - progress % self._snap_every)
        return self.prefill_chunk

    def _prompt_phase_rows(self) -> bool:
        """True while some occupied, unfinished row still has >= 2 prompt
        tokens to feed — the regime where a chunked step beats a decode
        step (a single remaining prompt token is just a decode feed)."""
        return any(
            req is not None
            and not self._row_spilled[b]
            and self._row_progress[b] < req.total_len - 1
            and req.prompt_len - self._row_progress[b] >= 2
            for b, req in enumerate(self._slot_req)
        )

    def step(self) -> int:
        """One sync cycle: apply scripted faults, refill (deadline sweep,
        restores, admission with preemption), chunked prefill until no
        row is mid-prompt (bounded by ``prefill_budget`` when set),
        ``steps_per_sync`` fused decode steps, then a single host
        readback to harvest finished — and drain cancelled / expired /
        poisoned — rows.  Returns the number of requests completed this
        cycle."""
        self._apply_faults()
        self._cycle += 1
        self._refill()
        if not any(r is not None for r in self._slot_req):
            return 0
        crossed: List[int] = []
        if self._prefill is not None:
            # prompt ingestion: chunked steps, back-to-back dispatches, no
            # host sync — the mirror knows each row's width without one.
            # Decode-phase rows ride along one token per chunk step.
            # ``prefill_budget`` caps the chunk steps per cycle so a long
            # prompt cannot starve resident decodes of a whole harvest
            # interval; leftover prompt tokens continue next cycle or
            # token-by-token inside the fused decode call below.
            nchunks = 0
            while self._prompt_phase_rows() and (
                    not self.prefill_budget
                    or nchunks < self.prefill_budget):
                widths = [
                    max(1, min(self._chunk_limit(self._row_progress[b]),
                               req.prompt_len - self._row_progress[b]))
                    if req is not None else 1
                    for b, req in enumerate(self._slot_req)
                ]
                self._mstate, self._slots = self._prefill(
                    self.params, self._mstate, self._slots
                )
                self.prefill_steps += 1
                nchunks += 1
                crossed += self._advance_mirror(widths)
        # rows a budget stop left mid-prompt are frozen for the fused
        # decode call (see ``_step_n``): advancing them token-by-token
        # would change their chunk partitioning, and with it the logits
        run = np.ones((self.batch,), bool)
        if self._prefill is not None:
            for b, req in enumerate(self._slot_req):
                if (req is not None and not self._row_spilled[b]
                        and req.prompt_len - self._row_progress[b] >= 2):
                    run[b] = False
        if self._spec_n is not None:
            # draft-and-verify decode: per-row strides are data-dependent
            # (accepted lengths), so the host mirror is refreshed from
            # the harvest readback below instead of replayed
            # arithmetically
            self._mstate, self._slots, self._acc = self._spec_n(
                self.params, self._mstate, self._slots, self._acc,
                jnp.asarray(run),
            )
            self.steps += self.steps_per_sync
        else:
            self._mstate, self._slots = self._step_n(
                self.params, self._mstate, self._slots, jnp.asarray(run)
            )
            self.steps += self.steps_per_sync
            crossed += self._advance_mirror(
                [self.steps_per_sync if run[b] else 0
                 for b in range(self.batch)]
            )
        # the one host sync of the cycle (allocator tops — and, under
        # speculation, per-row progress and the accept counter — ride
        # along; no extra round-trips)
        fetch = [self._slots.active, self._slots.tokens]
        i_prog = i_acc = i_page = i_snap = -1
        if self._spec_n is not None:
            fetch.append(self._slots.progress)
            i_prog = len(fetch) - 1
            fetch.append(self._acc)
            i_acc = len(fetch) - 1
        if self._paged:
            fetch.append(self._mstate["page_top"])
            i_page = len(fetch) - 1
        if self._snap:
            fetch.append(self._mstate["snap_top"])
            i_snap = len(fetch) - 1
        got = list(jax.device_get(tuple(fetch)))
        active, tokens = got[0], got[1]
        if self._spec_n is not None:
            # mirror refresh: the device's per-row progress is the truth
            # under speculation; the same deltas the deterministic replay
            # would have produced (ingestion counts, TTFT crossings) are
            # recovered from old-vs-new
            devprog = got[i_prog]
            for b, req in enumerate(self._slot_req):
                if req is None:
                    continue
                old = self._row_progress[b]
                dev = int(devprog[b])
                plen = req.prompt_len
                self.prompt_tokens += min(dev, plen) - min(old, plen)
                if old < plen <= dev:
                    crossed.append(req.req_id)
                self._row_progress[b] = dev
            acc = got[i_acc]
            self.spec_accepted = int(acc[0])
            self.spec_proposed = int(acc[1])
            self.spec_emitted = int(acc[2])
        if self._paged:
            self.peak_pages_in_use = max(
                self.peak_pages_in_use, self.n_pages - int(got[i_page])
            )
        if self._snap:
            self.peak_snaps_in_use = max(
                self.peak_snaps_in_use, self.n_snap_slots - int(got[i_snap])
            )
        # the readback above materialized every token this cycle produced,
        # so first-token latencies are stamped here, not at dispatch (the
        # pop keeps the submit-time ledger bounded by pending requests)
        now = time.perf_counter()
        for rid in crossed:
            t0 = self._t_submit.pop(rid, None)
            if t0 is not None:
                self.ttft.setdefault(rid, now - t0)
        finished = 0
        release = np.zeros((self.batch,), bool)
        drained = False
        for b, req in enumerate(self._slot_req):
            if req is None:
                continue
            rid = req.req_id
            t = self._deadline.get(rid)
            if rid in self._cancel_req:
                self.cancelled.add(rid)
            elif rid in self._poison_req:
                self.poisoned.add(rid)
            elif t is not None and now >= t:
                self.expired.add(rid)
            elif self._row_spilled[b] or active[b]:
                continue    # still running (or parked on the host tier)
            else:
                # finished for real: the generated span is the payload
                out = tokens[b, req.prompt_len : req.total_len].copy()
                self.outputs[rid] = out
                self.generated += out.size
                self._drop_row(b)
                release[b] = True
                finished += 1
                continue
            # cancelled / poisoned / past-deadline: no payload; the row —
            # device-active, mid-prefill, or spilled — drains through the
            # same release path, surrendering pages and snapshot slots in
            # every tier
            self._drop_row(b)
            release[b] = True
            drained = True
        if np.any(release) and (self._paged or self._snap or drained):
            # free-on-completion: the rows' pages and snapshot slots
            # (device *and* host tiers) return to their pools now, not
            # when the slot happens to be refilled; drained rows
            # additionally need their lanes deactivated (a cancelled row
            # may still be device-active)
            self._mstate, self._slots = self._release(
                self._mstate, self._slots, jnp.asarray(release)
            )
        return finished

    def _drop_row(self, b: int) -> None:
        """Host-mirror bookkeeping for a row leaving the batch (finished
        or drained): reservation, spill mirrors, prefix entries, SLO
        ledgers."""
        rid = self._slot_req[b].req_id
        self._slot_req[b] = None
        self._pages_reserved -= self._row_pages[b]
        self._row_pages[b] = 0
        self._spill_need[b] = 0
        self._row_spilled[b] = False
        # the slot's prompt leaves the prefix index; its *pages* live
        # on while any sharer still references them (device refcounts)
        self._evict_prefix(b)
        self._cancel_req.discard(rid)
        self._poison_req.discard(rid)
        self._deadline.pop(rid, None)
        self._t_submit.pop(rid, None)

    def run(self) -> Dict[int, np.ndarray]:
        """Serve until queue and slots drain; returns {req_id: generated}."""
        while self.queue or any(r is not None for r in self._slot_req):
            self.step()
        return self.outputs

    def reset_stats(self) -> None:
        """Zero every accumulated statistic (post-warm-up, pre-measurement).

        Lives next to the counters it owns so benchmark drivers don't
        hand-mirror the list; serving state (slots, caches, queue) is
        untouched."""
        self.outputs.clear()
        self.ttft.clear()
        self.steps = self.prefill_steps = 0
        self.generated = self.prompt_tokens = 0
        self.spec_proposed = self.spec_accepted = self.spec_emitted = 0
        if self._acc is not None:
            self._acc = jnp.zeros((3,), jnp.int32)
        self.peak_pages_in_use = self.peak_snaps_in_use = 0
        self.shared_prompt_tokens = self.cow_pages = 0
        self.preemptions = self.restores = 0
        self.cancelled.clear()
        self.expired.clear()
        self.poisoned.clear()

    def kv_bytes_per_page(self) -> int:
        """Bytes one page occupies across all layer slabs (K and V) —
        shape arithmetic frozen at construction, no pytree walk."""
        return self._kv_bytes_per_page

    def kv_resident_bytes(self, *, peak: bool = False) -> int:
        """Resident KV-cache footprint: allocated bytes under the paged
        layout (current or peak), the full slab under contiguous.  Byte
        factors are cached at construction; only the *current* paged
        residency reads a device scalar (``page_top``)."""
        if self._paged:
            pages = (
                self.peak_pages_in_use if peak
                else self.n_pages - int(self._mstate["page_top"])
            )
            return pages * self._kv_bytes_per_page
        return self._contig_kv_bytes

    def stats(self) -> Dict[str, float]:
        out = {
            "decode_steps": float(self.steps),
            "prefill_steps": float(self.prefill_steps),
            "generated_tokens": float(self.generated),
            "prompt_tokens": float(self.prompt_tokens),
            "batch": float(self.batch),
        }
        if self._paged:
            out["kv_pages"] = float(self.n_pages)
            out["kv_pages_peak"] = float(self.peak_pages_in_use)
            out["kv_resident_bytes_peak"] = float(
                self.kv_resident_bytes(peak=True)
            )
        if self._snap:
            out["snap_slots"] = float(self.n_snap_slots)
            out["snap_slots_peak"] = float(self.peak_snaps_in_use)
        if self.prefix_sharing:
            out["shared_prompt_tokens"] = float(self.shared_prompt_tokens)
            out["cow_pages"] = float(self.cow_pages)
        if self._spillable:
            out["preemptions"] = float(self.preemptions)
            out["restores"] = float(self.restores)
        if self.spec is not None:
            out["spec_proposed"] = float(self.spec_proposed)
            out["spec_accepted"] = float(self.spec_accepted)
            out["spec_emitted"] = float(self.spec_emitted)
            out["spec_accept_rate"] = (
                self.spec_accepted / max(self.spec_proposed, 1)
            )
        out["cancelled"] = float(len(self.cancelled))
        out["expired"] = float(len(self.expired))
        return out


def serve_all(
    model: Model,
    params,
    requests,
    *,
    batch: int,
    max_len: int,
    steps_per_sync: int = 8,
    cache: Optional[CacheConfig] = None,
    config: Optional[EngineConfig] = None,
    **engine_kwargs,
) -> Dict[int, np.ndarray]:
    """Convenience: submit ``[(tokens, max_new_tokens), ...]`` and drain.

    Accepts the typed config objects (``cache=`` / ``config=`` — the
    preferred surface; ``steps_per_sync`` then lives in ``config``) or
    the legacy kwarg pile, which flows through the engine's deprecation
    adapter.  Returns outputs keyed by submission order (0..n-1)."""
    if cache is not None or config is not None:
        eng = ServingEngine(
            model, params, batch=batch, max_len=max_len,
            cache=cache, config=config, **engine_kwargs,
        )
    elif engine_kwargs:
        # legacy kwarg pile: flows through the engine's from_kwargs
        # adapter (DeprecationWarning attributed to this call's caller)
        eng = ServingEngine(
            model, params, batch=batch, max_len=max_len,
            steps_per_sync=steps_per_sync, **engine_kwargs,
        )
    else:
        eng = ServingEngine(
            model, params, batch=batch, max_len=max_len,
            config=EngineConfig(steps_per_sync=steps_per_sync),
        )
    for tokens, gen in requests:
        eng.submit(tokens, gen)
    return eng.run()
