"""Sharding hints + rules — GSPMD glue between model code and the mesh.

Model code calls ``shard(x, axes)`` with *logical* axis names; outside a
mesh context this is a no-op (CPU smoke tests), inside it becomes
``with_sharding_constraint`` so the same single-source model lowers for the
production mesh — the paper's portability switch, applied to distribution.

``param_sharding_rules`` maps parameter pytree paths to NamedShardings:
FSDP (ZeRO-3) over the ``data`` axis + tensor parallelism over ``model``.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class _MeshState(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.data_axes: Tuple[str, ...] = ("data",)
        self.model_axes: Tuple[str, ...] = ("model",)
        self.sequence_parallel: bool = False


_STATE = _MeshState()


@contextlib.contextmanager
def use_mesh(
    mesh: Mesh, *, data_axes=("data",), model_axes=("model",),
    sequence_parallel: bool = False,
):
    """Activate sharding hints. data_axes may include 'pod' for multi-pod DP.

    sequence_parallel: shard the residual stream's sequence dim over the
    model axis between blocks (Megatron-SP); cuts per-layer activation
    residency by the TP degree — essential for the 100-layer configs.
    """
    prev = (
        _STATE.mesh, _STATE.data_axes, _STATE.model_axes,
        _STATE.sequence_parallel,
    )
    _STATE.mesh, _STATE.data_axes, _STATE.model_axes = (
        mesh, tuple(data_axes), tuple(model_axes)
    )
    _STATE.sequence_parallel = sequence_parallel
    try:
        with mesh:
            yield
    finally:
        (_STATE.mesh, _STATE.data_axes, _STATE.model_axes,
         _STATE.sequence_parallel) = prev


def active_mesh() -> Optional[Mesh]:
    return _STATE.mesh


def _resolve(axis):
    """Map logical axis name -> physical mesh axes tuple."""
    if axis is None:
        return None
    if axis == "data":
        return _STATE.data_axes if len(_STATE.data_axes) > 1 else _STATE.data_axes[0]
    if axis == "model":
        return _STATE.model_axes if len(_STATE.model_axes) > 1 else _STATE.model_axes[0]
    if axis == "sp":  # sequence-parallel: model axis if enabled, else unsharded
        if not _STATE.sequence_parallel:
            return None
        return _resolve("model")
    return axis


def pspec(axes: Sequence[Optional[str]]) -> P:
    return P(*[_resolve(a) for a in axes])


def axis_size(logical: str) -> int:
    """Product of mesh axis sizes behind a logical axis (1 without a mesh)."""
    if _STATE.mesh is None:
        return 1
    phys = _resolve(logical)
    if phys is None:
        return 1
    if isinstance(phys, str):
        phys = (phys,)
    n = 1
    for a in phys:
        n *= _STATE.mesh.shape[a]
    return n


def shard(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Sharding hint; identity without an active mesh.

    Divisibility-aware: any logical axis that does not evenly divide the
    corresponding dim is dropped (avoids GSPMD involuntary-remat paths for
    e.g. 2 KV heads over a 16-way model axis).
    """
    if _STATE.mesh is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"axes {axes} vs rank {x.ndim}")
    eff = []
    for i, a in enumerate(axes):
        if a == "auto":  # explicitly leave the dim to GSPMD propagation
            eff.append(P.UNCONSTRAINED)
        elif a is None or x.shape[i] % max(axis_size(a), 1) == 0:
            eff.append(_resolve(a))
        else:
            # indivisible: leave the dim to GSPMD propagation
            eff.append(P.UNCONSTRAINED)
    return jax.lax.with_sharding_constraint(x, P(*eff))


# ---------------------------------------------------------------------------
# Parameter sharding rules (FSDP over data + TP over model)
# ---------------------------------------------------------------------------

# Matched in order against the '/'-joined param path; first hit wins.
# Convention: weights (in_dim, out_dim). TP shards the "wide" dim; FSDP
# shards the other over data.
_RULES = [
    # embeddings / lm head: vocab on model (TP vocab parallelism), d on data
    (r"embed", ("model", "data")),
    (r"lm_head", ("data", "model")),
    # attention
    (r"\bwq\b|\bwk\b|\bwv\b", ("data", "model")),
    (r"\bwo\b", ("model", "data")),
    (r"\bbq\b|\bbk\b|\bbv\b", ("model",)),
    # mlp
    (r"\bwg\b|\bwi\b", ("data", "model")),
    # moe experts have a leading E axis -> EP over model, FSDP over data
    (r"experts|moe", ("model", "data", None)),
    (r"router", ("data", None)),
    # mamba
    (r"w_in", ("data", "model")),
    (r"w_out", ("model", "data")),
    (r"conv_w", (None, "model")),
    # norms / scalars / small vectors: replicate
    (r"ln|gate|a_log|d_skip|dt_bias|\bb\b", None),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def spec_for_param(path, leaf) -> P:
    """PartitionSpec for one parameter leaf (stacked layer axes prepended)."""
    s = _path_str(path)
    ndim = getattr(leaf, "ndim", 0)
    for pat, axes in _RULES:
        if re.search(pat, s):
            if axes is None:
                return P()
            axes = [a for a in axes]
            # leading stacked-layer axes (scan over layers/groups): leave
            # unsharded; align the rule to the *trailing* dims
            extra = ndim - len(axes)
            if extra < 0:
                axes = axes[-ndim:] if ndim else []
            full = [None] * max(extra, 0) + list(axes)
            # drop shardings that would over-partition tiny dims
            return P(*[_resolve(a) for a in full])
    return P()


def params_pspecs(params):
    """Pytree of PartitionSpecs matching the params pytree."""
    return jax.tree_util.tree_map_with_path(spec_for_param, params)


def params_shardings(mesh: Mesh, params):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, spec_for_param(path, leaf)),
        params,
    )
