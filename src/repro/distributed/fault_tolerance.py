"""Fault tolerance + elasticity + straggler mitigation.

What a 1000+-node deployment needs, and what of it runs here:

  * ``run_with_restarts`` — the supervision loop: run the train function,
    on failure restore the latest checkpoint and continue; an injectable
    ``FaultInjector`` exercises this in tests (kill at step k).
  * ``ElasticPlan`` — on device loss, rebuild the largest valid mesh from
    the surviving devices (keeping the model-parallel degree), recompute
    the per-host data-shard assignment, and restore the checkpoint with
    the new shardings (checkpoints are mesh-agnostic .npy shards).
  * ``StragglerPolicy`` — deterministic data-shard reassignment: shard i
    of step s goes to host ``perm(s)[i]``; a slow host's shard is cheap to
    re-issue because streams are pure in (seed, step, shard).  Step-time
    EMA detection flags hosts > ``threshold``x the median.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.distributed.checkpoint import Checkpointer


class FaultInjector:
    """Deterministic fault schedule for tests: raise at given steps."""

    def __init__(self, fail_at: Sequence[int] = ()):  # steps that die once
        self.fail_at = set(fail_at)
        self.fired: set = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected fault at step {step}")


def run_with_restarts(
    train_fn: Callable[[int, Any], Tuple[Any, int]],
    ckpt: Checkpointer,
    init_state: Any,
    *,
    max_restarts: int = 3,
) -> Tuple[Any, int, int]:
    """Supervise ``train_fn(start_step, state) -> (state, next_step)``.

    On exception: restore latest checkpoint and retry (up to max_restarts).
    Returns (state, final_step, restarts_used).
    """
    restarts = 0
    state = init_state
    step = 0
    while True:
        try:
            state, step = train_fn(step, state)
            return state, step, restarts
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            latest = ckpt.latest_step()
            if latest is not None:
                state = ckpt.restore(latest, state)
                step = latest
            else:
                step = 0


@dataclasses.dataclass
class ElasticPlan:
    """Re-mesh plan after losing devices.

    Keeps the TP degree (model axis) intact — TP re-sharding would change
    per-op layouts — and shrinks the data axis to the largest multiple
    that fits, dropping stragglers/failed hosts.
    """

    n_devices: int
    model_parallel: int

    def viable(self) -> bool:
        return self.n_devices >= self.model_parallel

    @property
    def data_parallel(self) -> int:
        return self.n_devices // self.model_parallel

    @property
    def devices_used(self) -> int:
        return self.data_parallel * self.model_parallel

    def global_batch_for(self, per_replica_batch: int) -> int:
        return self.data_parallel * per_replica_batch

    def make_mesh(self, devices=None):
        import jax

        devs = devices if devices is not None else jax.devices()
        devs = np.asarray(devs[: self.devices_used]).reshape(
            self.data_parallel, self.model_parallel
        )
        from jax.sharding import Mesh

        return Mesh(devs, ("data", "model"))


def plan_after_failure(
    total_devices: int, lost: int, model_parallel: int
) -> ElasticPlan:
    return ElasticPlan(total_devices - lost, model_parallel)


@dataclasses.dataclass
class StragglerPolicy:
    """EMA-based detection + deterministic shard reassignment."""

    n_hosts: int
    ema_alpha: float = 0.3
    threshold: float = 2.0

    def __post_init__(self):
        self.ema = np.zeros(self.n_hosts)

    def observe(self, host_times: Sequence[float]) -> List[int]:
        t = np.asarray(host_times, dtype=np.float64)
        self.ema = np.where(
            self.ema == 0, t, self.ema_alpha * t + (1 - self.ema_alpha) * self.ema
        )
        med = float(np.median(self.ema))
        return [i for i in range(self.n_hosts) if self.ema[i] > self.threshold * med]

    def assignment(self, step: int, exclude: Sequence[int] = ()) -> Dict[int, int]:
        """shard index -> host id for this step (deterministic permutation,
        skipping excluded hosts; excluded hosts' shards go to the fastest)."""
        alive = [h for h in range(self.n_hosts) if h not in set(exclude)]
        rng = np.random.default_rng(step)
        perm = rng.permutation(len(alive))
        return {
            shard: alive[perm[shard % len(alive)]]
            for shard in range(self.n_hosts)
        }
