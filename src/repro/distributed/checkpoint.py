"""Checkpointing — atomic, versioned, restart-safe.

Design for the 1000+-node case (documented here, exercised at container
scale in tests):

  * Atomicity: write to ``step_<N>.tmp/`` then ``os.replace`` to
    ``step_<N>/`` — a crashed writer never corrupts the latest checkpoint.
  * Manifest: every checkpoint carries a JSON manifest (step, mesh
    signature, param tree structure, data-stream position) so a restart
    can (a) verify compatibility, (b) re-shard to a *different* device
    count (elastic restart: ``repro.distributed.elastic``), and (c) resume
    the input pipeline deterministically (streams are pure in (seed, step)).
  * Multi-host: each host writes only the shards it owns (addressable
    shards); here (single host) that is all of them.  Layout on disk is
    one ``.npy`` per leaf, named by the flattened tree path.
  * Retention: ``keep`` newest checkpoints are retained, older deleted.
  * Async: ``save(..., blocking=False)`` snapshots to host memory and
    writes on a background thread so the train loop overlaps I/O.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "~".join(parts)


class Checkpointer:
    def __init__(self, directory: str | pathlib.Path, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save ----------------------------------------------------------------
    def save(
        self,
        step: int,
        state: Any,
        *,
        extra: Optional[Dict[str, Any]] = None,
        blocking: bool = True,
    ) -> None:
        """Snapshot to host then write; non-blocking overlaps the I/O."""
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def _write():
            import ml_dtypes

            tmp = self.dir / f"step_{step}.tmp"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            leaves = jax.tree_util.tree_flatten_with_path(host_state)[0]
            names, dtypes = [], {}
            for path, leaf in leaves:
                name = _path_str(path)
                arr = np.asarray(leaf)
                if arr.dtype == ml_dtypes.bfloat16:
                    # numpy can't serialize ml_dtypes natively: store bits
                    np.save(tmp / f"{name}.npy", arr.view(np.uint16))
                    dtypes[name] = "bfloat16"
                else:
                    np.save(tmp / f"{name}.npy", arr)
                    dtypes[name] = str(arr.dtype)
                names.append(name)
            manifest = {
                "step": step,
                "leaves": names,
                "dtypes": dtypes,
                "mesh": extra.get("mesh") if extra else None,
                "data_position": extra.get("data_position") if extra else None,
                "format": 1,
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def manifest(self, step: int) -> Dict[str, Any]:
        return json.loads(
            (self.dir / f"step_{step}" / "manifest.json").read_text()
        )

    def restore(
        self,
        step: int,
        target: Any,
        *,
        shardings: Any = None,
    ) -> Any:
        """Restore into the structure of ``target`` (values ignored).

        ``shardings``: optional pytree of NamedShardings — re-sharding onto
        whatever mesh the restart built (elastic restart path).
        """
        import ml_dtypes

        d = self.dir / f"step_{step}"
        manifest = self.manifest(step)
        dtypes = manifest.get("dtypes", {})
        leaves, treedef = jax.tree_util.tree_flatten_with_path(target)
        shard_leaves = (
            jax.tree.leaves(shardings) if shardings is not None else
            [None] * len(leaves)
        )
        out = []
        for (path, leaf), sh in zip(leaves, shard_leaves):
            name = _path_str(path)
            arr = np.load(d / f"{name}.npy")
            if dtypes.get(name) == "bfloat16":
                arr = arr.view(ml_dtypes.bfloat16)
            if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
                arr = arr.astype(leaf.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None else
                       jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(target), out
        )
