"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(n_devices: int, model_parallel: int = 1):
    """Elastic mesh for whatever devices survive (fault-tolerant restart)."""
    assert n_devices % model_parallel == 0, (n_devices, model_parallel)
    return jax.make_mesh(
        (n_devices // model_parallel, model_parallel), ("data", "model")
    )


def data_axes_of(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axes_of(mesh) -> Tuple[str, ...]:
    return ("model",) if "model" in mesh.axis_names else ()
