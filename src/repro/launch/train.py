"""Training driver — runnable end-to-end on CPU with reduced configs, and
the same code path the production mesh lowers (the paper's single-source
property applied to the launcher).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b-smoke \
        --steps 50 --batch 8 --seq 64 [--ckpt-dir /tmp/ckpt] [--resume]

Features exercised: sharded GSPMD step (when a mesh is available),
gradient accumulation, checkpoint/restart, straggler observation hooks,
fault injection (--fail-at for the restart test).
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.data.synthetic import TokenStream, TokenStreamSpec
from repro.distributed.checkpoint import Checkpointer
from repro.distributed.fault_tolerance import FaultInjector, run_with_restarts
from repro.launch.steps import init_train_state, make_train_step
from repro.optim.optimizers import OptConfig


def make_batch(cfg, stream: TokenStream, step: int, batch: int, seq: int):
    inputs, targets = stream.batch(step)
    tokens = jnp.concatenate([inputs[:, :1], targets], axis=1)
    # train_loss expects tokens (B, S+1)
    tokens = jnp.concatenate([inputs, targets[:, -1:]], axis=1)
    out = {"tokens": tokens}
    if cfg.family == "vlm":
        out["vision"] = jnp.zeros(
            (batch, cfg.n_vision_tokens, cfg.d_model), cfg.dtype_()
        )
    if cfg.family == "encdec":
        out["frames"] = jnp.zeros((batch, max(seq // 4, 4), cfg.d_model),
                                  cfg.dtype_())
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a fault at this step (restart demo)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    opt = OptConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps)
    stream = TokenStream(
        TokenStreamSpec(cfg.vocab_size, args.seq, args.batch)
    )
    # no donate here: eagerly-initialized zero moments can share buffers
    # (XLA constant caching) and double-donation is an error; the AOT
    # dry-run path still donates for accurate memory analysis
    step_fn = jax.jit(make_train_step(cfg, opt, microbatches=args.microbatches))
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    injector = FaultInjector([args.fail_at] if args.fail_at else [])
    start = 0
    if ckpt and args.resume and ckpt.latest_step() is not None:
        start = ckpt.latest_step()
        state = ckpt.restore(start, state)
        print(f"resumed from step {start}")

    def train_range(start_step: int, state):
        t0 = time.time()
        for s in range(start_step, args.steps):
            injector.maybe_fail(s)
            batch = make_batch(cfg, stream, s, args.batch, args.seq)
            state, loss = step_fn(state, batch)
            if (s + 1) % args.log_every == 0:
                dt = (time.time() - t0) / args.log_every
                t0 = time.time()
                print(f"step {s+1}: loss={float(loss):.4f} ({dt*1e3:.0f} ms/step)")
            if ckpt and (s + 1) % args.ckpt_every == 0:
                ckpt.save(s + 1, state, blocking=False,
                          extra={"data_position": s + 1})
        if ckpt:
            ckpt.wait()
        return state, args.steps

    if ckpt:
        state, final, restarts = run_with_restarts(
            train_range, ckpt, state, max_restarts=2
        )
        if restarts:
            print(f"recovered from {restarts} failure(s) via checkpoint restart")
    else:
        state, final = train_range(start, state)
    print(f"done at step {final}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
