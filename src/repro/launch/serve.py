"""Serving driver — a thin CLI over the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b-smoke \
        --batch 4 --prompt-len 16 --gen 32

Greedy decode over the synthetic token distribution; reports tokens/s and
verifies the cache path incrementally matches teacher-forced prefill
(--check) — the serving analogue of the paper's layer-by-layer regression
testing.  LM families run through ``repro.serving.ServingEngine`` (device-
side control state, one host sync per batch of steps); families without
per-row decode state (vlm, encdec) fall back to the lockstep loop.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.models import lm as LM
from repro.models.model import build_model
from repro.serving import ServingEngine, configs_from_flags
from repro.serving.checks import assert_decode_matches_teacher_forced


def _serve_engine(model, params, prompt, args) -> int:
    """Continuous-batching path: every request enters through the queue."""
    max_len = args.prompt_len + args.gen + 1
    cache, config = configs_from_flags(args)
    eng = ServingEngine(
        model, params, batch=args.batch, max_len=max_len,
        cache=cache, config=config,
    )
    rids = [
        eng.submit(prompt[b].tolist(), args.gen) for b in range(args.batch)
    ]
    t0 = time.time()
    outs = eng.run()
    dt = time.time() - t0
    total_tokens = args.batch * (args.prompt_len + args.gen)
    print(f"decoded {args.gen} tokens x batch {args.batch} "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s incl. prefill, "
          f"{eng.steps} decode + {eng.prefill_steps} prefill steps)")
    if eng.ttft:
        ttft = sum(eng.ttft.values()) / len(eng.ttft)
        print(f"mean TTFT {1e3 * ttft:.1f} ms "
              f"(prefill chunk {args.prefill_chunk})")
    s = eng.stats()
    if "kv_pages" in s:   # attention-free archs have no pages to report
        print(f"paged KV: peak {int(s['kv_pages_peak'])}/{int(s['kv_pages'])} "
              f"pages ({int(s['kv_resident_bytes_peak'])} resident bytes)")
    if "snap_slots" in s:   # recurrent families under prefix sharing
        print(f"state snapshots: peak {int(s['snap_slots_peak'])}/"
              f"{int(s['snap_slots'])} page-boundary slots resident")
    if "shared_prompt_tokens" in s:
        print(f"prefix sharing: {int(s['shared_prompt_tokens'])} prompt "
              f"tokens served from shared pages/snapshots "
              f"({int(s['cow_pages'])} CoW copies)")
    if "spec_accept_rate" in s:
        print(f"speculation: {int(s['spec_accepted'])}/"
              f"{int(s['spec_proposed'])} drafts accepted "
              f"({s['spec_accept_rate']:.0%}), "
              f"{int(s['spec_emitted'])} tokens via verify steps")
    print("sample:", outs[rids[0]][:16].tolist())
    return 0


def _serve_lockstep(model, params, prompt, args, cfg) -> int:
    """Legacy lockstep loop for families without per-row decode state."""
    max_len = args.prompt_len + args.gen + 1
    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    state = model.init_decode_state(args.batch, max_len)
    if cfg.family == "vlm":
        vision = jnp.zeros((args.batch, cfg.n_vision_tokens, cfg.d_model),
                           cfg.dtype_())
        state = LM.prefill_vlm_cross_cache(cfg, params, vision, state)

    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):
        logits, state = decode(params, state, prompt[:, i])
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    generated = [tok]
    for _ in range(args.gen - 1):
        logits, state = decode(params, state, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    total_tokens = args.batch * (args.prompt_len + args.gen)
    print(f"decoded {args.gen} tokens x batch {args.batch} "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s incl. prefill)")
    gen = jnp.stack(generated, axis=1)
    print("sample:", gen[0, :16].tolist())
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--steps-per-sync", type=int, default=8)
    ap.add_argument("--layout", choices=["contiguous", "paged"],
                    default="contiguous",
                    help="KV-cache layout (paged: pool+block-table)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--n-pages", type=int, default=None,
                    help="page-pool size (default: batch*max_len/page_size)")
    ap.add_argument("--kv-dtype", choices=["f32", "bf16", "int8"],
                    default="f32",
                    help="KV-pool storage precision (needs --layout paged "
                         "below f32; bf16 = 1/2 the f32 resident bytes, "
                         "int8 = 1/4 via per-(page, head)-scaled payload)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; >0 samples with per-request keys")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--prefill-chunk", type=int, default=1,
                    help="prompt tokens ingested per engine step (chunked "
                         "prefill; 1 = token-by-token)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="page-level prompt prefix sharing (needs --layout "
                         "paged): attention families alias pages with "
                         "copy-on-write; recurrent families (ssm/hybrid) "
                         "restore page-boundary state snapshots")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft K tokens per row per "
                         "step and verify them through the chunked prefill "
                         "path (0 = off; needs --prefill-chunk >= 2, "
                         "greedy only)")
    ap.add_argument("--spec-drafter", default="prompt_lookup",
                    choices=["prompt_lookup", "hybrid_ssm"],
                    help="draft source: n-gram prompt lookup (any family) "
                         "or the hybrid family's own Mamba layers")
    ap.add_argument("--spec-ngram", type=int, default=2,
                    help="prompt-lookup n-gram match length")
    ap.add_argument("--check", action="store_true",
                    help="verify decode path against teacher-forced forward")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )

    if cfg.family in ("dense", "moe", "ssm", "hybrid"):
        rc = _serve_engine(model, params, prompt, args)
    else:
        if (args.layout != "contiguous" or args.temperature > 0 or args.top_k
                or args.prefix_sharing or args.spec_k):
            print(f"warning: --layout/--temperature/--top-k/--prefix-sharing/"
                  f"--spec-k are engine features; the {cfg.family} fallback "
                  f"loop is lockstep greedy over the contiguous cache and "
                  f"ignores them")
        rc = _serve_lockstep(model, params, prompt, args, cfg)

    if args.check and cfg.family in ("dense", "moe", "ssm", "hybrid"):
        assert_decode_matches_teacher_forced(
            model, params, prompt, args.prompt_len + args.gen + 1
        )
        print("decode path matches teacher-forced forward ✓")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
