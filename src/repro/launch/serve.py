"""Serving driver — batched prefill + decode with KV/SSM caches.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b-smoke \
        --batch 4 --prompt-len 16 --gen 32

Greedy decode over the synthetic token distribution; reports tokens/s and
verifies the cache path incrementally matches teacher-forced prefill
(--check) — the serving analogue of the paper's layer-by-layer regression
testing.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.models import lm as LM
from repro.models.model import build_model


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--check", action="store_true",
                    help="verify decode path against teacher-forced forward")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init_params(rng)
    max_len = args.prompt_len + args.gen + 1
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )

    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    state = model.init_decode_state(args.batch, max_len)
    if cfg.family == "vlm":
        vision = jnp.zeros((args.batch, cfg.n_vision_tokens, cfg.d_model),
                           cfg.dtype_())
        state = LM.prefill_vlm_cross_cache(cfg, params, vision, state)

    # prompt consumption through the decode path (incremental prefill)
    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):
        logits, state = decode(params, state, prompt[:, i])
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    generated = [tok]
    for _ in range(args.gen - 1):
        logits, state = decode(params, state, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    total_tokens = args.batch * (args.prompt_len + args.gen)
    print(f"decoded {args.gen} tokens x batch {args.batch} "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s incl. prefill)")
    gen = jnp.stack(generated, axis=1)
    print("sample:", gen[0, :16].tolist())

    if args.check and cfg.family in ("dense", "moe", "ssm", "hybrid"):
        # teacher-forced: logits at last prompt position must match decode's
        h = LM.forward(cfg, params, prompt, remat=False)
        want = LM.lm_logits(cfg, params, h[:, -1:, :])[:, 0]
        state2 = model.init_decode_state(args.batch, max_len)
        got = None
        for i in range(args.prompt_len):
            got, state2 = model.decode_step(params, state2, prompt[:, i])
        import numpy as np

        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=2e-2, atol=2e-2,
        )
        print("decode path matches teacher-forced forward ✓")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
