"""Step builders: jit-able train_step / serve_step closures per cell.

``make_train_step`` is the GSPMD path (pjit + sharding constraints).
``make_manual_dp_train_step`` is the shard_map path with explicit,
optionally *compressed* gradient psum — the distributed-optimization
feature the GSPMD path can't express (wire-format compression).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.model import Model, build_model
from repro.optim import compress as GC
from repro.optim.optimizers import OptConfig, apply_updates, init_opt_state


def make_train_step(
    cfg: ArchConfig, opt: OptConfig, *, microbatches: int = 1
) -> Callable:
    """GSPMD train step; microbatches > 1 = gradient accumulation (scan over
    batch slices) — divides activation residency by the microbatch count."""
    model = build_model(cfg)

    def train_step(state: Dict[str, Any], batch: Dict[str, jax.Array]):
        params, opt_state = state["params"], state["opt"]
        if microbatches == 1:
            loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc_step(carry, mbatch):
                loss_i, g_i = jax.value_and_grad(model.train_loss)(
                    params, mbatch
                )
                l, g = carry
                return (l + loss_i, jax.tree.map(jnp.add, g, g_i)), None

            zero = (
                jnp.zeros((), jnp.float32),
                jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                ),
            )
            (loss, grads), _ = jax.lax.scan(acc_step, zero, mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        new_params, new_opt = apply_updates(opt, grads, opt_state, cfg.dtype_())
        return {"params": new_params, "opt": new_opt}, loss

    return train_step


def make_serve_decode_step(cfg: ArchConfig) -> Callable:
    model = build_model(cfg)

    def serve_step(params, state, token):
        logits, state = model.decode_step(params, state, token)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, state

    return serve_step


def make_serve_prefill(cfg: ArchConfig) -> Callable:
    model = build_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def init_train_state(cfg: ArchConfig, opt: OptConfig, rng) -> Dict[str, Any]:
    model = build_model(cfg)
    params = model.init_params(rng)
    return {"params": params, "opt": init_opt_state(opt, params)}


def train_state_shape(cfg: ArchConfig, opt: OptConfig):
    """ShapeDtypeStruct pytree of the train state (no allocation)."""
    return jax.eval_shape(
        lambda: init_train_state(cfg, opt, jax.random.PRNGKey(0))
    )


def decode_state_shape(cfg: ArchConfig, batch: int, max_len: int):
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init_decode_state(batch, max_len))


# ---------------------------------------------------------------------------
# Manual-DP (shard_map) path with compressed gradient collectives
# ---------------------------------------------------------------------------

def make_manual_dp_train_step(
    cfg: ArchConfig, opt: OptConfig, mesh, codec: str = "bf16"
) -> Callable:
    """Pure data-parallel train step over the flattened device axis.

    Params replicated; per-shard grads psum'ed with wire compression +
    error feedback (state carried in opt_state['ef']).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    model = build_model(cfg)
    axis = mesh.axis_names
    flat_axes = tuple(axis)

    def step(state, batch):
        def worker(state, batch):
            params, opt_state = state["params"], state["opt"]
            loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            red, new_ef = GC.psum_compressed(
                grads, opt_state["ef"], flat_axes[0], codec
            )
            for a in flat_axes[1:]:
                red = jax.tree.map(
                    lambda g: jax.lax.pmean(g, a), red
                )
            new_params, new_opt = apply_updates(
                opt, red, {k: v for k, v in opt_state.items() if k != "ef"},
                cfg.dtype_(),
            )
            new_opt["ef"] = new_ef
            loss = jax.lax.pmean(loss, flat_axes)
            return {"params": new_params, "opt": new_opt}, loss

        rep = jax.tree.map(lambda _: P(), state)
        bspec = jax.tree.map(lambda _: P(flat_axes[0]), batch)
        return shard_map(
            worker, mesh=mesh,
            in_specs=(rep, bspec),
            out_specs=(rep, P()),
            check_rep=False,
        )(state, batch)

    return step
