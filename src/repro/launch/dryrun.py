"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell on
512 placeholder host devices and extract the roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--resume]

The os.environ assignment below is the FIRST executable statement — before
ANY other import — because jax locks the device count on first init.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import pathlib
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, input_specs
from repro.configs.registry import all_archs, get_arch
from repro.distributed.sharding import use_mesh
from repro.launch import shardings as SH
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    decode_state_shape,
    make_serve_decode_step,
    make_serve_prefill,
    make_train_step,
    train_state_shape,
)
from repro.optim.optimizers import OptConfig
from repro.roofline import analysis as RA


def _mem_stats(compiled) -> dict:
    try:
        m = compiled.memory_analysis()
        return {
            "argument_bytes": m.argument_size_in_bytes,
            "output_bytes": m.output_size_in_bytes,
            "temp_bytes": m.temp_size_in_bytes,
            "generated_code_bytes": m.generated_code_size_in_bytes,
            "alias_bytes": m.alias_size_in_bytes,
        }
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def _cost(compiled) -> dict:
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0]
    return dict(c) if c else {}


def dryrun_cell(
    arch: str, shape_name: str, multi_pod: bool, *, microbatches: int = 1
) -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    if not cfg.supports(shape_name):
        return {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "status": "skip",
            "reason": "full-attention arch: long_500k out of contract "
                      "(see DESIGN.md §Arch-applicability)",
        }
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    data_axes = ("pod", "data") if multi_pod else ("data",)
    chips = mesh.devices.size
    opt = OptConfig()
    t0 = time.time()
    with use_mesh(mesh, data_axes=data_axes, sequence_parallel=True):
        if shape.kind == "train":
            step = make_train_step(cfg, opt, microbatches=microbatches)
            state_shape = train_state_shape(cfg, opt)
            batch_shape = input_specs(cfg, shape)
            state_sh = {
                "params": SH.param_shardings(mesh, cfg, state_shape["params"]),
                "opt": SH.opt_state_shardings(mesh, cfg, state_shape["opt"]),
            }
            batch_sh = SH.batch_shardings(mesh, cfg, batch_shape)
            jfn = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            )
            lowered = jfn.lower(state_shape, batch_shape)
            model_flops = RA.model_flops_train(cfg, shape)
        elif shape.kind == "prefill":
            step = make_serve_prefill(cfg)
            pshape = jax.eval_shape(
                lambda: __import__("repro.models.model", fromlist=["build_model"])
                .build_model(cfg).init_params(jax.random.PRNGKey(0))
            )
            batch_shape = input_specs(cfg, shape)
            p_sh = SH.param_shardings(mesh, cfg, pshape)
            batch_sh = SH.batch_shardings(mesh, cfg, batch_shape)
            jfn = jax.jit(step, in_shardings=(p_sh, batch_sh))
            lowered = jfn.lower(pshape, batch_shape)
            model_flops = 2.0 * cfg.active_param_count() * (
                shape.seq_len * shape.global_batch
            )
        else:  # decode
            step = make_serve_decode_step(cfg)
            pshape = jax.eval_shape(
                lambda: __import__("repro.models.model", fromlist=["build_model"])
                .build_model(cfg).init_params(jax.random.PRNGKey(0))
            )
            state_shape = decode_state_shape(
                cfg, shape.global_batch, shape.seq_len
            )
            tok_shape = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
            p_sh = SH.param_shardings(mesh, cfg, pshape)
            st_sh = SH.decode_state_shardings(mesh, cfg, shape, state_shape)
            tok_sh = SH.token_shardings(mesh, cfg, shape)
            jfn = jax.jit(
                step,
                in_shardings=(p_sh, st_sh, tok_sh),
                out_shardings=(tok_sh, st_sh),
                donate_argnums=(1,),
            )
            lowered = jfn.lower(pshape, state_shape, tok_shape)
            model_flops = RA.model_flops_decode(cfg, shape)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = _mem_stats(compiled)
    cost = _cost(compiled)
    hlo = compiled.as_text()
    roof = RA.analyze(
        arch, shape_name, mesh_name, chips, cost, hlo, model_flops,
        bytes_per_device=(
            mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
            + mem.get("output_bytes", 0) - mem.get("alias_bytes", 0)
        ),
    )
    rec = {
        "status": "ok",
        "coll_by_kind": getattr(RA.analyze, "last_by_kind", {}),
        "memory": mem,
        "cost_flops": cost.get("flops"),
        "cost_bytes": cost.get("bytes accessed"),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        **roof.to_dict(),
    }
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="",
                    help="suffix for the output file (perf iterations)")
    args = ap.parse_args()
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = []
    if args.all:
        for arch in all_archs():
            for shape in SHAPES:
                cells.append((arch, shape, False))
                cells.append((arch, shape, True))
    else:
        cells.append((args.arch, args.shape, args.multi_pod))

    failures = 0
    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
        if args.tag:
            tag += f"__{args.tag}"
        path = outdir / f"{tag}.json"
        if args.resume and path.exists():
            print(f"[skip existing] {tag}")
            continue
        print(f"[dryrun] {tag} ...", flush=True)
        t0 = time.time()
        try:
            rec = dryrun_cell(arch, shape, mp,
                              microbatches=args.microbatches)
        except Exception as e:
            rec = {
                "arch": arch, "shape": shape,
                "mesh": "2x16x16" if mp else "16x16",
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:],
            }
            failures += 1
        rec["wall_s"] = round(time.time() - t0, 1)
        path.write_text(json.dumps(rec, indent=2, default=str))
        print(f"  -> {rec['status']} ({rec['wall_s']}s)", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
