"""Cell-specific sharding builders: (arch × shape × mesh) -> sharding pytrees.

Parameter rules come from ``repro.distributed.sharding`` with per-arch
overrides (e.g. MoE expert placement depends on whether n_experts divides
the model axis).  Serve-state rules are shape-aware: KV caches shard batch
over ``data`` when the batch is wide, and sequence over ``data`` (context
parallelism) for the single-sequence long_500k cell.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.distributed import sharding as S


def _ns(mesh: Mesh, *axes) -> NamedSharding:
    return NamedSharding(mesh, S.pspec(axes))


def _axes_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        entry = (entry,)
    n = 1
    for a in entry:
        n *= mesh.shape[a]
    return n


def sanitize(mesh: Mesh, spec: P, shape) -> P:
    """pjit in_shardings demand divisibility; drop axes that don't divide."""
    ent = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, e in zip(shape, ent):
        out.append(e if dim % max(_axes_size(mesh, e), 1) == 0 else None)
    return P(*out)


def param_specs(cfg: ArchConfig, params_shape) -> "jax.tree":
    """PartitionSpec pytree for a params (or ShapeDtypeStruct) pytree."""
    moe_ep = cfg.n_experts and cfg.n_experts % 16 == 0

    def spec(path, leaf):
        s = S._path_str(path)
        ndim = getattr(leaf, "ndim", len(getattr(leaf, "shape", ())))
        if any(k in s for k in ("wg", "wi", "wo", "router")) and cfg.n_experts:
            # MoE expert tensors carry a leading E axis (after layer stacking)
            if "router" in s:
                return S.pspec([None] * (ndim - 2) + ["data", None])
            if moe_ep:
                # EP over model; FSDP over the NON-contracted (output) dim —
                # sharding the contracted d over data forced activation-sized
                # partial-sum all-reduces (perf iteration M3, §Perf)
                return S.pspec([None] * (ndim - 3) + ["model", None, "data"])
            # TP fallback: ff over model, d over data, experts replicated
            if s.endswith("wo") and ndim >= 3 and cfg.family == "moe":
                return S.pspec([None] * (ndim - 3) + [None, "model", "data"])
            if ndim >= 3 and cfg.family == "moe":
                return S.pspec([None] * (ndim - 3) + [None, "data", "model"])
        return S.spec_for_param(path, leaf)

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def param_shardings(mesh: Mesh, cfg: ArchConfig, params_shape):
    specs = param_specs(cfg, params_shape)
    return jax.tree.map(
        lambda sp, leaf: NamedSharding(mesh, sanitize(mesh, sp, leaf.shape)),
        specs,
        params_shape,
        is_leaf=lambda x: isinstance(x, P),
    )


def opt_state_shardings(mesh: Mesh, cfg: ArchConfig, opt_shape):
    """Optimizer state mirrors the param shardings; the step is replicated."""
    out = {}
    for k, sub in opt_shape.items():
        if k == "step":
            out[k] = NamedSharding(mesh, P())
        else:
            out[k] = param_shardings(mesh, cfg, sub)
    return out


def batch_shardings(mesh: Mesh, cfg: ArchConfig, batch_shape):
    """Training/prefill batch: leading batch dim over the data axes."""
    def spec(path, leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        sp = S.pspec(["data"] + [None] * (nd - 1))
        return NamedSharding(mesh, sanitize(mesh, sp, leaf.shape))

    return jax.tree_util.tree_map_with_path(spec, batch_shape)


def decode_state_shardings(
    mesh: Mesh, cfg: ArchConfig, shape: ShapeSpec, state_shape
):
    """Serve caches. batch >= data-axis size -> batch-sharded; else the
    long-context cell shards the sequence/state dims (context parallelism)."""
    data_size = 1
    for a in mesh.axis_names:
        if a in ("pod", "data"):
            data_size *= mesh.shape[a]
    wide_batch = shape.global_batch >= data_size

    def spec(path, leaf):
        s = S._path_str(path)
        nd = len(leaf.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        if s.startswith("pos"):
            return NamedSharding(mesh, P())
        if s in ("k", "v", "xk", "xv"):
            # (..., B, S, Hkv, hd): last two dims are heads/hd
            axes = [None] * nd
            b_ix, s_ix, h_ix = nd - 4, nd - 3, nd - 2
            tp = mesh.shape.get("model", 1)
            if cfg.n_kv_heads and cfg.n_kv_heads % tp == 0:
                axes[h_ix] = "model"
            elif leaf.shape[s_ix] % tp == 0:
                # GQA kv-heads < TP degree: context-parallel cache — shard
                # the sequence over the model axis instead (flash-decoding
                # style partial-softmax combine; perf iteration D1, §Perf)
                axes[s_ix] = "model"
            if wide_batch:
                axes[b_ix] = "data"
            elif axes[s_ix] is None and leaf.shape[s_ix] % 16 == 0:
                axes[s_ix] = "data"       # context-parallel over data too
            return NamedSharding(mesh, S.pspec(axes))
        if s == "ssm":
            # (L, B, H, P, N)
            axes = [None, "data" if wide_batch else None, "model", None, None]
            if cfg.ssm_heads % 16 != 0:
                axes[2] = None
            return NamedSharding(mesh, S.pspec(axes))
        if s == "conv":
            # (L, B, K-1, di)
            axes = [None, "data" if wide_batch else None, None,
                    "model" if cfg.d_inner % 16 == 0 else None]
            return NamedSharding(mesh, S.pspec(axes))
        return NamedSharding(mesh, P())

    def sane(path, leaf):
        ns = spec(path, leaf)
        return NamedSharding(mesh, sanitize(mesh, ns.spec, leaf.shape))

    return jax.tree_util.tree_map_with_path(sane, state_shape)


def token_shardings(mesh: Mesh, cfg: ArchConfig, shape: ShapeSpec):
    data_size = 1
    for a in mesh.axis_names:
        if a in ("pod", "data"):
            data_size *= mesh.shape[a]
    if shape.global_batch >= data_size:
        return NamedSharding(mesh, S.pspec(["data"]))
    return NamedSharding(mesh, P())
