"""The paper's CIFAR-10 quick network (Caffe cifar10_quick prototxt)."""
from repro.caffe.lenet import lenet_cifar10, lenet_cifar10_solver

NET = lenet_cifar10()
SOLVER = lenet_cifar10_solver()
