"""The paper's own LeNet-MNIST network (Caffe lenet_train_test.prototxt)."""
from repro.caffe.lenet import lenet_mnist, lenet_mnist_solver

NET = lenet_mnist()
SOLVER = lenet_mnist_solver()
