"""Architecture + input-shape configuration schema.

One ``ArchConfig`` per assigned architecture (exact numbers from the
assignment table), plus the paper's own LeNet configs.  ``reduced()``
derives the smoke-test variant (same family, tiny dims).  ``input_specs``
produces jax.ShapeDtypeStruct stand-ins for the dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    # attention details
    head_dim: int = 0              # 0 -> d_model // n_heads
    window: Optional[int] = None   # sliding-window attention (mixtral)
    qkv_bias: bool = False         # qwen2.5
    rope_theta: float = 10_000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # hybrid (zamba2): one *shared* attention block applied every N layers
    attn_every: int = 0
    # enc-dec (seamless)
    encoder_layers: int = 0
    # vlm (llama-3.2-vision): cross-attention every N layers
    cross_attn_every: int = 0
    n_vision_tokens: int = 0
    # norm / misc
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    sub_quadratic: bool = False    # eligible for long_500k
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:       # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def dtype_(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def supports(self, shape: str) -> bool:
        """Which assigned shapes this arch runs (skips are per-assignment)."""
        if shape == "long_500k":
            return self.sub_quadratic
        return True

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        small = dict(
            # f32: XLA:CPU cannot execute bf16 batched dots (full configs
            # stay bf16 — they are only compiled, via the dry-run)
            dtype="float32",
            n_layers=max(2, min(4, self.n_layers)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // self.n_heads))
            if self.n_heads
            else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=128,
            head_dim=16,
        )
        if self.n_experts:
            small.update(n_experts=4, top_k=min(2, self.top_k))
        if self.ssm_state:
            small.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
        if self.attn_every:
            small.update(attn_every=2, n_layers=4)
        if self.encoder_layers:
            small.update(encoder_layers=2)
        if self.cross_attn_every:
            small.update(cross_attn_every=2, n_layers=4, n_vision_tokens=8)
        if self.window:
            small["window"] = 32
        return dataclasses.replace(self, name=self.name + "-smoke", **small)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab_size
        hd = self.head_dim_
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.family == "ssm":
            # mamba2 block: in_proj (d -> 2*d_inner + 2*G*N + H), conv, out
            di, n, h = self.d_inner, self.ssm_state, self.ssm_heads
            per_layer = d * (2 * di + 2 * n + h) + di * self.ssm_conv + di * d \
                + 2 * d  # norms
            return emb + self.n_layers * per_layer
        if self.family == "hybrid":
            di, n, h = self.d_inner, self.ssm_state, self.ssm_heads
            per_m = d * (2 * di + 2 * n + h) + di * self.ssm_conv + di * d + 2 * d
            shared_attn = per_attn + 3 * d * self.d_ff + 2 * d
            return emb + self.n_layers * per_m + shared_attn
        per_mlp = 3 * d * self.d_ff
        if self.n_experts:
            per_mlp = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        per_layer = per_attn + per_mlp + 2 * d
        total = emb + self.n_layers * per_layer
        if self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
            total += n_cross * (per_attn + 2 * d)
        if self.encoder_layers:
            # encoder self-attn+mlp, decoder adds cross-attn
            total += self.encoder_layers * (per_attn + per_mlp + 2 * d)
            total += self.n_layers * (per_attn + d)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        dense_like = self.param_count() - self.n_layers * (
            self.n_experts * 3 * d * self.d_ff
        )
        return dense_like + self.n_layers * self.top_k * 3 * d * self.d_ff


def input_specs(
    cfg: ArchConfig, shape: ShapeSpec, *, batch_override: Optional[int] = None
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b = batch_override or shape.global_batch
    s = shape.seq_len
    i32 = jnp.int32
    dt = cfg.dtype_()
    if shape.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s + 1), i32)}
        if cfg.family == "vlm":
            specs["vision"] = jax.ShapeDtypeStruct(
                (b, cfg.n_vision_tokens, cfg.d_model), dt
            )
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct((b, s // 4, cfg.d_model), dt)
            specs["tokens"] = jax.ShapeDtypeStruct((b, s + 1), i32)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "vlm":
            specs["vision"] = jax.ShapeDtypeStruct(
                (b, cfg.n_vision_tokens, cfg.d_model), dt
            )
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct((b, s // 4, cfg.d_model), dt)
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        return specs
    # decode: one new token against a cache of length seq_len
    specs = {
        "token": jax.ShapeDtypeStruct((b,), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }
    return specs
