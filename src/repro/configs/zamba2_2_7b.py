"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,               # mamba2 blocks
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,             # assignment: kv=32 (MHA in the shared block)
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    attn_every=6,              # one shared attn+mlp block every 6 mamba blocks
    sub_quadratic=True,        # hybrid: runs long_500k
    source="arXiv:2411.15242; hf",
)
