"""llama-3.2-vision-90b [vlm] — cross-attn image layers.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,              # 80 self + 20 cross (every 5th is cross-attn)
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,              # GQA kv=8
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500_000.0,
    cross_attn_every=5,
    n_vision_tokens=1601,      # (448/14)^2 + 1 patch embeddings (stub frontend)
    sub_quadratic=False,       # full attention -> long_500k skipped
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
