"""seamless-m4t-medium [audio] — enc-dec backbone; modality frontend is a
stub (input_specs provides precomputed frame embeddings).
[arXiv:2308.11596; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,               # decoder layers
    encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    sub_quadratic=False,
    source="arXiv:2308.11596; hf",
)
