"""Architecture registry: --arch <id> resolution for launchers/tests."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig

_ARCH_MODULES = {
    "llama-3.2-vision-90b": "repro.configs.llama_3_2_vision_90b",
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "glm4-9b": "repro.configs.glm4_9b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
}


def arch_ids() -> List[str]:
    return list(_ARCH_MODULES)


def get_arch(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return get_arch(name[: -len("-smoke")]).reduced()
    try:
        mod = importlib.import_module(_ARCH_MODULES[name])
    except KeyError as e:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}"
        ) from e
    return mod.CONFIG


def all_archs() -> Dict[str, ArchConfig]:
    return {k: get_arch(k) for k in _ARCH_MODULES}
