"""Encoder-decoder family (seamless-m4t-medium backbone).

The audio frontend is a stub per the assignment: ``frames`` are precomputed
frame embeddings (B, S_enc, d).  Encoder: non-causal self-attention stack.
Decoder: causal self-attention + cross-attention to encoder memory + MLP.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.kernels import ops
from repro.models import components as C
from repro.models.lm import _cache_update, _cache_update_chunk, _stacked, _xent


def init_params(cfg: ArchConfig, rng) -> Dict[str, Any]:
    dt = cfg.dtype_()
    r_emb, r_enc, r_dec, r_head = jax.random.split(rng, 4)
    def enc_layer(r):
        r1, r2 = jax.random.split(r)
        return {"attn": C.init_attention(cfg, r1), "mlp": C.init_mlp(cfg, r2)}
    def dec_layer(r):
        r1, r2, r3 = jax.random.split(r, 3)
        return {
            "attn": C.init_attention(cfg, r1),
            "cross": C.init_attention(cfg, r2),
            "mlp": C.init_mlp(cfg, r3),
        }
    return {
        "embed": (
            jax.random.normal(r_emb, (cfg.vocab_size, cfg.d_model)) * 0.02
        ).astype(dt),
        "enc_layers": _stacked(enc_layer, r_enc, cfg.encoder_layers),
        "dec_layers": _stacked(dec_layer, r_dec, cfg.n_layers),
        "ln_enc": jnp.ones((cfg.d_model,), dt),
        "ln_f": jnp.ones((cfg.d_model,), dt),
        "lm_head": (
            jax.random.normal(r_head, (cfg.d_model, cfg.vocab_size))
            / np.sqrt(cfg.d_model)
        ).astype(dt),
    }


def encode(cfg: ArchConfig, params, frames: jax.Array, *, remat=True):
    x = shard(frames.astype(cfg.dtype_()), ("data", None, None))
    pos = jnp.arange(x.shape[1])

    def layer(x, p):
        x = C.attention_block(cfg, p["attn"], x, positions=pos, causal=False)
        return shard(C.mlp_block(cfg, p["mlp"], x), ("data", "sp", None)), None

    if remat:
        layer = jax.checkpoint(layer)
    x, _ = jax.lax.scan(layer, x, params["enc_layers"])
    return C.norm(cfg, params["ln_enc"], x)


def decode_train(cfg: ArchConfig, params, tokens: jax.Array, memory: jax.Array,
                 *, remat=True):
    x = params["embed"][tokens].astype(cfg.dtype_())
    x = shard(x, ("data", None, None))
    pos = jnp.arange(x.shape[1])

    def layer(x, p):
        x = C.attention_block(cfg, p["attn"], x, positions=pos, causal=True)
        x = C.attention_block(cfg, p["cross"], x, kv_src=memory, causal=False)
        return shard(C.mlp_block(cfg, p["mlp"], x), ("data", "sp", None)), None

    if remat:
        layer = jax.checkpoint(layer)
    x, _ = jax.lax.scan(layer, x, params["dec_layers"])
    return C.norm(cfg, params["ln_f"], x)


def train_loss(cfg: ArchConfig, params, batch: Dict[str, jax.Array]):
    frames, tokens = batch["frames"], batch["tokens"]
    memory = encode(cfg, params, frames)
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    h = decode_train(cfg, params, inputs, memory)
    logits = C.dense(h, params["lm_head"])
    logits = shard(logits, ("data", None, "model"))
    return _xent(logits, targets)


# -- serving ---------------------------------------------------------------

def init_decode_state(cfg: ArchConfig, batch: int, max_len: int, enc_len: int,
                      *, per_row_pos: bool = False, snapshots: bool = False,
                      cache=None):
    """Decode state.  ``per_row_pos=True`` keeps ``pos`` as a (B,) vector —
    signature parity with ``lm.init_decode_state`` so the serving engine's
    slot-refill path (per-row depths, masked cache writes) is not
    attention-LM-only by accident.  ``snapshots`` is accepted for the same
    parity and ignored: encdec carries no recurrent decode state (the lm
    dense-family semantics).  ``cache=`` accepts a
    ``repro.serving.config.CacheConfig`` (duck-typed — models never import
    serving) for config-object parity with ``lm``; encdec implements only
    the contiguous slab, so a paged layout is rejected here rather than
    silently ignored."""
    del snapshots
    if cache is not None and cache.layout != "contiguous":
        raise NotImplementedError(
            "encdec decode state is contiguous-only — "
            f"cache.layout {cache.layout!r} is not supported"
        )
    dt = cfg.dtype_()
    hkv, hd = cfg.n_kv_heads, cfg.head_dim_
    L = cfg.n_layers
    return {
        "pos": jnp.zeros((batch,) if per_row_pos else (), jnp.int32),
        "k": jnp.zeros((L, batch, max_len, hkv, hd), dt),
        "v": jnp.zeros((L, batch, max_len, hkv, hd), dt),
        # cross K/V precomputed from encoder memory at prefill
        "xk": jnp.zeros((L, batch, enc_len, hkv, hd), dt),
        "xv": jnp.zeros((L, batch, enc_len, hkv, hd), dt),
    }


def reset_decode_rows(
    cfg: ArchConfig, state: Dict[str, jax.Array], mask: jax.Array,  # (B,) bool
    start: jax.Array = 0,                                  # () or (B,) int32
) -> Dict[str, jax.Array]:
    """Zero the selected rows' decode caches in place — signature parity
    with ``lm.reset_decode_rows`` (including the prefix-sharing ``start``
    offset that places the reset rows' decode clock) so slot refill is not
    attention-LM-only by accident.  The cross K/V rows are zeroed too: a
    refilled slot serves a new utterance, whose encoder memory is written
    by ``prefill_cross_cache`` at admission.  Requires ``per_row_pos``
    state."""
    if state["pos"].ndim != 1:
        raise ValueError(
            "reset_decode_rows needs per_row_pos=True decode state"
        )
    unknown = set(state) - {"pos", "k", "v", "xk", "xv"}
    if unknown:
        # fail loudly: a silently-skipped cache key would leak the previous
        # request's state into the slot's next occupant (same contract as
        # lm.reset_decode_rows)
        raise ValueError(
            f"reset_decode_rows: unhandled decode-state keys {sorted(unknown)}"
            " — declare their batch axis here before serving with them"
        )
    out = dict(state)
    out["pos"] = jnp.where(mask, jnp.asarray(start, jnp.int32), state["pos"])
    for key in ("k", "v", "xk", "xv"):
        v = state[key]
        shape = [1] * v.ndim
        shape[1] = mask.shape[0]               # (L, B, S, Hkv, hd) caches
        out[key] = jnp.where(
            mask.reshape(shape), jnp.zeros((), v.dtype), v
        )
    return out


def prefill_cross_cache(cfg: ArchConfig, params, memory, state):
    hkv, hd = cfg.n_kv_heads, cfg.head_dim_

    def per_layer(p):
        pa = p["cross"]
        src = C.norm(cfg, pa["ln"], memory)
        k = C.dense(src, pa["wk"]).reshape(*memory.shape[:2], hkv, hd)
        v = C.dense(src, pa["wv"]).reshape(*memory.shape[:2], hkv, hd)
        return k, v

    xk, xv = jax.vmap(per_layer)(params["dec_layers"])
    return {**state, "xk": xk, "xv": xv}


def prefill_chunk(cfg: ArchConfig, params, state, toks: jax.Array,  # (B, C)
                  width: jax.Array,                    # () or (B,) int32
                  *, active: Optional[jax.Array] = None,
                  cow: bool = False, snap_every: int = 0):
    """Multi-token prompt ingestion — signature parity with
    ``lm.prefill_chunk`` so chunked prefill is not attention-LM-only by
    accident.  Self-attention runs the chunked kernel against the causal
    cache; cross-attention anchors every chunk query at the last encoder
    position, which makes the causal mask vacuous (full non-causal
    attention over the precomputed memory K/V).  Requires ``per_row_pos``
    decode state.  ``cow``/``snap_every`` are accepted for signature
    parity and are no-ops: the encdec cache is contiguous per-row (no
    shared pages to un-share) and attention-only (no recurrent state to
    snapshot)."""
    del cow, snap_every
    pos = state["pos"]
    if pos.ndim != 1:
        raise ValueError("prefill_chunk needs per_row_pos=True decode state")
    b, c = toks.shape
    if active is None:
        active = jnp.ones((b,), bool)
    width = jnp.clip(
        jnp.broadcast_to(jnp.asarray(width, jnp.int32).reshape(-1), (b,)),
        1, c,
    )
    x = params["embed"][toks].astype(cfg.dtype_())
    offs = jnp.arange(c, dtype=jnp.int32)[None, :]
    posmat = pos[:, None] + offs                       # (B, C)
    valid = active[:, None] & (offs < width[:, None])
    enc_len = state["xk"].shape[2]
    enc_start = jnp.full((b,), enc_len - 1, jnp.int32)
    enc_one = jnp.ones((b,), jnp.int32)
    hd = cfg.head_dim_

    def body(x, inp):
        p, ck, cv, xk, xv = inp
        hkv = cfg.n_kv_heads
        # causal self-attention with chunked cache writes
        pa = p["attn"]
        xn = C.norm(cfg, pa["ln"], x)
        q = C.dense(xn, pa["wq"]).reshape(b, c, cfg.n_heads, hd)
        kn = C.dense(xn, pa["wk"]).reshape(b, c, hkv, hd)
        vn = C.dense(xn, pa["wv"]).reshape(b, c, hkv, hd)
        cos, sin = C.rope_freqs(cfg, posmat)
        q = C.apply_rope(q, cos, sin)
        kn = C.apply_rope(kn, cos, sin)
        ck = _cache_update_chunk(ck, kn, posmat, valid)
        cv = _cache_update_chunk(cv, vn, posmat, valid)
        o = ops.attention_prefill_chunk(q, ck, cv, pos, width)
        x = x + C.dense(o.reshape(b, c, -1), pa["wo"])
        # cross-attention to encoder memory: width 1 pins every chunk
        # query at qpos = enc_len - 1, i.e. full non-causal attention —
        # and keeps padded cache tails (kpos >= enc_len) masked
        pc = p["cross"]
        xn = C.norm(cfg, pc["ln"], x)
        q = C.dense(xn, pc["wq"]).reshape(b, c, cfg.n_heads, hd)
        o = ops.attention_prefill_chunk(q, xk, xv, enc_start, enc_one)
        x = x + C.dense(o.reshape(b, c, -1), pc["wo"])
        # mlp
        pm = p["mlp"]
        xn = C.norm(cfg, pm["ln"], x)
        h = jax.nn.silu(C.dense(xn, pm["wg"])) * C.dense(xn, pm["wi"])
        x = x + C.dense(h, pm["wo"])
        return x, (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        body, x,
        (params["dec_layers"], state["k"], state["v"], state["xk"], state["xv"]),
    )
    last = jnp.take_along_axis(x, (width - 1)[:, None, None], axis=1)[:, 0]
    h = C.norm(cfg, params["ln_f"], last)
    logits = C.dense(h, params["lm_head"])
    return logits, {**state, "k": ks, "v": vs,
                    "pos": pos + jnp.where(active, width, 0)}


def decode_step(cfg: ArchConfig, params, state, token: jax.Array,
                *, active: Optional[jax.Array] = None,
                cow: bool = False, snap_every: int = 0):
    # cow/snap_every: signature parity with lm.decode_step (see
    # prefill_chunk) — no paged or recurrent state to apply them to
    del cow, snap_every
    pos = state["pos"]
    x = params["embed"][token].astype(cfg.dtype_())
    enc_len = state["xk"].shape[2]
    hd = cfg.head_dim_
    rope_pos = pos[..., None] if pos.ndim == 1 else pos[None]
    # per-row depths (continuous batching): masked writes, inactive rows
    # routed to slot -1 (dropped) — same idiom as the LM decode path
    if active is not None and pos.ndim == 1:
        w_idx = jnp.where(active, pos, -1)
    else:
        w_idx = pos

    def body(x, inp):
        p, ck, cv, xk, xv = inp
        b = x.shape[0]
        hkv = cfg.n_kv_heads
        # causal self-attention with cache
        pa = p["attn"]
        xn = C.norm(cfg, pa["ln"], x)
        q = C.dense(xn, pa["wq"]).reshape(b, cfg.n_heads, hd)
        kn = C.dense(xn, pa["wk"]).reshape(b, hkv, hd)
        vn = C.dense(xn, pa["wv"]).reshape(b, hkv, hd)
        cos, sin = C.rope_freqs(cfg, rope_pos)
        q = C.apply_rope(q.reshape(b, 1, -1, hd), cos, sin).reshape(b, -1, hd)
        kn = C.apply_rope(kn.reshape(b, 1, hkv, hd), cos, sin).reshape(b, hkv, hd)
        ck = _cache_update(cfg, ck, kn, w_idx)
        cv = _cache_update(cfg, cv, vn, w_idx)
        o = ops.attention_decode(q, ck, cv, pos + 1)
        x = x + C.dense(o.reshape(b, -1), pa["wo"])
        # cross-attention to encoder memory
        pc = p["cross"]
        xn = C.norm(cfg, pc["ln"], x)
        q = C.dense(xn, pc["wq"]).reshape(b, cfg.n_heads, hd)
        o = ops.attention_decode(q, xk, xv, jnp.asarray(enc_len, jnp.int32))
        x = x + C.dense(o.reshape(b, -1), pc["wo"])
        # mlp
        pm = p["mlp"]
        xn = C.norm(cfg, pm["ln"], x)
        h = jax.nn.silu(C.dense(xn, pm["wg"])) * C.dense(xn, pm["wi"])
        x = x + C.dense(h, pm["wo"])
        return x, (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        body, x,
        (params["dec_layers"], state["k"], state["v"], state["xk"], state["xv"]),
    )
    x = C.norm(cfg, params["ln_f"], x)
    logits = C.dense(x, params["lm_head"])
    if active is not None and pos.ndim == 1:
        new_pos = pos + active.astype(jnp.int32)
    else:
        new_pos = pos + 1
    return logits, {**state, "k": ks, "v": vs, "pos": new_pos}
