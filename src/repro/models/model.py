"""Family dispatcher — one uniform Model facade over the zoo.

``build_model(cfg)`` returns a ``Model`` whose methods close over the
config; the launcher/dry-run/smoke tests talk only to this interface.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, lm


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init_params: Callable[..., Any]
    train_loss: Callable[..., jax.Array]
    prefill: Callable[..., jax.Array]
    init_decode_state: Callable[..., Dict[str, jax.Array]]
    decode_step: Callable[..., Any]
    # zero selected batch rows' decode caches (serving slot refill);
    # ``start=`` places the reset rows' decode clock (prefix-sharing
    # admission resumes prefill at the first unshared token)
    reset_decode_rows: Callable[..., Dict[str, jax.Array]] = None
    # multi-token prompt ingestion (chunked prefill): (params, state,
    # toks (B,C), width (B,), active=...) -> (last-position logits, state)
    prefill_chunk: Callable[..., Any] = None
    # prefix-sharing admission for recurrent families: map donor snapshot
    # slots and load the donor's state at the last shared page boundary
    # (state, mask, src, nblk) -> state; None for families without a
    # recurrent-state snapshot store
    restore_snapshots: Callable[..., Dict[str, jax.Array]] = None
    # preemption (two-tier pager, ``init_decode_state(host_spill=True)``):
    # (state, mask) -> state moving the masked rows' KV pages + snapshot
    # slots to/from the host tier; None for families without KV pages
    spill_rows: Callable[..., Dict[str, jax.Array]] = None
    restore_rows: Callable[..., Dict[str, jax.Array]] = None


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family == "encdec":
        def init_decode_state(batch: int, max_len: int, **kw):
            return encdec.init_decode_state(
                cfg, batch, max_len, enc_len=max(max_len // 4, 8), **kw
            )

        def prefill_fn(params, batch):
            memory = encdec.encode(cfg, params, batch["frames"], remat=False)
            h = encdec.decode_train(cfg, params, batch["tokens"], memory,
                                    remat=False)
            from repro.models import components as C
            return C.dense(h[:, -1:, :], params["lm_head"])[:, 0]

        return Model(
            cfg=cfg,
            init_params=lambda rng: encdec.init_params(cfg, rng),
            train_loss=lambda params, batch: encdec.train_loss(cfg, params, batch),
            prefill=prefill_fn,
            init_decode_state=init_decode_state,
            decode_step=lambda params, state, token, **kw: encdec.decode_step(
                cfg, params, state, token, **kw
            ),
            reset_decode_rows=lambda state, mask, **kw: encdec.reset_decode_rows(
                cfg, state, mask, **kw
            ),
            prefill_chunk=lambda params, state, toks, width, **kw:
                encdec.prefill_chunk(cfg, params, state, toks, width, **kw),
        )

    def prefill_fn(params, batch):
        return lm.prefill(
            cfg, params, batch["tokens"], vision=batch.get("vision")
        )

    return Model(
        cfg=cfg,
        init_params=lambda rng: lm.init_params(cfg, rng),
        train_loss=lambda params, batch: lm.train_loss(cfg, params, batch),
        prefill=prefill_fn,
        init_decode_state=lambda batch, max_len, **kw: lm.init_decode_state(
            cfg, batch, max_len, **kw
        ),
        decode_step=lambda params, state, token, **kw: lm.decode_step(
            cfg, params, state, token, **kw
        ),
        reset_decode_rows=lambda state, mask, **kw: lm.reset_decode_rows(
            cfg, state, mask, **kw
        ),
        prefill_chunk=lambda params, state, toks, width, **kw:
            lm.prefill_chunk(cfg, params, state, toks, width, **kw),
        restore_snapshots=lambda state, mask, src, nblk:
            lm.restore_snapshots(state, mask, src, nblk),
        spill_rows=lambda state, mask: lm.spill_rows(cfg, state, mask),
        restore_rows=lambda state, mask: lm.restore_rows(cfg, state, mask),
    )
