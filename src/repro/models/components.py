"""Reusable model components for the architecture zoo.

Everything is built on the portable ops (``repro.kernels.ops``) so each
architecture is single-source across backends — the paper's property,
generalized from Caffe blocks to transformer blocks.

Sharding: activations/params pass through ``shard`` hints (no-ops without a
mesh) so the same code lowers on 1 CPU device and on the 512-chip mesh.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.kernels import ops


Params = Dict[str, jax.Array]


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None):
    """Projection over the last axis via the portable matmul."""
    lead = x.shape[:-1]
    y = ops.matmul(x.reshape(-1, x.shape[-1]), w)
    if b is not None:
        y = ops.bias_add_rows(y, b)
    return y.reshape(*lead, w.shape[-1])


def norm(cfg: ArchConfig, w: jax.Array, x: jax.Array) -> jax.Array:
    return ops.rmsnorm(x, w)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(cfg: ArchConfig, positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin (..., head_dim//2), f32."""
    hd = cfg.head_dim_
    inv = 1.0 / (
        cfg.rope_theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)
    )
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (S, D/2) or (B, D/2) for decode."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    while cos.ndim < x.ndim:
        cos, sin = cos[..., None, :], sin[..., None, :]  # broadcast over H
        if cos.ndim < x.ndim:
            cos, sin = jnp.expand_dims(cos, 0), jnp.expand_dims(sin, 0)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention block (self / cross, train / decode)
# ---------------------------------------------------------------------------

def init_attention(cfg: ArchConfig, rng, *, cross: bool = False) -> Params:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(rng, 4)
    s = 1.0 / np.sqrt(d)
    dt = cfg.dtype_()
    p = {
        "wq": (jax.random.normal(ks[0], (d, h * hd)) * s).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, hkv * hd)) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, hkv * hd)) * s).astype(dt),
        "wo": (jax.random.normal(ks[3], (h * hd, d)) * s).astype(dt),
        "ln": jnp.ones((d,), dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((hkv * hd,), dt)
        p["bv"] = jnp.zeros((hkv * hd,), dt)
    if cross:
        p["gate"] = jnp.zeros((), dt)  # llama-vision tanh gate
    return p


def attention_block(
    cfg: ArchConfig,
    p: Params,
    x: jax.Array,                       # (B, S, d)
    *,
    positions: Optional[jax.Array] = None,
    causal: bool = True,
    kv_src: Optional[jax.Array] = None,  # cross-attention memory (B, Sk, d)
    window: Optional[int] = None,
) -> jax.Array:
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    xn = norm(cfg, p["ln"], x)
    # SP gather-once: norm runs on the seq-sharded residual; the normed
    # activation is gathered ONCE here and reused by all three projections
    # (instead of GSPMD re-gathering per dot — perf iteration L1, §Perf).
    xn = shard(xn, ("data", None, None))
    src = norm(cfg, p["ln"], kv_src) if kv_src is not None else xn
    q = dense(xn, p["wq"], p.get("bq")).reshape(b, s, h, hd)
    k = dense(src, p["wk"], p.get("bk")).reshape(b, src.shape[1], hkv, hd)
    v = dense(src, p["wv"], p.get("bv")).reshape(b, src.shape[1], hkv, hd)
    if kv_src is None:  # self-attention: rotary positions
        if positions is None:
            positions = jnp.arange(s)
        cos, sin = rope_freqs(cfg, positions)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    # TP over q heads when divisible; KV heads replicate across TP when the
    # GQA group count is below the TP degree (Megatron GQA convention).
    from repro.distributed.sharding import axis_size
    tp = axis_size("model")
    q = shard(q, ("data", None, "model" if h % max(tp, 1) == 0 else "auto", None))
    kv_axis = "model" if hkv % max(tp, 1) == 0 else None
    k = shard(k, ("data", None, kv_axis, None))
    v = shard(v, ("data", None, kv_axis, None))
    o = ops.attention(
        q, k, v, causal=causal and kv_src is None, window=window
    )
    o = dense(o.reshape(b, s, h * hd), p["wo"])
    if "gate" in p:
        o = jnp.tanh(p["gate"]) * o
    return x + o


def attention_decode_block(
    cfg: ArchConfig,
    p: Params,
    x: jax.Array,                       # (B, d) one token
    cache_k: jax.Array,                 # (B, Smax, Hkv, hd)
    cache_v: jax.Array,
    pos: jax.Array,                     # scalar int32
    *,
    window: Optional[int] = None,
    cross: bool = False,
    cross_len: Optional[jax.Array] = None,
):
    b, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    xn = norm(cfg, p["ln"], x)
    q = dense(xn, p["wq"], p.get("bq")).reshape(b, h, hd)
    if not cross:
        k_new = dense(xn, p["wk"], p.get("bk")).reshape(b, hkv, hd)
        v_new = dense(xn, p["wv"], p.get("bv")).reshape(b, hkv, hd)
        cos, sin = rope_freqs(cfg, pos[None])           # (1, hd/2)
        q = apply_rope(q.reshape(b, 1, h, hd), cos, sin).reshape(b, h, hd)
        k_new = apply_rope(
            k_new.reshape(b, 1, hkv, hd), cos, sin
        ).reshape(b, hkv, hd)
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k_new[:, None], pos, axis=1
        )
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v_new[:, None], pos, axis=1
        )
        cache_len = pos + 1
    else:
        cache_len = cross_len if cross_len is not None else cache_k.shape[1]
    o = ops.attention_decode(
        q, cache_k, cache_v, jnp.asarray(cache_len, jnp.int32), window=window
    )
    o = dense(o.reshape(b, h * hd), p["wo"])
    if "gate" in p:
        o = jnp.tanh(p["gate"]) * o
    return x + o, cache_k, cache_v


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(cfg: ArchConfig, rng, d_ff: Optional[int] = None) -> Params:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    dt = cfg.dtype_()
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(ff)
    return {
        "wg": (jax.random.normal(ks[0], (d, ff)) * s_in).astype(dt),
        "wi": (jax.random.normal(ks[1], (d, ff)) * s_in).astype(dt),
        "wo": (jax.random.normal(ks[2], (ff, d)) * s_out).astype(dt),
        "ln": jnp.ones((d,), dt),
    }


def mlp_block(cfg: ArchConfig, p: Params, x: jax.Array) -> jax.Array:
    xn = norm(cfg, p["ln"], x)
    xn = shard(xn, ("data", None, None))   # SP gather-once (see attention)
    h = jax.nn.silu(dense(xn, p["wg"])) * dense(xn, p["wi"])
    h = shard(h, ("data", None, "model"))
    return x + dense(h, p["wo"])


# ---------------------------------------------------------------------------
# MoE (top-k routing, capacity-based dispatch; EP-shardable)
# ---------------------------------------------------------------------------

def init_moe(cfg: ArchConfig, rng) -> Params:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(rng, 4)
    dt = cfg.dtype_()
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(ff)
    return {
        "router": (jax.random.normal(ks[0], (d, e)) * s_in).astype(jnp.float32),
        "wg": (jax.random.normal(ks[1], (e, d, ff)) * s_in).astype(dt),
        "wi": (jax.random.normal(ks[2], (e, d, ff)) * s_in).astype(dt),
        "wo": (jax.random.normal(ks[3], (e, ff, d)) * s_out).astype(dt),
        "ln": jnp.ones((d,), dt),
    }


def moe_block(cfg: ArchConfig, p: Params, x: jax.Array) -> jax.Array:
    """Token-drop capacity MoE, GShard *grouped* formulation.

    Tokens are split into G groups (= data shards) with group-LOCAL
    capacity, so dispatch/scatter is local to each data shard; expert
    tensors carry the E axis for EP over the model axis (or ff-TP when E
    doesn't divide it).  This replaced a global-capacity scatter that
    GSPMD lowered to replicated 5.4 GB buffers per layer — perf iteration
    M1, §Perf (~an order of magnitude of collective traffic).
    """
    from repro.distributed.sharding import axis_size

    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    g = axis_size("data")
    if t % g != 0:
        g = 1
    tl = t // g
    xn = norm(cfg, p["ln"], x).reshape(g, tl, d)
    xn = shard(xn, ("data", None, None))
    logits = jnp.einsum(
        "gtd,de->gte", xn.astype(jnp.float32), p["router"],
        preferred_element_type=jnp.float32,
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)               # (g, tl, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    cap = max(1, int(np.ceil(tl * k / e * cfg.capacity_factor)))

    def dispatch_group(xg, idxg):
        flat_e = idxg.reshape(tl * k)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        ranks = jnp.cumsum(onehot, axis=0) - onehot
        rank = jnp.take_along_axis(ranks, flat_e[:, None], axis=1)[:, 0]
        keep = rank < cap
        rank_c = jnp.minimum(rank, cap - 1)
        tok = jnp.arange(tl * k) // k
        buf = jnp.zeros((e, cap, d), xg.dtype)
        buf = buf.at[flat_e, rank_c].add(
            xg[tok] * keep[:, None].astype(xg.dtype)
        )
        return buf, flat_e, rank_c, keep

    buf, flat_e, rank_c, keep = jax.vmap(dispatch_group)(xn, idx)
    # dispatch mirror of M4: build the buffer d-sharded (scatter + its
    # backward gather stay local), THEN all-to-all into the EP layout
    # (perf iteration M5, §Perf)
    buf = shard(buf, ("data", None, None, "model"))
    buf = shard(buf, ("data", "model", None, None))    # G x E (EP)
    h = jax.nn.silu(
        jnp.einsum("gecd,edf->gecf", buf, p["wg"],
                   preferred_element_type=jnp.float32).astype(xn.dtype)
    ) * jnp.einsum("gecd,edf->gecf", buf, p["wi"],
                   preferred_element_type=jnp.float32).astype(xn.dtype)
    h = shard(h, ("data", "model", None, None))
    out_e = jnp.einsum("gecf,efd->gecd", h, p["wo"],
                       preferred_element_type=jnp.float32).astype(xn.dtype)
    # combine: reshard E-sharded -> d-sharded (all-to-all, ~payload/TP per
    # device) so the (e,c) gather below is LOCAL.  Gathering across the
    # model-sharded E dim made GSPMD emit masked-gathers + full (tl*k, d)
    # f32 all-reduces — 2.4 TB/device/step (perf iteration M4, §Perf).
    out_e = shard(out_e, ("data", None, None, "model"))
    pulled = jax.vmap(
        lambda oe, fe, rc, kp: oe[fe, rc] * kp[:, None].astype(oe.dtype)
    )(out_e, flat_e, rank_c, keep)                     # (g, tl*k, d)
    combined = (
        pulled.reshape(g, tl, k, d) * gates[..., None].astype(xn.dtype)
    ).sum(axis=2)
    return x + combined.reshape(b, s, d)


# ---------------------------------------------------------------------------
# Mamba-2 block (conv1d + SSD), train and decode paths
# ---------------------------------------------------------------------------

def init_mamba(cfg: ArchConfig, rng) -> Params:
    d, di = cfg.d_model, cfg.d_inner
    n, h = cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(rng, 4)
    dt = cfg.dtype_()
    s = 1.0 / np.sqrt(d)
    return {
        # in_proj emits [z (di), x (di), B (n), C (n), dt (h)]
        "w_in": (jax.random.normal(ks[0], (d, 2 * di + 2 * n + h)) * s).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di)) * 0.1).astype(dt),
        "a_log": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "w_out": (jax.random.normal(ks[2], (di, d)) / np.sqrt(di)).astype(dt),
        "ln": jnp.ones((d,), dt),
        "ln_inner": jnp.ones((di,), dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. x: (B,S,di), w: (K,di)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(k)
    )
    return out


def _split_mamba_proj(cfg: ArchConfig, zxbcdt: jax.Array):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xs = zxbcdt[..., di : 2 * di]
    b_ = zxbcdt[..., 2 * di : 2 * di + n]
    c_ = zxbcdt[..., 2 * di + n : 2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n :]
    return z, xs, b_, c_, dt


def mamba_block(cfg: ArchConfig, p: Params, x: jax.Array) -> jax.Array:
    b, s, d = x.shape
    di, n, h, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xn = norm(cfg, p["ln"], x)
    z, xs, b_, c_, dt = _split_mamba_proj(cfg, dense(xn, p["w_in"]))
    xs = _causal_conv(xs, p["conv_w"])
    xs = jax.nn.silu(xs)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    y = ops.ssd_scan(
        xs.reshape(b, s, h, hd),
        dt,
        a,
        b_.reshape(b, s, 1, n),
        c_.reshape(b, s, 1, n),
        chunk=cfg.ssm_chunk,
    )
    y = y + xs.reshape(b, s, h, hd) * p["d_skip"][None, None, :, None]
    y = (y.reshape(b, s, di) * jax.nn.silu(z)).astype(x.dtype)
    y = ops.rmsnorm(y, p["ln_inner"])
    return x + dense(y, p["w_out"])


def mamba_prefill_block(
    cfg: ArchConfig, p: Params,
    x: jax.Array,              # (B, C, d): C tokens per sequence
    ssm_state: jax.Array,      # (B, H, P, N) f32: carried recurrent state
    conv_state: jax.Array,     # (B, K-1, di): carried conv window
    valid: jax.Array,          # (B, C) bool: prefix mask of real tokens
):
    """Chunked Mamba-2 block with carried recurrent state — the single
    source of the recurrent families' serving-time math.

    A chunk of C tokens runs as B*C-row projections, one chunked causal
    conv against the carried (K-1)-deep window, and one SSD scan seeded
    with the carried state (``ops.ssd_prefill_chunk``) — instead of C
    sequential single-token dispatches.  ``mamba_decode_block`` is the
    C=1 case of this function, so decode and prefill share one
    accumulation order rather than two hand-synchronized recurrences.

    Per-row widths ride on the ``valid`` prefix mask: a padding position's
    ``dt`` is zeroed (exp(0) decay, zero input — an algebraic no-op on the
    SSD state), and the new conv window is gathered to end at each row's
    last *real* token, so neither carry ever sees padding.  Rows with no
    real tokens (``valid`` all False) carry both states through untouched.
    Outputs at padding positions are finite garbage the caller discards.
    """
    b, c, d = x.shape
    di, n, h, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    k = cfg.ssm_conv
    xn = norm(cfg, p["ln"], x)
    z, xs, b_, c_, dt = _split_mamba_proj(cfg, dense(xn, p["w_in"]))
    # chunked causal conv against the carried window: position i of the
    # chunk reads raw inputs i-K+1..i, reaching into the carry for i < K-1
    win = jnp.concatenate([conv_state, xs], axis=1)        # (B, K-1+C, di)
    xs = sum(
        win[:, i : i + c] * p["conv_w"][i][None, None, :] for i in range(k)
    )
    # new conv window: the last K-1 inputs up to each row's width (width 0
    # gathers win[:, :K-1] — the old carry, verbatim)
    width = jnp.sum(valid, axis=1, dtype=jnp.int32)        # (B,)
    gidx = width[:, None] + jnp.arange(k - 1, dtype=jnp.int32)[None, :]
    conv_state = jnp.take_along_axis(win, gidx[:, :, None], axis=1)
    xs = jax.nn.silu(xs)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    dt = jnp.where(valid[:, :, None], dt, 0.0)             # padding: no-op
    a = -jnp.exp(p["a_log"])
    y, ssm_state = ops.ssd_prefill_chunk(
        xs.reshape(b, c, h, hd), dt, a,
        b_.reshape(b, c, 1, n), c_.reshape(b, c, 1, n),
        ssm_state, chunk=cfg.ssm_chunk,
    )
    y = y + xs.reshape(b, c, h, hd) * p["d_skip"][None, None, :, None]
    y = (y.reshape(b, c, di) * jax.nn.silu(z)).astype(x.dtype)
    y = ops.rmsnorm(y, p["ln_inner"])
    return x + dense(y, p["w_out"]), ssm_state, conv_state


def mamba_decode_block(
    cfg: ArchConfig, p: Params, x: jax.Array,
    ssm_state: jax.Array,      # (B, H, P, N)
    conv_state: jax.Array,     # (B, K-1, di)
    valid: Optional[jax.Array] = None,   # (B, 1) bool; None = all rows live
):
    """Single-token decode — the C=1 case of ``mamba_prefill_block``.

    One code path serves both regimes; the sequential recurrence is the
    chunked scan's degenerate case, not a second implementation kept in
    parity by hand (the dispatch layer may still pick a cheaper lowering
    for S=1 — specialization stays below this line).

    ``valid`` is the per-row liveness mask: a False row carries both
    recurrent states through untouched (the width-0 no-op documented on
    ``mamba_prefill_block``).  The serving engine relies on this for
    preempted/spilled rows, whose live state must survive in place while
    the lane idles; ``None`` keeps the historical all-rows-live default.
    """
    if valid is None:
        valid = jnp.ones((x.shape[0], 1), bool)
    y, ssm_state, conv_state = mamba_prefill_block(
        cfg, p, x[:, None], ssm_state, conv_state, valid
    )
    return y[:, 0], ssm_state, conv_state
