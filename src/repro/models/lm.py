"""Decoder-only LM family: dense / MoE / SSM (Mamba-2) / hybrid (Zamba2) /
VLM (Llama-3.2-Vision cross-attention).

Layer trunks are homogeneous and scanned (``lax.scan`` over stacked params)
so a 100-layer model compiles one layer body — essential for the 512-device
dry-run.  Heterogeneous patterns (Zamba2's shared attention every N blocks,
Vision's cross-attention every N layers) scan over *groups*.

Public API (all pure functions of (cfg, params, ...)):
    init_params, train_loss, forward, lm_logits,
    init_decode_state, decode_step, prefill, prefill_chunk
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models import components as C
from repro.kernels import ops


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _stacked(init_fn, rng, n: int):
    return jax.vmap(init_fn)(jax.random.split(rng, n))


def _init_layer_dense(cfg):
    def f(rng):
        r1, r2 = jax.random.split(rng)
        return {"attn": C.init_attention(cfg, r1), "mlp": C.init_mlp(cfg, r2)}
    return f


def _init_layer_moe(cfg):
    def f(rng):
        r1, r2 = jax.random.split(rng)
        return {"attn": C.init_attention(cfg, r1), "moe": C.init_moe(cfg, r2)}
    return f


def _init_layer_mamba(cfg):
    def f(rng):
        return {"mamba": C.init_mamba(cfg, rng)}
    return f


def init_params(cfg: ArchConfig, rng) -> Dict[str, Any]:
    dt = cfg.dtype_()
    r_emb, r_layers, r_head, r_extra = jax.random.split(rng, 4)
    params: Dict[str, Any] = {
        "embed": (
            jax.random.normal(r_emb, (cfg.vocab_size, cfg.d_model)) * 0.02
        ).astype(dt),
        "ln_f": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(r_head, (cfg.d_model, cfg.vocab_size))
            / np.sqrt(cfg.d_model)
        ).astype(dt)
    fam = cfg.family
    if fam in ("dense",):
        params["layers"] = _stacked(_init_layer_dense(cfg), r_layers, cfg.n_layers)
    elif fam == "moe":
        params["layers"] = _stacked(_init_layer_moe(cfg), r_layers, cfg.n_layers)
    elif fam == "ssm":
        params["layers"] = _stacked(_init_layer_mamba(cfg), r_layers, cfg.n_layers)
    elif fam == "hybrid":
        g = cfg.n_layers // cfg.attn_every
        def group(rng):
            return _stacked(_init_layer_mamba(cfg), rng, cfg.attn_every)
        params["groups"] = _stacked(group, r_layers, g)
        ra, rm = jax.random.split(r_extra)
        params["shared_attn"] = C.init_attention(cfg, ra)
        params["shared_mlp"] = C.init_mlp(cfg, rm)
    elif fam == "vlm":
        g = cfg.n_layers // cfg.cross_attn_every
        per = cfg.cross_attn_every - 1
        def group(rng):
            return _stacked(_init_layer_dense(cfg), rng, per)
        params["groups"] = _stacked(group, r_layers, g)
        params["cross"] = _stacked(
            lambda r: {
                "attn": C.init_attention(cfg, r, cross=True),
                "mlp": C.init_mlp(cfg, jax.random.fold_in(r, 1)),
            },
            r_extra, g,
        )
    else:
        raise ValueError(f"init_params: unsupported family {fam}")
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _layer_apply(cfg: ArchConfig, p, x, positions):
    if "mamba" in p:
        return C.mamba_block(cfg, p["mamba"], x)
    x = C.attention_block(
        cfg, p["attn"], x, positions=positions, causal=True, window=cfg.window
    )
    if "moe" in p:
        return C.moe_block(cfg, p["moe"], x)
    return C.mlp_block(cfg, p["mlp"], x)


def forward(
    cfg: ArchConfig,
    params,
    tokens: jax.Array,                  # (B, S)
    *,
    vision: Optional[jax.Array] = None,  # (B, P, d) VLM patch embeddings
    remat: bool = True,
) -> jax.Array:
    x = params["embed"][tokens].astype(cfg.dtype_())
    x = shard(x, ("data", "sp", None))
    s = tokens.shape[1]
    positions = jnp.arange(s)

    def layer(x, p):
        # residual stream is sequence-parallel between blocks (SP)
        return shard(_layer_apply(cfg, p, x, positions), ("data", "sp", None)), None

    if remat:
        layer = jax.checkpoint(layer)

    if cfg.family in ("dense", "moe", "ssm"):
        x, _ = jax.lax.scan(layer, x, params["layers"])
    elif cfg.family == "hybrid":
        def group(x, gp):
            x, _ = jax.lax.scan(layer, x, gp["inner"])
            x = C.attention_block(
                cfg, gp["shared_attn"], x, positions=positions, causal=True
            )
            x = C.mlp_block(cfg, gp["shared_mlp"], x)
            return x, None
        if remat:
            group = jax.checkpoint(group)
        # shared params broadcast into every group step
        g = cfg.n_layers // cfg.attn_every
        gp = {
            "inner": params["groups"],
            "shared_attn": jax.tree.map(
                lambda l: jnp.broadcast_to(l, (g, *l.shape)), params["shared_attn"]
            ),
            "shared_mlp": jax.tree.map(
                lambda l: jnp.broadcast_to(l, (g, *l.shape)), params["shared_mlp"]
            ),
        }
        x, _ = jax.lax.scan(group, x, gp)
    elif cfg.family == "vlm":
        assert vision is not None, "vlm needs vision embeddings"
        def group(x, gp):
            x, _ = jax.lax.scan(layer, x, gp["self"])
            x = C.attention_block(
                cfg, gp["cross"]["attn"], x, kv_src=vision, causal=False
            )
            x = C.mlp_block(cfg, gp["cross"]["mlp"], x)
            return x, None
        if remat:
            group = jax.checkpoint(group)
        x, _ = jax.lax.scan(
            group, x, {"self": params["groups"], "cross": params["cross"]}
        )
    else:
        raise ValueError(cfg.family)
    return C.norm(cfg, params["ln_f"], x)


def lm_logits(cfg: ArchConfig, params, h: jax.Array) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return C.dense(h, w)


def _xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Stable mean NLL in f32 over (..., V) logits."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    picked = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    return (lse - picked).mean()


def train_loss(cfg: ArchConfig, params, batch: Dict[str, jax.Array]):
    tokens = batch["tokens"]
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    inputs = shard(inputs, ("data", None))
    h = forward(cfg, params, inputs, vision=batch.get("vision"))
    logits = lm_logits(cfg, params, h)
    logits = shard(logits, ("data", None, "model"))
    return _xent(logits, targets)


# ---------------------------------------------------------------------------
# Serving: prefill + decode with KV / SSM caches
# ---------------------------------------------------------------------------

def init_decode_state(
    cfg: ArchConfig, batch: int, max_len: int, *, per_row_pos: bool = False,
    layout: str = "contiguous", page_size: int = 16,
    n_pages: Optional[int] = None, snapshots: bool = False,
    host_spill: bool = False, kv_dtype: str = "f32", cache=None,
) -> Dict[str, jax.Array]:
    """Decode caches.  ``per_row_pos=True`` keeps ``pos`` as a (B,) vector so
    rows may sit at different sequence depths (continuous batching).

    ``cache=`` accepts a ``repro.serving.config.CacheConfig`` (duck-typed
    — models never import serving) and overrides the individual layout
    kwargs, which remain for legacy call sites.

    ``layout`` picks the KV-cache representation (``KVCacheLayout``):
    ``"contiguous"`` is the dense ``(layers, B, max_len, Hkv, hd)`` slab;
    ``"paged"`` replaces it with a page pool + per-row block table + free
    list (see ``repro.serving.pager`` for the layout contract), so resident
    KV memory scales with live tokens instead of ``B x max_len``.  SSM and
    conv state is recurrent (O(1) per row) and stays contiguous under
    either layout; only attention K/V pages.

    ``snapshots=True`` (recurrent families, paged layout only) adds the
    page-boundary recurrent-state snapshot store: slot pools for the full
    per-row SSM + conv state, a per-(row, boundary) slot table and a
    refcounted free list, managed by the same allocator primitives as KV
    pages (``repro.serving.pager`` documents the snapshot-slot contract).
    Snapshots are what make prompt prefix sharing real for ssm/hybrid: a
    sharer restores the donor's state at the last shared page boundary
    instead of re-running the recurrence.  Attention-only families ignore
    the flag (they have no recurrent carry to snapshot).

    ``host_spill=True`` (paged layout with KV pages only) adds the host
    tier behind preemption: mirror pools (``hkp``/``hvp`` and, with
    snapshots, ``hsnap_ssm``/``hsnap_conv``), per-row host tables, and a
    second refcounted free list per space, all sized at the worst case
    (``batch x max_blocks`` slots) so a spill can never find the host
    free list dry.  ``spill_rows``/``restore_rows`` move a row between
    tiers; families without KV pages (pure ssm, contiguous layouts)
    ignore the flag — they have no page pool to relieve, so the engine
    never preempts them.

    ``kv_dtype="int8"`` (paged layout only) stores the KV page pools as
    symmetric per-(page, head)-scaled int8: the payload arrays switch to
    ``jnp.int8`` and f32 scale pools ``ksc``/``vsc`` (shape
    ``(stacks, n_pages, Hkv)``) ride alongside — written by
    ``pager.write_page_quant``/``write_page_chunk_quant``, dequantized
    inside the attention kernels.  Host-tier mirrors (``hksc``/``hvsc``)
    spill the quantized form, cutting spill bandwidth the same 4x.
    ``kv_dtype="bf16"`` is the storage-only midpoint: half-width pools
    through the unmodified kernels (which upcast K/V tiles to f32), no
    scale pools, exactly half the f32 resident bytes.
    """
    if cache is not None:
        layout = cache.layout
        page_size = cache.page_size
        n_pages = cache.n_pages
        snapshots = cache.snapshots
        host_spill = bool(cache.host_spill)
        kv_dtype = getattr(cache, "kv_dtype", "f32")
    if layout not in ("contiguous", "paged"):
        raise ValueError(f"unknown KV-cache layout {layout!r}")
    if kv_dtype not in ("f32", "bf16", "int8"):
        raise ValueError(
            f"unknown kv_dtype {kv_dtype!r} "
            "(expected 'f32', 'bf16', or 'int8')"
        )
    if kv_dtype != "f32" and layout != "paged":
        raise ValueError(
            "sub-f32 KV storage is a paged-pool feature (quantized "
            "scales are per page) — layout='paged' required for "
            f"kv_dtype={kv_dtype!r}"
        )
    dt = cfg.dtype_()
    hkv, hd = cfg.n_kv_heads, cfg.head_dim_
    # sliding-window archs only ever need `window` cache slots (ring buffer)
    eff = min(max_len, cfg.window) if cfg.window else max_len
    pos0 = jnp.zeros((batch,) if per_row_pos else (), jnp.int32)
    state: Dict[str, jax.Array] = {"pos": pos0}
    recurrent = cfg.family in ("ssm", "hybrid")
    if snapshots and recurrent and layout != "paged":
        raise ValueError(
            "recurrent-state snapshots use page-boundary granularity — "
            "layout='paged' required"
        )

    def paged_kv(stacks: int) -> Dict[str, jax.Array]:
        # paged writes at *absolute* positions (no window ring): block ids
        # are position // page_size, so the table covers max_len
        from repro.serving import pager as P

        max_blocks = -(-max_len // page_size)
        pages = batch * max_blocks if n_pages is None else n_pages
        ps = P.init_pager(pages)
        quant = kv_dtype == "int8"
        kv_dt = {"int8": jnp.int8, "bf16": jnp.bfloat16}.get(kv_dtype, dt)
        out = {
            "kp": jnp.zeros((stacks, pages, page_size, hkv, hd), kv_dt),
            "vp": jnp.zeros((stacks, pages, page_size, hkv, hd), kv_dt),
            "block_table": P.init_block_table(batch, max_blocks),
            "page_free": ps.free,
            "page_top": ps.top,
            "page_rc": ps.rc,
        }
        if quant:
            # per-(page, head) f32 scales — zero means "empty page"
            # (write_page_quant resets the scale at slot 0)
            out["ksc"] = jnp.zeros((stacks, pages, hkv), jnp.float32)
            out["vsc"] = jnp.zeros((stacks, pages, hkv), jnp.float32)
        if host_spill:
            # host tier: worst-case sizing (every row fully resident, all
            # spilled at once) so spill pops can never run dry
            n_hslots = batch * max_blocks
            hs = P.init_pager(n_hslots)
            out.update({
                "hkp": jnp.zeros(
                    (stacks, n_hslots, page_size, hkv, hd), kv_dt
                ),
                "hvp": jnp.zeros(
                    (stacks, n_hslots, page_size, hkv, hd), kv_dt
                ),
                "host_table": P.init_block_table(batch, max_blocks),
                "host_free": hs.free,
                "host_top": hs.top,
                "host_rc": hs.rc,
            })
            if quant:
                # spill moves the quantized payload + its scales; the
                # host tier never re-quantizes
                out["hksc"] = jnp.zeros(
                    (stacks, n_hslots, hkv), jnp.float32
                )
                out["hvsc"] = jnp.zeros(
                    (stacks, n_hslots, hkv), jnp.float32
                )
        return out

    def snap_store(host: bool = False) -> Dict[str, jax.Array]:
        # worst-case slot pool: every row can snapshot every boundary it
        # can ever reach, so — like the page reservation ledger — the
        # allocator can never run dry mid-request (slots a dead donor
        # leaves behind are mapped, hence budgeted, by their sharers)
        from repro.serving import pager as P

        n_bound = -(-max_len // page_size)
        n_slots = batch * n_bound
        ps = P.init_pager(n_slots)
        out = {
            "snap_ssm": jnp.zeros(
                (n_slots, cfg.n_layers, cfg.ssm_heads, cfg.ssm_head_dim,
                 cfg.ssm_state), jnp.float32,
            ),
            "snap_conv": jnp.zeros(
                (n_slots, cfg.n_layers, cfg.ssm_conv - 1, cfg.d_inner), dt
            ),
            "snap_table": P.init_block_table(batch, n_bound),
            "snap_free": ps.free,
            "snap_top": ps.top,
            "snap_rc": ps.rc,
        }
        if host:
            # host snapshot tier (spillable families only): boundary space
            # mirrors at the same worst case as the device slot pool
            hs = P.init_pager(n_slots)
            out.update({
                "hsnap_ssm": jnp.zeros_like(out["snap_ssm"]),
                "hsnap_conv": jnp.zeros_like(out["snap_conv"]),
                "hsnap_table": P.init_block_table(batch, n_bound),
                "hsnap_free": hs.free,
                "hsnap_top": hs.top,
                "hsnap_rc": hs.rc,
            })
        return out

    if cfg.family in ("dense", "moe"):
        if layout == "paged":
            state.update(paged_kv(cfg.n_layers))
            return state
        state["k"] = jnp.zeros((cfg.n_layers, batch, eff, hkv, hd), dt)
        state["v"] = jnp.zeros((cfg.n_layers, batch, eff, hkv, hd), dt)
    elif cfg.family == "ssm":
        state["ssm"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32,
        )
        state["conv"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_conv - 1, cfg.d_inner), dt
        )
        if snapshots:
            state.update(snap_store())
    elif cfg.family == "hybrid":
        g = cfg.n_layers // cfg.attn_every
        state["ssm"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32,
        )
        state["conv"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_conv - 1, cfg.d_inner), dt
        )
        if snapshots:
            state.update(snap_store(host=host_spill and layout == "paged"))
        if layout == "paged":
            state.update(paged_kv(g))
            return state
        state["k"] = jnp.zeros((g, batch, eff, hkv, hd), dt)
        state["v"] = jnp.zeros((g, batch, eff, hkv, hd), dt)
    elif cfg.family == "vlm":
        if layout == "paged":
            raise NotImplementedError(
                "paged KV layout: vlm's grouped self-attn cache not yet "
                "paged (serving engine families are dense/moe/ssm/hybrid)"
            )
        g = cfg.n_layers // cfg.cross_attn_every
        per = cfg.cross_attn_every - 1
        state["k"] = jnp.zeros((g, per, batch, eff, hkv, hd), dt)
        state["v"] = jnp.zeros((g, per, batch, eff, hkv, hd), dt)
        # cross K/V filled by prefill from vision embeddings
        state["xk"] = jnp.zeros((g, batch, cfg.n_vision_tokens, hkv, hd), dt)
        state["xv"] = jnp.zeros((g, batch, cfg.n_vision_tokens, hkv, hd), dt)
    else:
        raise ValueError(cfg.family)
    return state


def _cache_index(cfg: ArchConfig, pos: jax.Array) -> jax.Array:
    return pos % cfg.window if cfg.window else pos


def _cache_update(cfg: ArchConfig, cache: jax.Array, new: jax.Array,
                  idx: jax.Array) -> jax.Array:
    """Write one token's K/V at ``idx`` into a (B, S, Hkv, hd) cache.

    When the cache's sequence dim is sharded (context-parallel decode for
    GQA head counts below the TP degree), a dynamic-update-slice forces
    GSPMD to all-gather the cache; an elementwise masked write partitions
    cleanly instead (perf iteration D2, §Perf).
    """
    from repro.distributed.sharding import active_mesh, axis_size

    tp = max(axis_size("model"), 1)
    seq_sharded = (
        active_mesh() is not None
        and cfg.n_kv_heads % tp != 0
        and cache.shape[1] % tp == 0
    )
    if seq_sharded or idx.ndim == 1:
        # per-row idx (continuous batching) uses the same elementwise masked
        # write — each row lands at its own slot in one fused op
        pos_iota = jax.lax.broadcasted_iota(
            jnp.int32, (1, cache.shape[1], 1, 1), 1
        )
        idx_b = idx.reshape(-1, 1, 1, 1) if idx.ndim == 1 else idx
        return jnp.where(pos_iota == idx_b, new[:, None].astype(cache.dtype),
                         cache)
    return jax.lax.dynamic_update_slice_in_dim(
        cache, new[:, None], idx, axis=1
    )


def _cache_update_chunk(cache: jax.Array, new: jax.Array,
                        posmat: jax.Array, valid: jax.Array) -> jax.Array:
    """Write a chunk of C tokens' K/V into a (B, S, Hkv, hd) cache.

    ``new`` is (B, C, Hkv, hd); token i of row b lands at absolute position
    ``posmat[b, i]``; invalid positions (chunk padding, inactive rows) are
    routed past the sequence axis and dropped.  Positions are distinct per
    row, so the scatter never writes one slot twice.  Absolute positions
    only — ring-indexed sliding-window caches can't host multi-token chunks
    (the chunk's own writes would recycle slots its queries still read).
    """
    smax = cache.shape[1]
    tgt = jnp.where(valid, posmat, smax)
    rows = jnp.arange(cache.shape[0])[:, None]
    return cache.at[rows, tgt].set(new.astype(cache.dtype), mode="drop")


def _paged_cow(state, wpos, active, *, cow: bool):
    """Shared head of every paged write path: unpack the allocator, and —
    when the engine can share pages (``cow``, a trace-time constant) — run
    copy-on-write for the page each row writes at ``wpos``, moving the
    already-written slot prefix into the private copy in both pools.
    Returns ``(state, PagerState, block_table)``; the caller allocs into
    ``bt`` and commits with ``_paged_commit``."""
    from repro.serving import pager as PG

    pstate = PG.PagerState(
        state["page_free"], state["page_top"], state["page_rc"]
    )
    bt = state["block_table"]
    if cow:
        pstate, bt, src, dst, lim, _ = PG.cow_on_write(
            pstate, bt, wpos, active, page_size=state["kp"].shape[2]
        )
        state = {**state,
                 "kp": PG.copy_page_prefix(state["kp"], src, dst, lim),
                 "vp": PG.copy_page_prefix(state["vp"], src, dst, lim)}
        if "ksc" in state:
            # quantized pools: the private copy inherits the donor page's
            # scale, so the copied prefix stays decodable; the next write
            # max-merges (and requantizes) from there
            state = {**state,
                     "ksc": PG.copy_page_scale(state["ksc"], src, dst),
                     "vsc": PG.copy_page_scale(state["vsc"], src, dst)}
    return state, pstate, bt


def _paged_commit(state, pstate, bt):
    return {**state, "page_free": pstate.free, "page_top": pstate.top,
            "page_rc": pstate.rc, "block_table": bt}


def _snap_capture(state, pos_after: jax.Array, active: jax.Array,
                  snap_every: int):
    """Write a page-boundary recurrent-state snapshot for every row whose
    step just ended exactly at a boundary (``pos_after`` a positive
    multiple of ``snap_every``): allocate a slot for boundary index
    ``pos_after/snap_every - 1`` in the row's snapshot table (boundary
    space is block space with page_size 1 — same allocator, same
    conservation invariant) and scatter the row's full-depth SSM + conv
    state into the pools.  Pure ``jnp``, fixed shapes, one masked scatter
    per pool — runs inside the jitted engine steps without retracing.

    A slot still shared with a peer (rc > 1) is never overwritten: shared
    slots sit strictly below the row's own progress (a sharer resumes past
    its inherited boundaries), so the guard is belt-and-braces for the
    immutability of shared snapshots — the same read-only contract as
    shared KV pages.
    """
    from repro.serving import pager as PG

    at = active & (pos_after > 0) & (pos_after % snap_every == 0)
    bound = pos_after // snap_every - 1
    sstate = PG.PagerState(
        state["snap_free"], state["snap_top"], state["snap_rc"]
    )
    sstate, stbl = PG.alloc_on_write(
        sstate, state["snap_table"], bound, at, page_size=1
    )
    n_slots = state["snap_ssm"].shape[0]
    nb = stbl.shape[1]
    slot = jnp.take_along_axis(
        stbl, jnp.clip(bound, 0, nb - 1)[:, None], axis=1
    )[:, 0]
    ok = at & (bound >= 0) & (bound < nb) & (slot >= 0)
    ok &= sstate.rc[jnp.clip(slot, 0, n_slots - 1)] <= 1
    tgt = jnp.where(ok, slot, n_slots)                 # sentinel: dropped
    snap_ssm = state["snap_ssm"].at[tgt].set(
        jnp.moveaxis(state["ssm"], 1, 0), mode="drop"
    )
    snap_conv = state["snap_conv"].at[tgt].set(
        jnp.moveaxis(state["conv"], 1, 0).astype(state["snap_conv"].dtype),
        mode="drop",
    )
    return {**state, "snap_ssm": snap_ssm, "snap_conv": snap_conv,
            "snap_table": stbl, "snap_free": sstate.free,
            "snap_top": sstate.top, "snap_rc": sstate.rc}


def restore_snapshots(state, mask: jax.Array, src: jax.Array,
                      nblk: jax.Array):
    """Prefix-sharing admission for recurrent state: map the donor rows'
    leading ``nblk`` snapshot slots into the masked rows' tables
    (``pager.share_prefix`` on boundary space — refcount bumps keep the
    slots alive past the donor's release) and load slot ``nblk - 1`` —
    the donor's state after its first ``nblk`` pages — into the rows'
    live SSM/conv state, so prefill resumes at the first unshared token
    with the recurrence already advanced.  ``nblk == 0`` rows are
    untouched (the non-sharing admission path is the same trace).
    """
    from repro.serving import pager as PG

    sstate, stbl = PG.share_prefix(
        PG.PagerState(state["snap_free"], state["snap_top"],
                      state["snap_rc"]),
        state["snap_table"], src, nblk, mask,
    )
    b = stbl.shape[0]
    nb = stbl.shape[1]
    nblk_b = jnp.broadcast_to(jnp.asarray(nblk, jnp.int32).reshape(-1), (b,))
    k = jnp.clip(nblk_b - 1, 0, nb - 1)
    slot = jnp.take_along_axis(stbl, k[:, None], axis=1)[:, 0]
    ok = mask & (nblk_b > 0) & (slot >= 0)
    n_slots = state["snap_ssm"].shape[0]
    sl = jnp.clip(slot, 0, n_slots - 1)
    ssm_r = jnp.moveaxis(state["snap_ssm"][sl], 0, 1)      # (L, B, ...)
    conv_r = jnp.moveaxis(state["snap_conv"][sl], 0, 1)
    return {**state,
            "ssm": jnp.where(ok[None, :, None, None, None], ssm_r,
                             state["ssm"]),
            "conv": jnp.where(ok[None, :, None, None],
                              conv_r.astype(state["conv"].dtype),
                              state["conv"]),
            "snap_table": stbl, "snap_free": sstate.free,
            "snap_top": sstate.top, "snap_rc": sstate.rc}


def spill_rows(
    cfg: ArchConfig, state: Dict[str, jax.Array], mask: jax.Array,  # (B,) bool
) -> Dict[str, jax.Array]:
    """Preemption: move the masked rows' KV pages — and, with a snapshot
    store, their boundary snapshot slots — to the host tier.

    Bookkeeping runs through ``pager.spill_rows`` (host slot per mapped
    block, then a device-side release; shared pages stay resident for
    their peers while the victim gets a private host copy) and the data
    moves through ``pager.copy_pages`` in the same jitted call.  The
    row's *lane* state (``pos``, live ssm/conv carries) stays in place —
    a spilled row keeps its slot and simply idles with ``active=False``;
    only pool residency moves.  Requires
    ``init_decode_state(host_spill=True)``.
    """
    from repro.serving import pager as PG

    if "host_table" not in state:
        raise ValueError(
            "spill_rows needs init_decode_state(host_spill=True) paged state"
        )
    pstate = PG.PagerState(
        state["page_free"], state["page_top"], state["page_rc"]
    )
    hstate = PG.PagerState(
        state["host_free"], state["host_top"], state["host_rc"]
    )
    pstate, bt, hstate, ht, src, dst = PG.spill_rows(
        pstate, state["block_table"], hstate, state["host_table"], mask
    )
    out = {**state,
           "hkp": PG.copy_pages(state["hkp"], state["kp"], src, dst),
           "hvp": PG.copy_pages(state["hvp"], state["vp"], src, dst),
           "block_table": bt, "page_free": pstate.free,
           "page_top": pstate.top, "page_rc": pstate.rc,
           "host_table": ht, "host_free": hstate.free,
           "host_top": hstate.top, "host_rc": hstate.rc}
    if "hksc" in state:
        # quantized pools spill as-is: int8 payload + f32 scales move with
        # the same (src, dst) slot vectors, so host copies stay decodable
        out["hksc"] = PG.copy_pages(state["hksc"], state["ksc"], src, dst)
        out["hvsc"] = PG.copy_pages(state["hvsc"], state["vsc"], src, dst)
    if "hsnap_table" in state:
        sstate = PG.PagerState(
            state["snap_free"], state["snap_top"], state["snap_rc"]
        )
        hs = PG.PagerState(
            state["hsnap_free"], state["hsnap_top"], state["hsnap_rc"]
        )
        sstate, stbl, hs, hstbl, ssrc, sdst = PG.spill_rows(
            sstate, state["snap_table"], hs, state["hsnap_table"], mask
        )
        out.update({
            "hsnap_ssm": PG.copy_pages(
                state["hsnap_ssm"], state["snap_ssm"], ssrc, sdst, axis=0
            ),
            "hsnap_conv": PG.copy_pages(
                state["hsnap_conv"], state["snap_conv"], ssrc, sdst, axis=0
            ),
            "snap_table": stbl, "snap_free": sstate.free,
            "snap_top": sstate.top, "snap_rc": sstate.rc,
            "hsnap_table": hstbl, "hsnap_free": hs.free,
            "hsnap_top": hs.top, "hsnap_rc": hs.rc,
        })
    return out


def restore_rows(
    cfg: ArchConfig, state: Dict[str, jax.Array], mask: jax.Array,  # (B,) bool
) -> Dict[str, jax.Array]:
    """The exact mirror of ``spill_rows``: re-allocate device pages (and
    snapshot slots) for the masked rows' host-table entries, copy the
    content back, and release the host slots.  A restored row owns its
    pages privately (rc == 1) even where it used to share — the caller's
    reservation ledger must already cover the row's worst-case page
    count so the device pops cannot run dry."""
    from repro.serving import pager as PG

    if "host_table" not in state:
        raise ValueError(
            "restore_rows needs init_decode_state(host_spill=True) paged state"
        )
    pstate = PG.PagerState(
        state["page_free"], state["page_top"], state["page_rc"]
    )
    hstate = PG.PagerState(
        state["host_free"], state["host_top"], state["host_rc"]
    )
    pstate, bt, hstate, ht, src, dst = PG.restore_rows(
        pstate, state["block_table"], hstate, state["host_table"], mask
    )
    out = {**state,
           "kp": PG.copy_pages(state["kp"], state["hkp"], src, dst),
           "vp": PG.copy_pages(state["vp"], state["hvp"], src, dst),
           "block_table": bt, "page_free": pstate.free,
           "page_top": pstate.top, "page_rc": pstate.rc,
           "host_table": ht, "host_free": hstate.free,
           "host_top": hstate.top, "host_rc": hstate.rc}
    if "hksc" in state:
        out["ksc"] = PG.copy_pages(state["ksc"], state["hksc"], src, dst)
        out["vsc"] = PG.copy_pages(state["vsc"], state["hvsc"], src, dst)
    if "hsnap_table" in state:
        sstate = PG.PagerState(
            state["snap_free"], state["snap_top"], state["snap_rc"]
        )
        hs = PG.PagerState(
            state["hsnap_free"], state["hsnap_top"], state["hsnap_rc"]
        )
        sstate, stbl, hs, hstbl, ssrc, sdst = PG.restore_rows(
            sstate, state["snap_table"], hs, state["hsnap_table"], mask
        )
        out.update({
            "snap_ssm": PG.copy_pages(
                state["snap_ssm"], state["hsnap_ssm"], ssrc, sdst, axis=0
            ),
            "snap_conv": PG.copy_pages(
                state["snap_conv"], state["hsnap_conv"], ssrc, sdst, axis=0
            ),
            "snap_table": stbl, "snap_free": sstate.free,
            "snap_top": sstate.top, "snap_rc": sstate.rc,
            "hsnap_table": hstbl, "hsnap_free": hs.free,
            "hsnap_top": hs.top, "hsnap_rc": hs.rc,
        })
    return out


def decode_step(
    cfg: ArchConfig, params, state, token: jax.Array,  # (B,) int32
    *, active: Optional[jax.Array] = None,             # (B,) bool
    cow: bool = False, snap_every: int = 0,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One token for every sequence in the batch; returns (logits, state).

    ``state["pos"]`` may be a scalar (all rows in lockstep) or a (B,) vector
    (rows at independent depths — the continuous-batching serving engine).

    ``active`` (requires per-row ``pos``) masks rows that are between
    requests: their caches are not written, no pages are allocated, and
    their ``pos`` does not advance.  The layout is picked by the state dict
    itself: a ``block_table`` key means paged (see ``repro.serving.pager``
    for the contract), otherwise the contiguous slab path runs unchanged.

    ``cow`` (trace-time constant) enables the copy-on-write pass before
    paged writes — required exactly when pages can be prefix-shared
    (``pager.share_prefix`` ran on this state); engines that never share
    skip the per-step page gather/scatter entirely.

    ``snap_every`` (trace-time constant; recurrent families with a
    snapshot store) captures the row's post-step SSM/conv state whenever
    the step lands exactly on a page boundary — a decode step ends at
    every successive position, so every boundary it reaches is captured.
    """
    pos = state["pos"]
    paged = "block_table" in state
    quant = paged and "ksc" in state    # trace-time: int8 KV pools
    x = params["embed"][token].astype(cfg.dtype_())   # (B, d)
    # paged layout uses absolute positions (window masking in attention);
    # the contiguous layout ring-indexes sliding-window caches
    idx = pos if paged else _cache_index(cfg, pos)
    if cfg.window and not paged:
        cache_len = jnp.minimum(pos + 1, cfg.window)
    else:
        cache_len = pos + 1
    rope_pos = pos[..., None] if pos.ndim == 1 else pos[None]

    if paged:
        from repro.serving import pager as PG

        # copy-on-write before the write: a row whose target page is
        # prefix-shared (rc > 1) moves to a private copy first, so the
        # write can never corrupt a peer's cache
        state, pstate, bt = _paged_cow(state, idx, active, cow=cow)
        pstate, bt = PG.alloc_on_write(
            pstate, bt, idx, active, page_size=state["kp"].shape[2]
        )
        state = _paged_commit(state, pstate, bt)
    # contiguous masked-write: routing inactive rows to slot -1 drops them
    if active is not None and not paged and idx.ndim == 1:
        w_idx = jnp.where(active, idx, -1)
    else:
        w_idx = idx

    def attn_dec(p, x, kv):
        # ``kv`` is the per-layer cache tuple: (ck, cv) — or, quantized,
        # (ck, cv, ksc, vsc) with the scale pools riding the same scan
        b, d = x.shape
        hkv, hd = cfg.n_kv_heads, cfg.head_dim_
        xn = C.norm(cfg, p["ln"], x)
        q = C.dense(xn, p["wq"], p.get("bq")).reshape(b, cfg.n_heads, hd)
        k_new = C.dense(xn, p["wk"], p.get("bk")).reshape(b, hkv, hd)
        v_new = C.dense(xn, p["wv"], p.get("bv")).reshape(b, hkv, hd)
        cos, sin = C.rope_freqs(cfg, rope_pos)
        q = C.apply_rope(q.reshape(b, 1, -1, hd), cos, sin).reshape(b, -1, hd)
        k_new = C.apply_rope(
            k_new.reshape(b, 1, hkv, hd), cos, sin
        ).reshape(b, hkv, hd)
        if paged:
            from repro.serving import pager as PG

            bt = state["block_table"]
            if quant:
                ck, cv, ksc, vsc = kv
                ck, ksc = PG.write_page_quant(ck, ksc, k_new, bt, idx,
                                              active)
                cv, vsc = PG.write_page_quant(cv, vsc, v_new, bt, idx,
                                              active)
                o = ops.attention_decode(
                    q, ck, cv, jnp.asarray(cache_len, jnp.int32),
                    block_table=bt, window=cfg.window,
                    kv_scales=(ksc, vsc),
                )
                kv = (ck, cv, ksc, vsc)
            else:
                ck, cv = kv
                ck = PG.write_page(ck, k_new, bt, idx, active)
                cv = PG.write_page(cv, v_new, bt, idx, active)
                o = ops.attention_decode(
                    q, ck, cv, jnp.asarray(cache_len, jnp.int32),
                    block_table=bt, window=cfg.window,
                )
                kv = (ck, cv)
        else:
            ck, cv = kv
            ck = _cache_update(cfg, ck, k_new, w_idx)
            cv = _cache_update(cfg, cv, v_new, w_idx)
            o = ops.attention_decode(
                q, ck, cv, jnp.asarray(cache_len, jnp.int32)
            )
            kv = (ck, cv)
        return x + C.dense(o.reshape(b, -1), p["wo"]), kv

    def mlp_dec(p, x):
        xn = C.norm(cfg, p["ln"], x)
        h = jax.nn.silu(C.dense(xn, p["wg"])) * C.dense(xn, p["wi"])
        return x + C.dense(h, p["wo"])

    def moe_dec(p, x):
        return C.moe_block(cfg, p, x[:, None, :])[:, 0, :]

    kk, vk = ("kp", "vp") if paged else ("k", "v")
    # scan xs carry the per-layer cache stacks; quantized pools append
    # their scale stacks so the whole cache moves through one scan
    kv_keys = (kk, vk) + (("ksc", "vsc") if quant else ())

    fam = cfg.family
    if fam in ("dense", "moe"):
        def body(x, inp):
            p, kv = inp[0], inp[1:]
            x, kv = attn_dec(p["attn"], x, kv)
            x = moe_dec(p["moe"], x) if "moe" in p else mlp_dec(p["mlp"], x)
            return x, kv
        x, kv_out = jax.lax.scan(
            body, x,
            (params["layers"],) + tuple(state[k] for k in kv_keys),
        )
        state = {**state, **dict(zip(kv_keys, kv_out))}
    elif fam == "ssm":
        # inactive (idle or spilled) rows must carry their recurrent state
        # through *untouched* — a spilled row's live ssm/conv is the part
        # of its context that never leaves the lane
        val = active[:, None] if active is not None else None

        def body(x, inp):
            p, s_ssm, s_conv = inp
            x, s_ssm, s_conv = C.mamba_decode_block(
                cfg, p["mamba"], x, s_ssm, s_conv, valid=val
            )
            return x, (s_ssm, s_conv)
        x, (ssm, conv) = jax.lax.scan(
            body, x, (params["layers"], state["ssm"], state["conv"])
        )
        state = {**state, "ssm": ssm, "conv": conv}
    elif fam == "hybrid":
        g = cfg.n_layers // cfg.attn_every
        a = cfg.attn_every
        ssm_g = state["ssm"].reshape(g, a, *state["ssm"].shape[1:])
        conv_g = state["conv"].reshape(g, a, *state["conv"].shape[1:])
        val = active[:, None] if active is not None else None

        def group(x, inp):
            gp, s_ssm, s_conv = inp[0], inp[1], inp[2]
            kv = inp[3:]

            def inner(x, i2):
                p, s1, s2 = i2
                x, s1, s2 = C.mamba_decode_block(
                    cfg, p["mamba"], x, s1, s2, valid=val
                )
                return x, (s1, s2)
            x, (s_ssm, s_conv) = jax.lax.scan(inner, x, (gp, s_ssm, s_conv))
            x, kv = attn_dec(params["shared_attn"], x, kv)
            x = mlp_dec(params["shared_mlp"], x)
            return x, (s_ssm, s_conv) + kv

        x, out = jax.lax.scan(
            group, x,
            (params["groups"], ssm_g, conv_g)
            + tuple(state[k] for k in kv_keys),
        )
        ssm, conv = out[0], out[1]
        state = {
            **state,
            "ssm": ssm.reshape(cfg.n_layers, *ssm.shape[2:]),
            "conv": conv.reshape(cfg.n_layers, *conv.shape[2:]),
            **dict(zip(kv_keys, out[2:])),
        }
    elif fam == "vlm":
        def group(x, inp):
            gp, cp, ck, cv, xk, xv = inp

            def inner(x, i2):
                p, ck1, cv1 = i2
                x, (ck1, cv1) = attn_dec(p["attn"], x, (ck1, cv1))
                x = mlp_dec(p["mlp"], x)
                return x, (ck1, cv1)
            x, (ck, cv) = jax.lax.scan(inner, x, (gp, ck, cv))
            # cross-attention to static vision K/V
            b = x.shape[0]
            hd = cfg.head_dim_
            pa = cp["attn"]
            xn = C.norm(cfg, pa["ln"], x)
            q = C.dense(xn, pa["wq"]).reshape(b, cfg.n_heads, hd)
            o = ops.attention_decode(
                q, xk, xv, jnp.asarray(cfg.n_vision_tokens, jnp.int32)
            )
            x = x + jnp.tanh(pa["gate"]) * C.dense(o.reshape(b, -1), pa["wo"])
            x = mlp_dec(cp["mlp"], x)
            return x, (ck, cv)

        x, (ks, vs) = jax.lax.scan(
            group, x,
            (params["groups"], params["cross"], state["k"], state["v"],
             state["xk"], state["xv"]),
        )
        state = {**state, "k": ks, "v": vs}
    else:
        raise ValueError(fam)

    x = C.norm(cfg, params["ln_f"], x)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = C.dense(x, w)
    if active is not None and pos.ndim == 1:
        state = {**state, "pos": pos + active.astype(jnp.int32)}
    else:
        state = {**state, "pos": pos + 1}
    if snap_every and "snap_table" in state and pos.ndim == 1:
        act = active if active is not None else jnp.ones_like(pos, bool)
        state = _snap_capture(state, state["pos"], act, snap_every)
    return logits, state


def prefill_chunk(
    cfg: ArchConfig, params, state, toks: jax.Array,   # (B, C) int32
    width: jax.Array,                                  # () or (B,) int32
    *, active: Optional[jax.Array] = None,             # (B,) bool
    cow: bool = False, snap_every: int = 0, logits_all: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Ingest up to C prompt tokens per row in one step.

    Row b's real tokens are ``toks[b, :width[b]]`` at absolute positions
    ``pos[b] .. pos[b]+width[b]-1``; the rest of the chunk is padding and
    never touches caches (masked multi-position K/V writes, zeroed-``dt``
    SSD no-ops, dropped page writes).  Returns logits at each row's
    *last real* position — exactly what a ``decode_step`` fed that position
    would return — and the state with per-row ``pos`` advanced by
    ``width`` for active rows.  ``width == 1`` rows degenerate to a decode
    step, so decode-phase rows can ride along in a mixed batch.

    Both block families chunk for real: attention runs one (C, hd) query
    block per row (``ops.attention_prefill_chunk``), and Mamba blocks run
    one masked per-row-width SSD scan seeded with the carried state
    (``C.mamba_prefill_block`` over ``ops.ssd_prefill_chunk``) — B*C-row
    GEMMs and one scan instead of C sequential dispatches.  Single-token
    decode is the C=1 case of the same block, so the two regimes share
    one accumulation order instead of two recurrences kept in parity by
    hand.

    ``snap_every`` (trace-time constant; recurrent families with a
    snapshot store) captures the post-chunk SSM/conv state of every row
    whose chunk ends exactly at a page boundary.  A chunk that *crosses*
    a boundary without ending there records nothing for it — callers that
    need full boundary coverage (the prefix-sharing engine) clip chunk
    widths to end at boundaries.

    ``logits_all=True`` (trace-time constant; the speculative-decoding
    verifier) returns logits at *every* chunk position — ``(B, C, V)``
    instead of ``(B, V)`` at the last real position.  In-chunk causality
    makes slot ``j``'s logits exact whenever slots ``0..j`` hold true
    tokens, which is precisely the prefix the greedy accept rule keeps.

    Requires ``per_row_pos`` decode state.  Sliding-window archs need the
    paged layout: the contiguous ring cache recycles slots the in-chunk
    queries still read.
    """
    pos = state["pos"]
    if pos.ndim != 1:
        raise ValueError("prefill_chunk needs per_row_pos=True decode state")
    paged = "block_table" in state
    quant = paged and "ksc" in state    # trace-time: int8 KV pools
    b, c = toks.shape
    uses_attn = cfg.family in ("dense", "moe", "hybrid", "vlm")
    if cfg.window and not paged and uses_attn:
        raise NotImplementedError(
            "chunked prefill with a sliding window needs layout='paged': "
            "the contiguous ring cache overwrites slots the in-chunk "
            "queries still read"
        )
    if active is None:
        active = jnp.ones((b,), bool)
    width = jnp.clip(
        jnp.broadcast_to(jnp.asarray(width, jnp.int32).reshape(-1), (b,)),
        1, c,
    )
    x = params["embed"][toks].astype(cfg.dtype_())     # (B, C, d)
    offs = jnp.arange(c, dtype=jnp.int32)[None, :]
    posmat = pos[:, None] + offs                       # (B, C) absolute pos
    valid = active[:, None] & (offs < width[:, None])  # (B, C) real tokens

    if paged:
        from repro.serving import pager as PG

        # copy-on-write at the chunk's first position: shared blocks are
        # a page-aligned prefix of the row, so only position ``pos`` can
        # land in one (later in-chunk positions fall in the same — now
        # private — page or in fresh blocks mapped below)
        state, pstate, bt = _paged_cow(state, pos, active, cow=cow)
        # map every block the chunk touches up front (multi-page-per-step;
        # admission-time reservation guarantees the pops succeed)
        pstate, bt = PG.alloc_range(
            pstate, bt, pos, pos + width - 1, active,
            page_size=state["kp"].shape[2], max_chunk=c,
        )
        state = _paged_commit(state, pstate, bt)

    def attn_chunk(p, x, kv):
        # ``kv`` mirrors decode_step: (ck, cv) or quantized
        # (ck, cv, ksc, vsc) per-layer cache tuple
        hkv, hd = cfg.n_kv_heads, cfg.head_dim_
        xn = C.norm(cfg, p["ln"], x)
        q = C.dense(xn, p["wq"], p.get("bq")).reshape(b, c, cfg.n_heads, hd)
        k_new = C.dense(xn, p["wk"], p.get("bk")).reshape(b, c, hkv, hd)
        v_new = C.dense(xn, p["wv"], p.get("bv")).reshape(b, c, hkv, hd)
        cos, sin = C.rope_freqs(cfg, posmat)           # (B, C, hd/2)
        q = C.apply_rope(q, cos, sin)
        k_new = C.apply_rope(k_new, cos, sin)
        if paged:
            from repro.serving import pager as PG

            bt = state["block_table"]
            if quant:
                ck, cv, ksc, vsc = kv
                ck, ksc = PG.write_page_chunk_quant(
                    ck, ksc, k_new, bt, pos, width, active
                )
                cv, vsc = PG.write_page_chunk_quant(
                    cv, vsc, v_new, bt, pos, width, active
                )
                o = ops.attention_prefill_chunk(
                    q, ck, cv, pos, width, block_table=bt,
                    window=cfg.window, kv_scales=(ksc, vsc),
                )
                kv = (ck, cv, ksc, vsc)
            else:
                ck, cv = kv
                ck = PG.write_page_chunk(ck, k_new, bt, pos, width, active)
                cv = PG.write_page_chunk(cv, v_new, bt, pos, width, active)
                o = ops.attention_prefill_chunk(
                    q, ck, cv, pos, width, block_table=bt, window=cfg.window
                )
                kv = (ck, cv)
        else:
            ck, cv = kv
            ck = _cache_update_chunk(ck, k_new, posmat, valid)
            cv = _cache_update_chunk(cv, v_new, posmat, valid)
            o = ops.attention_prefill_chunk(q, ck, cv, pos, width)
            kv = (ck, cv)
        return x + C.dense(o.reshape(b, c, -1), p["wo"]), kv

    def mlp_chunk(p, x):
        xn = C.norm(cfg, p["ln"], x)
        h = jax.nn.silu(C.dense(xn, p["wg"])) * C.dense(xn, p["wi"])
        return x + C.dense(h, p["wo"])

    def mamba_chunk(p, x, s_ssm, s_conv):
        # one chunked SSD call per block: the carried state seeds the scan
        # and padding positions are algebraic no-ops (zeroed dt, width-
        # bounded conv gather), so per-row widths can't corrupt the carry
        return C.mamba_prefill_block(cfg, p, x, s_ssm, s_conv, valid)

    kk, vk = ("kp", "vp") if paged else ("k", "v")
    kv_keys = (kk, vk) + (("ksc", "vsc") if quant else ())

    fam = cfg.family
    if fam in ("dense", "moe"):
        def body(x, inp):
            p, kv = inp[0], inp[1:]
            x, kv = attn_chunk(p["attn"], x, kv)
            x = (C.moe_block(cfg, p["moe"], x) if "moe" in p
                 else mlp_chunk(p["mlp"], x))
            return x, kv
        x, kv_out = jax.lax.scan(
            body, x,
            (params["layers"],) + tuple(state[k] for k in kv_keys),
        )
        state = {**state, **dict(zip(kv_keys, kv_out))}
    elif fam == "ssm":
        def body(x, inp):
            p, s_ssm, s_conv = inp
            x, s_ssm, s_conv = mamba_chunk(p["mamba"], x, s_ssm, s_conv)
            return x, (s_ssm, s_conv)
        x, (ssm, conv) = jax.lax.scan(
            body, x, (params["layers"], state["ssm"], state["conv"])
        )
        state = {**state, "ssm": ssm, "conv": conv}
    elif fam == "hybrid":
        g = cfg.n_layers // cfg.attn_every
        a = cfg.attn_every
        ssm_g = state["ssm"].reshape(g, a, *state["ssm"].shape[1:])
        conv_g = state["conv"].reshape(g, a, *state["conv"].shape[1:])

        def group(x, inp):
            gp, s_ssm, s_conv = inp[0], inp[1], inp[2]
            kv = inp[3:]

            def inner(x, i2):
                p, s1, s2 = i2
                x, s1, s2 = mamba_chunk(p["mamba"], x, s1, s2)
                return x, (s1, s2)
            x, (s_ssm, s_conv) = jax.lax.scan(inner, x, (gp, s_ssm, s_conv))
            x, kv = attn_chunk(params["shared_attn"], x, kv)
            x = mlp_chunk(params["shared_mlp"], x)
            return x, (s_ssm, s_conv) + kv

        x, out = jax.lax.scan(
            group, x,
            (params["groups"], ssm_g, conv_g)
            + tuple(state[k] for k in kv_keys),
        )
        ssm, conv = out[0], out[1]
        state = {
            **state,
            "ssm": ssm.reshape(cfg.n_layers, *ssm.shape[2:]),
            "conv": conv.reshape(cfg.n_layers, *conv.shape[2:]),
            **dict(zip(kv_keys, out[2:])),
        }
    else:
        raise NotImplementedError(
            f"prefill_chunk: unsupported family {fam!r}"
        )

    # logits at each row's last real position (gather-then-norm: the final
    # norm and head are position-wise, so this equals the decode_step there)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if logits_all:
        # verifier path: every chunk position's logits (B, C, V); padding
        # positions carry garbage the caller masks by width
        h = C.norm(cfg, params["ln_f"], x)
        logits = C.dense(h, w)
    else:
        last = jnp.take_along_axis(
            x, (width - 1)[:, None, None], axis=1
        )[:, 0]
        h = C.norm(cfg, params["ln_f"], last)
        logits = C.dense(h, w)
    state = {**state, "pos": pos + jnp.where(active, width, 0)}
    if snap_every and "snap_table" in state:
        state = _snap_capture(state, state["pos"], active, snap_every)
    return logits, state


def prefill(
    cfg: ArchConfig, params, tokens: jax.Array,
    *, vision: Optional[jax.Array] = None,
) -> jax.Array:
    """Prefill = forward pass producing last-position logits (caches omitted
    in the benchmarked path; decode cells measure steady-state decode)."""
    h = forward(cfg, params, tokens, vision=vision, remat=False)
    return lm_logits(cfg, params, h[:, -1:, :])[:, 0]


def reset_decode_rows(
    cfg: ArchConfig, state: Dict[str, jax.Array], mask: jax.Array,  # (B,) bool
    start: jax.Array = 0,                                  # () or (B,) int32
) -> Dict[str, jax.Array]:
    """Zero the decode caches of the rows selected by ``mask``.

    The slot-refill path of the serving engine: a finished row's caches are
    reset in place (no retracing, no reallocation) before a queued request
    is admitted into it.  Requires ``per_row_pos`` state — with a scalar
    ``pos`` the rows share a clock and cannot be reset independently.

    ``start`` places the reset rows' decode clock (prefix-sharing
    admission: positions below ``start`` are already cached in pages the
    engine maps via ``pager.share_prefix`` right after this reset, so
    prefill resumes at the first unshared token instead of position 0).
    """
    if state["pos"].ndim != 1:
        raise ValueError(
            "reset_decode_rows needs per_row_pos=True decode state"
        )
    # drf_* is the hybrid_ssm drafter's private recurrent state
    # (repro.serving.drafter): batch axis 1, zeroed like ssm/conv
    known = {"k", "v", "ssm", "conv", "xk", "xv", "drf_ssm", "drf_conv"}
    paged_keys = {"kp", "vp", "ksc", "vsc", "block_table", "page_free",
                  "page_top", "page_rc"}
    snap_keys = {"snap_ssm", "snap_conv", "snap_table", "snap_free",
                 "snap_top", "snap_rc"}
    host_keys = {"hkp", "hvp", "hksc", "hvsc", "host_table", "host_free",
                 "host_top", "host_rc"}
    hsnap_keys = {"hsnap_ssm", "hsnap_conv", "hsnap_table", "hsnap_free",
                  "hsnap_top", "hsnap_rc"}
    unknown = (set(state) - known - paged_keys - snap_keys - host_keys
               - hsnap_keys - {"pos", "drf_pos"})
    if unknown:
        # fail loudly: a silently-skipped cache key would leak the previous
        # request's state into the slot's next occupant
        raise ValueError(
            f"reset_decode_rows: unhandled decode-state keys {sorted(unknown)}"
            " — declare their batch axis here before serving with them"
        )
    out = dict(state)
    out["pos"] = jnp.where(mask, jnp.asarray(start, jnp.int32), state["pos"])
    if "drf_pos" in state:
        # the drafter's ingestion clock resets with the row's decode clock
        out["drf_pos"] = jnp.where(
            mask, jnp.asarray(start, jnp.int32), state["drf_pos"]
        )
    if "block_table" in state:
        # paged layout: a reset row *releases* its pages (the pool is global
        # and is never zeroed — a recycled page is fully overwritten by its
        # next owner before any masked-in read can see it); pages still
        # referenced by a prefix-sharing peer stay resident (refcounts)
        from repro.serving import pager as PG

        pstate, bt = PG.release_rows(
            PG.PagerState(state["page_free"], state["page_top"],
                          state["page_rc"]),
            state["block_table"], mask,
        )
        out["block_table"] = bt
        out["page_free"], out["page_top"] = pstate.free, pstate.top
        out["page_rc"] = pstate.rc
    if "snap_table" in state:
        # snapshot slots are released with their rows exactly like pages:
        # refs drop, slots still held by a prefix-sharing peer stay
        # resident, and the pools are never zeroed (a recycled slot is
        # fully overwritten at its next boundary capture before any
        # restore can read it)
        from repro.serving import pager as PG

        sstate, stbl = PG.release_rows(
            PG.PagerState(state["snap_free"], state["snap_top"],
                          state["snap_rc"]),
            state["snap_table"], mask,
        )
        out["snap_table"] = stbl
        out["snap_free"], out["snap_top"] = sstate.free, sstate.top
        out["snap_rc"] = sstate.rc
    if "host_table" in state:
        # a row cancelled *while spilled* drains through the same path:
        # its host slots are released exactly like device pages (host
        # copies are private — rc == 1 — so they always return to the
        # host free list; the pools are never zeroed)
        from repro.serving import pager as PG

        hstate, ht = PG.release_rows(
            PG.PagerState(state["host_free"], state["host_top"],
                          state["host_rc"]),
            state["host_table"], mask,
        )
        out["host_table"] = ht
        out["host_free"], out["host_top"] = hstate.free, hstate.top
        out["host_rc"] = hstate.rc
    if "hsnap_table" in state:
        from repro.serving import pager as PG

        hs, hstbl = PG.release_rows(
            PG.PagerState(state["hsnap_free"], state["hsnap_top"],
                          state["hsnap_rc"]),
            state["hsnap_table"], mask,
        )
        out["hsnap_table"] = hstbl
        out["hsnap_free"], out["hsnap_top"] = hs.free, hs.top
        out["hsnap_rc"] = hs.rc
    for key in known & set(state):
        v = state[key]
        # batch axis: (layers/groups, B, ...) except the VLM self-attn cache,
        # which is (groups, per, B, ...)
        axis = 2 if cfg.family == "vlm" and key in ("k", "v") else 1
        shape = [1] * v.ndim
        shape[axis] = mask.shape[0]
        out[key] = jnp.where(
            mask.reshape(shape), jnp.zeros((), v.dtype), v
        )
    return out


def prefill_vlm_cross_cache(cfg: ArchConfig, params, vision, state):
    """Fill the static cross K/V from vision embeddings (VLM serving)."""
    g = cfg.n_layers // cfg.cross_attn_every
    hkv, hd = cfg.n_kv_heads, cfg.head_dim_

    def per_group(cp):
        pa = cp["attn"]
        src = C.norm(cfg, pa["ln"], vision)
        k = C.dense(src, pa["wk"]).reshape(*vision.shape[:2], hkv, hd)
        v = C.dense(src, pa["wv"]).reshape(*vision.shape[:2], hkv, hd)
        return k, v

    xk, xv = jax.vmap(per_group)(params["cross"])
    return {**state, "xk": xk, "xv": xv}
