"""Fused softmax / softmax-cross-entropy Pallas kernels.

Caffe's SoftMax and SoftMaxWithLoss blocks.  The fusion (max-subtract, exp,
normalize, label-gather, and the analytic backward p - onehot in one VMEM
round-trip) is exactly the "merge small parallel activities into fewer,
more complex kernels" step the paper's §4.3 prescribes as future work — we
implement it.

Grid is over row blocks; the full class/vocab dimension lives in VMEM per
block (LeNet: 10; the LM configs: ≤152k f32 rows ≈ 0.6 MB — fits).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as plc

from repro.core.policy import interpret_default
from repro.core.registry import get_tuning
from repro.tuning.shapes import shape_class
from repro.kernels.gemm import pad_to


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def softmax_pallas(x: jax.Array, interpret=None) -> jax.Array:
    """Row softmax over the last axis (any leading rank)."""
    if interpret is None:
        interpret = interpret_default()
    orig = x.shape
    x2 = x.reshape(-1, orig[-1])
    r, v = x2.shape
    t = get_tuning("softmax", key=shape_class(r=r, v=v), br=256)
    br = min(t["br"], r)
    xp = pad_to(x2, (br, v))
    if xp.shape[0] != r:
        # pad rows with zeros; padded rows produce finite softmax, sliced off
        pass
    grid = (xp.shape[0] // br,)
    out = pl.pallas_call(
        _softmax_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, v), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=interpret,
        compiler_params=plc.CompilerParams(dimension_semantics=("parallel",)),
        name="repro_softmax",
    )(xp)
    return out[:r].reshape(orig)


def _xent_kernel(x_ref, y_ref, loss_ref, p_ref, *, v: int):
    x = x_ref[...].astype(jnp.float32)               # (br, V)
    y = y_ref[...]                                   # (br, 1) int32
    m = jnp.max(x, axis=-1, keepdims=True)
    s = x - m
    lse = jnp.log(jnp.sum(jnp.exp(s), axis=-1, keepdims=True))
    logp = s - lse                                   # (br, V)
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, logp.shape, 1) == y
    )
    nll = -jnp.sum(jnp.where(onehot, logp, 0.0), axis=-1, keepdims=True)
    loss_ref[...] = nll
    p_ref[...] = jnp.exp(logp).astype(p_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def softmax_xent_pallas(logits: jax.Array, labels: jax.Array, interpret=None):
    """(B,V), (B,) -> (mean nll, probs). Labels < 0 are treated as padding."""
    if interpret is None:
        interpret = interpret_default()
    b, v = logits.shape
    t = get_tuning("softmax_xent", key=shape_class(b=b, v=v), br=128)
    br = min(t["br"], b)
    xp = pad_to(logits, (br, v))
    yp = pad_to(labels.astype(jnp.int32).reshape(-1, 1), (br, 1))
    grid = (xp.shape[0] // br,)
    nll, probs = pl.pallas_call(
        functools.partial(_xent_kernel, v=v),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, v), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, v), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.float32),
            jax.ShapeDtypeStruct(xp.shape, logits.dtype),
        ],
        interpret=interpret,
        compiler_params=plc.CompilerParams(dimension_semantics=("parallel",)),
        name="repro_softmax_xent",
    )(xp, yp)
    return nll[:b, 0].mean(), probs[:b]


def _xent_bwd_kernel(p_ref, y_ref, o_ref, *, scale: float):
    p = p_ref[...].astype(jnp.float32)
    y = y_ref[...]
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, p.shape, 1) == y
    ).astype(jnp.float32)
    o_ref[...] = ((p - onehot) * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def softmax_xent_bwd_pallas(probs: jax.Array, labels: jax.Array, interpret=None):
    if interpret is None:
        interpret = interpret_default()
    b, v = probs.shape
    t = get_tuning("softmax_xent", key=shape_class(b=b, v=v), br=128)
    br = min(t["br"], b)
    pp = pad_to(probs, (br, v))
    yp = pad_to(labels.astype(jnp.int32).reshape(-1, 1), (br, 1))
    # padded rows get onehot on a real class but are sliced away
    grid = (pp.shape[0] // br,)
    out = pl.pallas_call(
        functools.partial(_xent_bwd_kernel, scale=1.0 / b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, v), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(pp.shape, probs.dtype),
        interpret=interpret,
        compiler_params=plc.CompilerParams(dimension_semantics=("parallel",)),
        name="repro_softmax_xent_bwd",
    )(pp, yp)
    return out[:b]
