"""Mamba-2 SSD (state-space duality) chunked-scan Pallas kernel.

TPU-native layout of the SSD algorithm (arXiv:2405.21060): grid
(B, H, n_chunks) with the chunk axis sequential; the running (P, N) state
lives in VMEM f32 scratch across chunk steps.  Every compute inside the
kernel is a 2-D MXU matmul:

    cb       = C @ B^T                      (L,L)   intra-chunk kernel
    y_intra  = (cb ⊙ seg ⊙ dt_u) @ x        (L,P)
    y_inter  = (C ⊙ e^cum) @ state^T        (L,P)
    state'   = e^{cum_L} state + x^T @ (B ⊙ decay·dt)   (P,N)

Supports n_groups == 1 (the Mamba-2 2.7B / Zamba2 configuration); grouped
B/C falls back to the reference oracle.  Backward is the reference vjp
(recorded, like the paper's partially-ported blocks).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pallas_compat as plc

from repro.core.policy import interpret_default
from repro.core.registry import get_tuning
from repro.tuning.shapes import shape_class


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, hf_ref, state_ref,
    *, n_c: int, chunk: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0].astype(jnp.float32)           # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (L,)
    a = a_ref[0, 0]                                   # scalar
    bmat = b_ref[0].astype(jnp.float32)               # (L, N)
    cmat = c_ref[0].astype(jnp.float32)               # (L, N)

    dA = dt * a                                       # (L,)
    cum = jnp.cumsum(dA)                              # (L,)
    seg = cum[:, None] - cum[None, :]                 # (L, L)
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    seg = jnp.where(tri, jnp.exp(seg), 0.0)
    cb = jnp.dot(cmat, bmat.T, preferred_element_type=jnp.float32)
    att = cb * seg * dt[None, :]
    y = jnp.dot(att, x, preferred_element_type=jnp.float32)
    state = state_ref[...]                            # (P, N)
    y += jnp.dot(
        cmat * jnp.exp(cum)[:, None], state.T,
        preferred_element_type=jnp.float32,
    )
    y_ref[0, :, 0] = y.astype(y_ref.dtype)
    decay = jnp.exp(cum[-1] - cum) * dt               # (L,)
    state_ref[...] = jnp.exp(cum[-1]) * state + jnp.dot(
        x.T, bmat * decay[:, None], preferred_element_type=jnp.float32
    )

    @pl.when(ic == n_c - 1)
    def _done():
        hf_ref[0, 0] = state_ref[...].astype(jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret", "tuning_op")
)
def ssd_scan_pallas(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H)
    A: jax.Array,    # (H,)
    B_: jax.Array,   # (B, S, 1, N)  — n_groups == 1
    C: jax.Array,    # (B, S, 1, N)
    *,
    chunk: int = 64,
    initial_state: Optional[jax.Array] = None,   # (B, H, P, N)
    interpret=None,
    tuning_op: str = "ssd_scan",
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N)).

    ``tuning_op`` names the tuning-table entry the chunk size resolves
    from: the training path tunes as ``"ssd_scan"``, the serving path
    (``ops.ssd_prefill_chunk``) as ``"ssd_prefill_chunk"`` — so serving's
    knob is never overridden by a training setting."""
    if interpret is None:
        interpret = interpret_default()
    b, s, h, p = x.shape
    assert B_.shape[2] == 1, "pallas SSD kernel supports n_groups=1"
    n = B_.shape[3]
    t = get_tuning(tuning_op, key=shape_class(s=s), chunk=chunk)
    # a chunk longer than the sequence is identical math on pure padding
    # (dt pads with 0 = state no-op): clamp so short sequences — down to
    # the S=1 decode-as-C=1 case — never pay a full chunk of dead MXU work
    chunk = max(1, min(t["chunk"], s))
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = x.shape[1]
    n_c = sp // chunk
    h0 = (
        initial_state
        if initial_state is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )
    grid = (b, h, n_c)
    y, hf = pl.pallas_call(
        functools.partial(_ssd_kernel, n_c=n_c, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, ih, ic: (b_, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b_, ih, ic: (b_, ic, ih)),
            pl.BlockSpec((1, 1), lambda b_, ih, ic: (0, ih)),
            pl.BlockSpec((1, chunk, n), lambda b_, ih, ic: (b_, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, ih, ic: (b_, ic, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, ih, ic: (b_, ih, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, ih, ic: (b_, ic, ih, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, ih, ic: (b_, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, sp, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[plc.VMEM((p, n), jnp.float32)],
        interpret=interpret,
        compiler_params=plc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        name="repro_ssd_scan",
    )(
        x,
        dt,
        A.reshape(1, h).astype(jnp.float32),
        B_.reshape(b, sp, n),
        C.reshape(b, sp, n),
        h0,
    )
    return y[:, :s], hf
