"""Fused direct convolution (implicit GEMM) — the paper's deferred
"highly-optimized, state-of-the-art convolutional scan", done TPU-style.

The im2col+GEMM path materializes the column matrix in HBM (duplicating
each input pixel up to KH*KW times).  This kernel never materializes it:
the grid runs over (batch, filter-tile) and the kernel body accumulates
KH*KW small MXU GEMMs — one (ft, C) x (C, OH*OW) dot per static (kh, kw)
shift — directly from the padded input tile in VMEM.  HBM traffic drops
from (1 + KH*KW)x input reads + column writes to a single input read.

Beyond-paper optimization; benchmarked against the im2col path in
tests/test_kernels_conv_direct.py (bytes via the HLO cost model).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as plc

from repro.core.policy import interpret_default
from repro.core.registry import get_tuning
from repro.tuning.shapes import shape_class
from repro.kernels.ref import conv_out_size


def _conv_direct_kernel(x_ref, w_ref, b_ref, o_ref, *, kh, kw, stride,
                        oh, ow, c, ft, has_bias):
    x = x_ref[0]                                     # (C, HP, WP)
    w = w_ref[...]                                   # (ft, C, KH, KW)
    acc = jnp.zeros((ft, oh * ow), jnp.float32)
    for i in range(kh):                              # static KH*KW unroll:
        for j in range(kw):                          # one MXU dot per shift
            win = jax.lax.slice(
                x,
                (0, i, j),
                (c, i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1),
                (1, stride, stride),
            ).reshape(c, oh * ow)
            acc += jnp.dot(
                w[:, :, i, j], win, preferred_element_type=jnp.float32
            )
    if has_bias:
        acc += b_ref[...].astype(jnp.float32).reshape(ft, 1)
    o_ref[0] = acc.reshape(ft, oh, ow).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("stride", "pad", "interpret")
)
def conv2d_direct_pallas(
    x: jax.Array,                 # (N, C, H, W)
    w: jax.Array,                 # (F, C, KH, KW)
    b: jax.Array | None = None,   # (F,)
    *,
    stride: int = 1,
    pad: int = 0,
    interpret=None,
) -> jax.Array:
    if interpret is None:
        interpret = interpret_default()
    n, c, h, wd = x.shape
    f, _, kh, kw = w.shape
    oh = conv_out_size(h, kh, stride, pad)
    ow = conv_out_size(wd, kw, stride, pad)
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    hp, wp = xp.shape[2], xp.shape[3]
    t = get_tuning("conv_direct", key=shape_class(c=c, f=f), ft=128)
    ft = min(t["ft"], f)
    fpad = (-f) % ft
    wf = jnp.pad(w, ((0, fpad), (0, 0), (0, 0), (0, 0)))
    has_bias = b is not None
    bf = jnp.pad(
        b if has_bias else jnp.zeros((f,), x.dtype), ((0, fpad),)
    )
    grid = (n, wf.shape[0] // ft)
    out = pl.pallas_call(
        functools.partial(
            _conv_direct_kernel, kh=kh, kw=kw, stride=stride,
            oh=oh, ow=ow, c=c, ft=ft, has_bias=has_bias,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, hp, wp), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((ft, c, kh, kw), lambda i, j: (j, 0, 0, 0)),
            pl.BlockSpec((ft,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((1, ft, oh, ow), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, wf.shape[0], oh, ow), x.dtype),
        interpret=interpret,
        compiler_params=plc.CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        name="repro_conv_direct",
    )(xp, wf, bf)
    return out[:, :f]
