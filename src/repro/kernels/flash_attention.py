"""Blockwise (flash) attention Pallas kernels — GQA, causal, sliding-window.

The LM-zoo's dominant compute hot-spot.  TPU-native design: online-softmax
accumulation in VMEM f32 scratch across the sequential KV-block grid axis;
Q/KV tiles are MXU-aligned; GQA is expressed *in the BlockSpec index maps*
(kv block index = q_head // group) so grouped KV is never materialized
g-fold — the paper's "avoid layout-conversion copies at boundaries" lesson
applied to head layout.

Kernels:
  _flash_fwd   : grid (B, Hq, nQ, nK) -> out, lse
  _flash_dq    : grid (B, Hq, nQ, nK) -> dq
  _flash_dkv   : grid (B, Hkv, nK, g*nQ) -> dk, dv  (inner axis walks the
                 g q-heads of the group × their q blocks; scratch persists)
  _flash_decode: single-q-row attention against a KV cache with *dynamic*
                 valid length (SMEM scalar), for serve_step.
  _flash_decode_paged: the same online softmax against a *paged* cache —
                 the per-row block table is a scalar-prefetch operand, so
                 the physical page each grid step DMAs is chosen in the
                 BlockSpec index map (the paper's "keep layout conversion
                 out of the compute loop" lesson: the gather costs an index
                 lookup, never a materialized copy of the cache).
  _flash_prefill_chunk[_paged]: chunked prompt ingestion — a (C, hd)
                 query block per row attends causally to the cache plus the
                 in-chunk tokens (written before the call), with per-row
                 ``start``/``width`` scalars; the paged variant reuses the
                 decode block-table indirection.

Causal/window block skipping uses pl.when so fully-masked tiles do no MXU
work (they still schedule — negligible next to the saved matmuls).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import pallas_compat as plc

from repro.core.policy import interpret_default
from repro.core.registry import get_tuning
from repro.tuning.shapes import shape_class

NEG_INF = float(-1e30)


def _mask(s, iq, ik, bq, bk, *, causal, window, sk):
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    m = kpos < sk  # kv padding
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return jnp.where(m, s, NEG_INF)


def _flash_fwd_kernel(
    q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref,
    *, scale, causal, window, n_k, bq, bk, sk,
):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level skip for causality / window
    run = jnp.bool_(True)
    if causal:
        run &= ik * bk <= (iq + 1) * bq - 1
    if window is not None:
        run &= (ik + 1) * bk - 1 > iq * bq - window

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = _mask(s, iq, ik, bq, bk, causal=causal, window=window, sk=sk)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _done():
        l = l_ref[...]
        l_safe = jnp.where(l == 0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_ref[...] + jnp.log(l_safe))[:, 0]


def _pad_seq(x, block, axis):
    pad = (-x.shape[axis]) % block
    if pad:
        cfg = [(0, 0)] * x.ndim
        cfg[axis] = (0, pad)
        x = jnp.pad(x, cfg)
    return x


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Sk, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    interpret=None,
):
    """Returns (out (B,Sq,Hq,D), lse (B,Hq,Sq))."""
    if interpret is None:
        interpret = interpret_default()
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else float(1.0 / np.sqrt(d))
    t = get_tuning("flash_attention", key=shape_class(d=d, s=sk),
                   bq=128, bk=128)
    bq, bk = min(t["bq"], sq), min(t["bk"], sk)
    qt = _pad_seq(q.transpose(0, 2, 1, 3), bq, 2)    # (B,Hq,Sq',D)
    kt = _pad_seq(k.transpose(0, 2, 1, 3), bk, 2)    # (B,Hkv,Sk',D)
    vt = _pad_seq(v.transpose(0, 2, 1, 3), bk, 2)
    n_q, n_k = qt.shape[2] // bq, kt.shape[2] // bk
    grid = (b, hq, n_q, n_k)
    out, lse = pl.pallas_call(
        functools.partial(
            _flash_fwd_kernel,
            scale=scale, causal=causal, window=window,
            n_k=n_k, bq=bq, bk=bk, sk=sk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, i, j, g=g: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, i, j, g=g: (b_, h // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h, i, j: (b_, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(qt.shape, q.dtype),
            jax.ShapeDtypeStruct(qt.shape[:3], jnp.float32),
        ],
        scratch_shapes=[
            plc.VMEM((bq, d), jnp.float32),
            plc.VMEM((bq, 1), jnp.float32),
            plc.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=plc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
        name="repro_flash_fwd",
    )(qt, kt, vt)
    out = out[:, :, :sq].transpose(0, 2, 1, 3)
    return out, lse[:, :, :sq]


# ---------------------------------------------------------------------------
# Backward
# ---------------------------------------------------------------------------

def _flash_dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, dd_ref, dq_ref, acc_ref,
    *, scale, causal, window, n_k, bq, bk, sk,
):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = jnp.bool_(True)
    if causal:
        run &= ik * bk <= (iq + 1) * bq - 1
    if window is not None:
        run &= (ik + 1) * bk - 1 > iq * bq - window

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0][:, None]                  # (bq,1)
        dd = dd_ref[0, 0][:, None]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = _mask(s, iq, ik, bq, bk, causal=causal, window=window, sk=sk)
        p = jnp.exp(s - lse)
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - dd)
        acc_ref[...] += jnp.dot(ds, k, preferred_element_type=jnp.float32) * scale

    @pl.when(ik == n_k - 1)
    def _done():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


def _flash_dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, dd_ref, dk_ref, dv_ref,
    dk_acc, dv_acc,
    *, scale, causal, window, n_q, n_inner, bq, bk, sk, sq,
):
    ik, inner = pl.program_id(2), pl.program_id(3)
    iq = inner % n_q

    @pl.when(inner == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    run = jnp.bool_(True)
    if causal:
        run &= ik * bk <= (iq + 1) * bq - 1
    if window is not None:
        run &= (ik + 1) * bk - 1 > iq * bq - window

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0][:, None]
        dd = dd_ref[0, 0][:, None]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = _mask(s, iq, ik, bq, bk, causal=causal, window=window, sk=sk)
        # mask padded q rows too (their lse is garbage)
        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        p = jnp.where(qpos < sq, jnp.exp(s - lse), 0.0)
        dv_acc[...] += jnp.dot(p.T, do, preferred_element_type=jnp.float32)
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - dd)
        dk_acc[...] += jnp.dot(ds.T, q, preferred_element_type=jnp.float32) * scale

    @pl.when(inner == n_inner - 1)
    def _done():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "interpret"),
)
def flash_attention_bwd_pallas(
    q, k, v, out, lse, do,
    *, causal=True, window=None, scale=None, interpret=None,
):
    """Returns (dq, dk, dv)."""
    if interpret is None:
        interpret = interpret_default()
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else float(1.0 / np.sqrt(d))
    t = get_tuning("flash_attention", key=shape_class(d=d, s=sk),
                   bq=128, bk=128)
    bq, bk = min(t["bq"], sq), min(t["bk"], sk)
    qt = _pad_seq(q.transpose(0, 2, 1, 3), bq, 2)
    kt = _pad_seq(k.transpose(0, 2, 1, 3), bk, 2)
    vt = _pad_seq(v.transpose(0, 2, 1, 3), bk, 2)
    dot = _pad_seq(do.transpose(0, 2, 1, 3), bq, 2)
    ot = _pad_seq(out.transpose(0, 2, 1, 3), bq, 2)
    dd = jnp.sum(dot.astype(jnp.float32) * ot.astype(jnp.float32), axis=-1)
    lse_p = _pad_seq(lse, bq, 2)
    n_q, n_k = qt.shape[2] // bq, kt.shape[2] // bk
    # --- dq ---
    grid = (b, hq, n_q, n_k)
    dq = pl.pallas_call(
        functools.partial(
            _flash_dq_kernel,
            scale=scale, causal=causal, window=window,
            n_k=n_k, bq=bq, bk=bk, sk=sk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, i, j, g=g: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, i, j, g=g: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h, i, j: (b_, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b_, h, i, j: (b_, h, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[plc.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
        compiler_params=plc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
        name="repro_flash_dq",
    )(qt, kt, vt, dot, lse_p, dd)
    # --- dk, dv --- inner axis = (q-head-in-group, q-block)
    n_inner = g * n_q
    grid2 = (b, hkv, n_k, n_inner)

    def qix(b_, h, jk, inner, g=g, n_q=n_q):
        return (b_, h * g + inner // n_q, inner % n_q, 0)

    def qix3(b_, h, jk, inner, g=g, n_q=n_q):
        return (b_, h * g + inner // n_q, inner % n_q)

    dk, dv = pl.pallas_call(
        functools.partial(
            _flash_dkv_kernel,
            scale=scale, causal=causal, window=window,
            n_q=n_q, n_inner=n_inner, bq=bq, bk=bk, sk=sk, sq=sq,
        ),
        grid=grid2,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), qix),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, jk, inner: (b_, h, jk, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, jk, inner: (b_, h, jk, 0)),
            pl.BlockSpec((1, 1, bq, d), qix),
            pl.BlockSpec((1, 1, bq), qix3),
            pl.BlockSpec((1, 1, bq), qix3),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, jk, inner: (b_, h, jk, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, jk, inner: (b_, h, jk, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(kt.shape, k.dtype),
            jax.ShapeDtypeStruct(vt.shape, v.dtype),
        ],
        scratch_shapes=[
            plc.VMEM((bk, d), jnp.float32),
            plc.VMEM((bk, d), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=plc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
        name="repro_flash_dkv",
    )(qt, kt, vt, dot, lse_p, dd)
    dq = dq[:, :, :sq].transpose(0, 2, 1, 3)
    dk = dk[:, :, :sk].transpose(0, 2, 1, 3)
    dv = dv[:, :, :sk].transpose(0, 2, 1, 3)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# Decode: one new token vs a KV cache of dynamic valid length (SMEM scalar)
# ---------------------------------------------------------------------------

# The contiguous and paged decode kernels share one online-softmax block
# (init / accumulate-a-KV-tile / finalize) so a numerics change cannot
# de-synchronize the two layouts; they differ only in how a grid step maps
# to cache positions (kpos_base) and in which tiles are skipped (`run`).

def _decode_init(acc_ref, m_ref, l_ref):
    acc_ref[...] = jnp.zeros_like(acc_ref)
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)


def _online_update(s, v, acc_ref, m_ref, l_ref):
    """One online-softmax accumulation of a masked score tile ``s``."""
    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new


def _decode_accum(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref, *,
                  kpos_base, cache_len, window, scale,
                  k_s=None, v_s=None, bs=None):
    """``k_s``/``v_s`` (quantized pools) are the page's per-head dequant
    scales — applied right after the f32 upcast, so scores and the online
    softmax always accumulate in f32 regardless of storage dtype.  ``bs``
    statically unrolls the tile into bs-row sub-tiles (the quantized
    kernels' tuning knob); ``None`` keeps the single-tile accumulation
    bit-identical to the pre-quantization kernels."""
    q = q_ref[0, 0].astype(jnp.float32)           # (g, d) rows = heads grp
    k = k_ref[0, 0].astype(jnp.float32)           # (tile, d)
    v = v_ref[0, 0].astype(jnp.float32)
    if k_s is not None:
        k = k * k_s
        v = v * v_s
    tile = k.shape[0]
    step = tile if bs is None else bs
    for t in range(tile // step):
        k_t = jax.lax.slice_in_dim(k, t * step, (t + 1) * step, axis=0)
        v_t = jax.lax.slice_in_dim(v, t * step, (t + 1) * step, axis=0)
        s = jnp.dot(q, k_t.T, preferred_element_type=jnp.float32) * scale
        kpos = (kpos_base + t * step
                + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1))
        valid = kpos < cache_len
        if window is not None:
            valid &= kpos >= cache_len - window
        s = jnp.where(valid, s, NEG_INF)
        _online_update(s, v_t, acc_ref, m_ref, l_ref)


def _decode_finalize(o_ref, acc_ref, l_ref):
    l = l_ref[...]
    o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0, 1.0, l)).astype(
        o_ref.dtype
    )


def _flash_decode_kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale, n_k, bk, window,
):
    ik = pl.program_id(2)
    cache_len = len_ref[pl.program_id(0)]  # per-sequence valid length

    @pl.when(ik == 0)
    def _init():
        _decode_init(acc_ref, m_ref, l_ref)

    # skip blocks entirely beyond the valid length (or before the window)
    run = ik * bk < cache_len
    if window is not None:
        run &= (ik + 1) * bk - 1 >= cache_len - window

    @pl.when(run)
    def _body():
        _decode_accum(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
                      kpos_base=ik * bk, cache_len=cache_len,
                      window=window, scale=scale)

    @pl.when(ik == n_k - 1)
    def _done():
        _decode_finalize(o_ref, acc_ref, l_ref)


@functools.partial(
    jax.jit, static_argnames=("window", "scale", "interpret")
)
def flash_decode_pallas(
    q: jax.Array,        # (B, Hq, D)  one token per sequence
    k_cache: jax.Array,  # (B, Smax, Hkv, D)
    v_cache: jax.Array,
    cache_len: jax.Array,  # int32 () or (B,): valid prefix len (incl. new tok)
    *,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    interpret=None,
):
    if interpret is None:
        interpret = interpret_default()
    b, hq, d = q.shape
    _, smax, hkv, _ = k_cache.shape
    g = hq // hkv
    scale = scale if scale is not None else float(1.0 / np.sqrt(d))
    t = get_tuning("flash_decode", key=shape_class(s=smax), bk=512)
    bk = min(t["bk"], smax)
    kt = _pad_seq(k_cache.transpose(0, 2, 1, 3), bk, 2)  # (B,Hkv,S',D)
    vt = _pad_seq(v_cache.transpose(0, 2, 1, 3), bk, 2)
    n_k = kt.shape[2] // bk
    # group query heads of one kv head into rows of a single matmul
    qg = q.reshape(b, hkv, g, d)
    grid = (b, hkv, n_k)
    out = pl.pallas_call(
        functools.partial(
            _flash_decode_kernel, scale=scale, n_k=n_k, bk=bk, window=window
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=plc.MemorySpace.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda b_, h, j: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, j: (b_, h, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, j: (b_, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, h, j: (b_, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[
            plc.VMEM((g, d), jnp.float32),
            plc.VMEM((g, 1), jnp.float32),
            plc.VMEM((g, 1), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=plc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        name="repro_flash_decode",
    )(
        jnp.broadcast_to(cache_len.reshape(-1).astype(jnp.int32), (b,)),
        qg, kt, vt,
    )
    return out.reshape(b, hq, d)


# ---------------------------------------------------------------------------
# Paged decode: block-table-indirect KV pages, gathered in the index map
# ---------------------------------------------------------------------------

def _flash_decode_paged_kernel(
    len_ref, bt_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale, n_b, page, window,
):
    ib, j = pl.program_id(0), pl.program_id(2)
    cache_len = len_ref[ib]

    @pl.when(j == 0)
    def _init():
        _decode_init(acc_ref, m_ref, l_ref)

    # skip unmapped pages and pages entirely beyond the valid prefix
    run = (bt_ref[ib, j] >= 0) & (j * page < cache_len)
    if window is not None:
        run &= (j + 1) * page - 1 >= cache_len - window

    @pl.when(run)
    def _body():
        _decode_accum(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
                      kpos_base=j * page, cache_len=cache_len,
                      window=window, scale=scale)

    @pl.when(j == n_b - 1)
    def _done():
        _decode_finalize(o_ref, acc_ref, l_ref)


@functools.partial(
    jax.jit, static_argnames=("window", "scale", "interpret")
)
def flash_decode_paged_pallas(
    q: jax.Array,            # (B, Hq, D)  one token per sequence
    k_pages: jax.Array,      # (n_pages, page_size, Hkv, D) shared page pool
    v_pages: jax.Array,
    cache_len: jax.Array,    # int32 () or (B,): valid prefix incl. new token
    block_table: jax.Array,  # (B, max_blocks) int32; -1 = unmapped
    *,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    interpret=None,
):
    """Decode attention over the paged KV layout (contract: pager.py).

    Grid (B, Hkv, max_blocks); the KV BlockSpec index maps read the
    scalar-prefetched block table to select the physical page — unmapped
    blocks clamp to page 0 and are skipped by ``pl.when``, so their DMA is
    harmless and no MXU work runs.
    """
    if interpret is None:
        interpret = interpret_default()
    b, hq, d = q.shape
    n_pages, page, hkv, _ = k_pages.shape
    n_b = block_table.shape[1]
    g = hq // hkv
    scale = scale if scale is not None else float(1.0 / np.sqrt(d))
    kt = k_pages.transpose(0, 2, 1, 3)            # (n_pages, Hkv, page, D)
    vt = v_pages.transpose(0, 2, 1, 3)
    qg = q.reshape(b, hkv, g, d)
    lens = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (b,)
    )

    def kv_ix(b_, h, j, lens_ref, bt_ref):
        return (jnp.maximum(bt_ref[b_, j], 0), h, 0, 0)

    grid_spec = plc.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                    # lens, block table
        grid=(b, hkv, n_b),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, h, j, *_: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, page, d), kv_ix),
            pl.BlockSpec((1, 1, page, d), kv_ix),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, h, j, *_: (b_, h, 0, 0)),
        scratch_shapes=[
            plc.VMEM((g, d), jnp.float32),
            plc.VMEM((g, 1), jnp.float32),
            plc.VMEM((g, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _flash_decode_paged_kernel,
            scale=scale, n_b=n_b, page=page, window=window,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
        compiler_params=plc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        name="repro_flash_decode_paged",
    )(lens, block_table, qg, kt, vt)
    return out.reshape(b, hq, d)


# ---------------------------------------------------------------------------
# Quantized paged decode: int8 pages, per-(page, head) scales prefetched to
# SMEM and applied inside the kernel right after the upcast
# ---------------------------------------------------------------------------

def _flash_decode_paged_quant_kernel(
    len_ref, bt_ref, ksc_ref, vsc_ref, q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, scale, n_b, page, bs, window,
):
    ib, h, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    cache_len = len_ref[ib]
    # the page the block table routed this grid step to — same clamp as
    # the BlockSpec index map, so skipped steps read scale 0 harmlessly
    pg = jnp.maximum(bt_ref[ib, j], 0)
    k_s = ksc_ref[pg, h]
    v_s = vsc_ref[pg, h]

    @pl.when(j == 0)
    def _init():
        _decode_init(acc_ref, m_ref, l_ref)

    run = (bt_ref[ib, j] >= 0) & (j * page < cache_len)
    if window is not None:
        run &= (j + 1) * page - 1 >= cache_len - window

    @pl.when(run)
    def _body():
        _decode_accum(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
                      kpos_base=j * page, cache_len=cache_len,
                      window=window, scale=scale,
                      k_s=k_s, v_s=v_s, bs=bs)

    @pl.when(j == n_b - 1)
    def _done():
        _decode_finalize(o_ref, acc_ref, l_ref)


@functools.partial(
    jax.jit, static_argnames=("window", "scale", "interpret")
)
def flash_decode_paged_quant_pallas(
    q: jax.Array,            # (B, Hq, D)  one token per sequence
    k_pages: jax.Array,      # (n_pages, page_size, Hkv, D) int8 page pool
    v_pages: jax.Array,
    k_scale: jax.Array,      # (n_pages, Hkv) f32 per-(page, head) scales
    v_scale: jax.Array,
    cache_len: jax.Array,    # int32 () or (B,): valid prefix incl. new token
    block_table: jax.Array,  # (B, max_blocks) int32; -1 = unmapped
    *,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    interpret=None,
):
    """Decode attention over the quantized paged KV layout.

    Same block-table indirection as ``flash_decode_paged_pallas``; the
    per-(page, head) scale pools ride the scalar prefetch into SMEM and
    the kernel multiplies them in right after the int8 -> f32 upcast, so
    scores and the online softmax accumulate in f32 (R007).  ``bs``
    (tuned) statically sub-tiles the page axis of the accumulation.
    """
    if interpret is None:
        interpret = interpret_default()
    b, hq, d = q.shape
    n_pages, page, hkv, _ = k_pages.shape
    n_b = block_table.shape[1]
    g = hq // hkv
    scale = scale if scale is not None else float(1.0 / np.sqrt(d))
    t = get_tuning("flash_decode_paged_quant", key=shape_class(p=page),
                   bs=16)
    bs = max(1, min(int(t["bs"]), page))
    while page % bs:
        bs //= 2
    kt = k_pages.transpose(0, 2, 1, 3)            # (n_pages, Hkv, page, D)
    vt = v_pages.transpose(0, 2, 1, 3)
    qg = q.reshape(b, hkv, g, d)
    lens = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (b,)
    )

    def kv_ix(b_, h, j, lens_ref, bt_ref, ksc_ref, vsc_ref):
        return (jnp.maximum(bt_ref[b_, j], 0), h, 0, 0)

    grid_spec = plc.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,        # lens, block table, k/v scale pools
        grid=(b, hkv, n_b),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, h, j, *_: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, page, d), kv_ix),
            pl.BlockSpec((1, 1, page, d), kv_ix),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, h, j, *_: (b_, h, 0, 0)),
        scratch_shapes=[
            plc.VMEM((g, d), jnp.float32),
            plc.VMEM((g, 1), jnp.float32),
            plc.VMEM((g, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _flash_decode_paged_quant_kernel,
            scale=scale, n_b=n_b, page=page, bs=bs, window=window,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
        compiler_params=plc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        name="repro_flash_decode_paged_quant",
    )(lens, block_table, k_scale.astype(jnp.float32),
      v_scale.astype(jnp.float32), qg, kt, vt)
    return out.reshape(b, hq, d)


# ---------------------------------------------------------------------------
# Chunked prefill: a (C, hd) query block per row vs the already-written cache
# ---------------------------------------------------------------------------

# Multi-token prompt ingestion.  The chunk's K/V are written into the cache
# *before* attention runs (same order as decode, which writes the current
# token first), so a grid step only needs the causal mask to separate
# in-chunk from already-cached keys.  The query block folds the GQA group
# and the chunk into one matmul: row r = gq * C + i is (head-in-group gq,
# chunk offset i) at absolute position start + i.  Padding rows (i >=
# width) alias the last real position so every softmax row keeps at least
# one finite score — their outputs are garbage-but-finite and the caller
# discards them (NaNs here would leak into real tokens through MoE
# dispatch buffers).

def _prefill_chunk_mask(s, *, kpos_base, start, width, c, window):
    i = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % c
    qpos = start + jnp.minimum(i, width - 1)
    kpos = kpos_base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = kpos <= qpos
    if window is not None:
        valid &= kpos > qpos - window
    return jnp.where(valid, s, NEG_INF)


def _prefill_chunk_accum(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref, *,
                         kpos_base, start, width, c, window, scale,
                         k_s=None, v_s=None, bs=None):
    # ``k_s``/``v_s``/``bs`` as in ``_decode_accum``: per-page dequant
    # scales applied after the f32 upcast, optional static sub-tiling
    q = q_ref[0, 0].astype(jnp.float32)           # (g*c, d)
    k = k_ref[0, 0].astype(jnp.float32)           # (tile, d)
    v = v_ref[0, 0].astype(jnp.float32)
    if k_s is not None:
        k = k * k_s
        v = v * v_s
    tile = k.shape[0]
    step = tile if bs is None else bs
    for t in range(tile // step):
        k_t = jax.lax.slice_in_dim(k, t * step, (t + 1) * step, axis=0)
        v_t = jax.lax.slice_in_dim(v, t * step, (t + 1) * step, axis=0)
        s = jnp.dot(q, k_t.T, preferred_element_type=jnp.float32) * scale
        s = _prefill_chunk_mask(s, kpos_base=kpos_base + t * step,
                                start=start, width=width, c=c,
                                window=window)
        _online_update(s, v_t, acc_ref, m_ref, l_ref)


def _flash_prefill_chunk_kernel(
    start_ref, w_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale, n_k, bk, c, window,
):
    ib, ik = pl.program_id(0), pl.program_id(2)
    start = start_ref[ib]
    width = w_ref[ib]

    @pl.when(ik == 0)
    def _init():
        _decode_init(acc_ref, m_ref, l_ref)

    # the last key any chunk query may see is start + width - 1; the first
    # query sits at start, so a window cuts tiles before start - window
    run = ik * bk <= start + width - 1
    if window is not None:
        run &= (ik + 1) * bk - 1 > start - window

    @pl.when(run)
    def _body():
        _prefill_chunk_accum(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
                             kpos_base=ik * bk, start=start, width=width,
                             c=c, window=window, scale=scale)

    @pl.when(ik == n_k - 1)
    def _done():
        _decode_finalize(o_ref, acc_ref, l_ref)


@functools.partial(
    jax.jit, static_argnames=("window", "scale", "interpret")
)
def flash_prefill_chunk_pallas(
    q: jax.Array,        # (B, C, Hq, D)  C prompt tokens per sequence
    k_cache: jax.Array,  # (B, Smax, Hkv, D) — chunk K/V already written
    v_cache: jax.Array,
    start: jax.Array,    # int32 () or (B,): absolute position of chunk tok 0
    width: jax.Array,    # int32 () or (B,): real tokens in the chunk (1..C)
    *,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    interpret=None,
):
    """Chunked-prefill attention over the contiguous cache layout.

    Query i of row b attends causally to absolute positions
    ``<= start[b] + i`` (window-limited when set); rows ``i >= width[b]``
    are padding and return finite garbage.  Kept in lock-step with the
    jnp oracle in ``repro.kernels.ops``.
    """
    if interpret is None:
        interpret = interpret_default()
    b, c, hq, d = q.shape
    _, smax, hkv, _ = k_cache.shape
    g = hq // hkv
    scale = scale if scale is not None else float(1.0 / np.sqrt(d))
    t = get_tuning("flash_prefill", key=shape_class(c=c, s=smax),
                   bk=512)
    bk = min(t["bk"], smax)
    kt = _pad_seq(k_cache.transpose(0, 2, 1, 3), bk, 2)   # (B,Hkv,S',D)
    vt = _pad_seq(v_cache.transpose(0, 2, 1, 3), bk, 2)
    n_k = kt.shape[2] // bk
    # fold (group head, chunk offset) into the matmul's row axis
    qg = q.reshape(b, c, hkv, g, d).transpose(0, 2, 3, 1, 4)
    qg = qg.reshape(b, hkv, g * c, d)
    grid = (b, hkv, n_k)
    out = pl.pallas_call(
        functools.partial(
            _flash_prefill_chunk_kernel,
            scale=scale, n_k=n_k, bk=bk, c=c, window=window,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=plc.MemorySpace.SMEM),
            pl.BlockSpec(memory_space=plc.MemorySpace.SMEM),
            pl.BlockSpec((1, 1, g * c, d), lambda b_, h, j: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, j: (b_, h, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, j: (b_, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g * c, d), lambda b_, h, j: (b_, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g * c, d), q.dtype),
        scratch_shapes=[
            plc.VMEM((g * c, d), jnp.float32),
            plc.VMEM((g * c, 1), jnp.float32),
            plc.VMEM((g * c, 1), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=plc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        name="repro_flash_prefill_chunk",
    )(
        jnp.broadcast_to(jnp.asarray(start, jnp.int32).reshape(-1), (b,)),
        jnp.broadcast_to(jnp.asarray(width, jnp.int32).reshape(-1), (b,)),
        qg, kt, vt,
    )
    out = out.reshape(b, hkv, g, c, d).transpose(0, 3, 1, 2, 4)
    return out.reshape(b, c, hq, d)


def _flash_prefill_chunk_paged_kernel(
    start_ref, w_ref, bt_ref, q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, scale, n_b, page, c, window,
):
    ib, j = pl.program_id(0), pl.program_id(2)
    start = start_ref[ib]
    width = w_ref[ib]

    @pl.when(j == 0)
    def _init():
        _decode_init(acc_ref, m_ref, l_ref)

    # skip unmapped pages and pages entirely beyond the chunk's last key
    run = (bt_ref[ib, j] >= 0) & (j * page <= start + width - 1)
    if window is not None:
        run &= (j + 1) * page - 1 > start - window

    @pl.when(run)
    def _body():
        _prefill_chunk_accum(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
                             kpos_base=j * page, start=start, width=width,
                             c=c, window=window, scale=scale)

    @pl.when(j == n_b - 1)
    def _done():
        _decode_finalize(o_ref, acc_ref, l_ref)


@functools.partial(
    jax.jit, static_argnames=("window", "scale", "interpret")
)
def flash_prefill_chunk_paged_pallas(
    q: jax.Array,            # (B, C, Hq, D)  C prompt tokens per sequence
    k_pages: jax.Array,      # (n_pages, page_size, Hkv, D) shared page pool
    v_pages: jax.Array,
    start: jax.Array,        # int32 () or (B,): absolute pos of chunk tok 0
    width: jax.Array,        # int32 () or (B,): real tokens in the chunk
    block_table: jax.Array,  # (B, max_blocks) int32; -1 = unmapped
    *,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    interpret=None,
):
    """Chunked-prefill attention over the paged layout (contract: pager.py).

    Same scalar-prefetch indirection as ``flash_decode_paged_pallas`` — the
    block table picks the physical page in the BlockSpec index map — with
    the multi-row causal chunk mask of ``flash_prefill_chunk_pallas``.
    Every block covering ``start .. start+width-1`` must be mapped before
    the call (``pager.alloc_range``).
    """
    if interpret is None:
        interpret = interpret_default()
    b, c, hq, d = q.shape
    n_pages, page, hkv, _ = k_pages.shape
    n_b = block_table.shape[1]
    g = hq // hkv
    scale = scale if scale is not None else float(1.0 / np.sqrt(d))
    kt = k_pages.transpose(0, 2, 1, 3)            # (n_pages, Hkv, page, D)
    vt = v_pages.transpose(0, 2, 1, 3)
    qg = q.reshape(b, c, hkv, g, d).transpose(0, 2, 3, 1, 4)
    qg = qg.reshape(b, hkv, g * c, d)
    starts = jnp.broadcast_to(
        jnp.asarray(start, jnp.int32).reshape(-1), (b,)
    )
    widths = jnp.broadcast_to(
        jnp.asarray(width, jnp.int32).reshape(-1), (b,)
    )

    def kv_ix(b_, h, j, starts_ref, w_ref, bt_ref):
        return (jnp.maximum(bt_ref[b_, j], 0), h, 0, 0)

    grid_spec = plc.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,                    # starts, widths, table
        grid=(b, hkv, n_b),
        in_specs=[
            pl.BlockSpec((1, 1, g * c, d), lambda b_, h, j, *_: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, page, d), kv_ix),
            pl.BlockSpec((1, 1, page, d), kv_ix),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g * c, d), lambda b_, h, j, *_: (b_, h, 0, 0)
        ),
        scratch_shapes=[
            plc.VMEM((g * c, d), jnp.float32),
            plc.VMEM((g * c, 1), jnp.float32),
            plc.VMEM((g * c, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _flash_prefill_chunk_paged_kernel,
            scale=scale, n_b=n_b, page=page, c=c, window=window,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g * c, d), q.dtype),
        interpret=interpret,
        compiler_params=plc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        name="repro_flash_prefill_chunk_paged",
    )(starts, widths, block_table, qg, kt, vt)
    out = out.reshape(b, hkv, g, c, d).transpose(0, 3, 1, 2, 4)
    return out.reshape(b, c, hq, d)


def _flash_prefill_chunk_paged_quant_kernel(
    start_ref, w_ref, bt_ref, ksc_ref, vsc_ref, q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, scale, n_b, page, bs, c, window,
):
    ib, h, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    start = start_ref[ib]
    width = w_ref[ib]
    pg = jnp.maximum(bt_ref[ib, j], 0)
    k_s = ksc_ref[pg, h]
    v_s = vsc_ref[pg, h]

    @pl.when(j == 0)
    def _init():
        _decode_init(acc_ref, m_ref, l_ref)

    run = (bt_ref[ib, j] >= 0) & (j * page <= start + width - 1)
    if window is not None:
        run &= (j + 1) * page - 1 > start - window

    @pl.when(run)
    def _body():
        _prefill_chunk_accum(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
                             kpos_base=j * page, start=start, width=width,
                             c=c, window=window, scale=scale,
                             k_s=k_s, v_s=v_s, bs=bs)

    @pl.when(j == n_b - 1)
    def _done():
        _decode_finalize(o_ref, acc_ref, l_ref)


@functools.partial(
    jax.jit, static_argnames=("window", "scale", "interpret")
)
def flash_prefill_chunk_paged_quant_pallas(
    q: jax.Array,            # (B, C, Hq, D)  C prompt tokens per sequence
    k_pages: jax.Array,      # (n_pages, page_size, Hkv, D) int8 page pool
    v_pages: jax.Array,
    k_scale: jax.Array,      # (n_pages, Hkv) f32 per-(page, head) scales
    v_scale: jax.Array,
    start: jax.Array,        # int32 () or (B,): absolute pos of chunk tok 0
    width: jax.Array,        # int32 () or (B,): real tokens in the chunk
    block_table: jax.Array,  # (B, max_blocks) int32; -1 = unmapped
    *,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    interpret=None,
):
    """Chunked-prefill attention over the quantized paged layout.

    ``flash_prefill_chunk_paged_pallas`` with the scale pools added to
    the scalar prefetch: dequant happens inside the kernel after the
    int8 -> f32 upcast, accumulation stays f32 (R007).  The chunk's own
    K/V must already be written (``pager.write_page_chunk_quant``).
    """
    if interpret is None:
        interpret = interpret_default()
    b, c, hq, d = q.shape
    n_pages, page, hkv, _ = k_pages.shape
    n_b = block_table.shape[1]
    g = hq // hkv
    scale = scale if scale is not None else float(1.0 / np.sqrt(d))
    t = get_tuning("flash_prefill_paged_quant",
                   key=shape_class(c=c, p=page), bs=16)
    bs = max(1, min(int(t["bs"]), page))
    while page % bs:
        bs //= 2
    kt = k_pages.transpose(0, 2, 1, 3)            # (n_pages, Hkv, page, D)
    vt = v_pages.transpose(0, 2, 1, 3)
    qg = q.reshape(b, c, hkv, g, d).transpose(0, 2, 3, 1, 4)
    qg = qg.reshape(b, hkv, g * c, d)
    starts = jnp.broadcast_to(
        jnp.asarray(start, jnp.int32).reshape(-1), (b,)
    )
    widths = jnp.broadcast_to(
        jnp.asarray(width, jnp.int32).reshape(-1), (b,)
    )

    def kv_ix(b_, h, j, starts_ref, w_ref, bt_ref, ksc_ref, vsc_ref):
        return (jnp.maximum(bt_ref[b_, j], 0), h, 0, 0)

    grid_spec = plc.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,    # starts, widths, table, k/v scale pools
        grid=(b, hkv, n_b),
        in_specs=[
            pl.BlockSpec((1, 1, g * c, d), lambda b_, h, j, *_: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, page, d), kv_ix),
            pl.BlockSpec((1, 1, page, d), kv_ix),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g * c, d), lambda b_, h, j, *_: (b_, h, 0, 0)
        ),
        scratch_shapes=[
            plc.VMEM((g * c, d), jnp.float32),
            plc.VMEM((g * c, 1), jnp.float32),
            plc.VMEM((g * c, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _flash_prefill_chunk_paged_quant_kernel,
            scale=scale, n_b=n_b, page=page, bs=bs, c=c, window=window,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g * c, d), q.dtype),
        interpret=interpret,
        compiler_params=plc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        name="repro_flash_prefill_chunk_paged_quant",
    )(starts, widths, block_table, k_scale.astype(jnp.float32),
      v_scale.astype(jnp.float32), qg, kt, vt)
    out = out.reshape(b, hkv, g, c, d).transpose(0, 3, 1, 2, 4)
    return out.reshape(b, c, hq, d)
