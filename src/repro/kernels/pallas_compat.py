"""Version-portable aliases for ``jax.experimental.pallas.tpu`` symbols.

JAX renamed ``TPUCompilerParams`` -> ``CompilerParams`` and
``TPUMemorySpace`` -> ``MemorySpace`` across releases.  Kernels import the
names from here so the same source compiles against either side of the
rename — the library-level analogue of the paper's single-source property
(the kernel text does not change when the toolchain does).
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
MemorySpace = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace
