"""Version-portable aliases for ``jax.experimental.pallas.tpu`` symbols.

JAX renamed ``TPUCompilerParams`` -> ``CompilerParams`` and
``TPUMemorySpace`` -> ``MemorySpace`` across releases.  Kernels import the
names from here so the same source compiles against either side of the
rename — the library-level analogue of the paper's single-source property
(the kernel text does not change when the toolchain does).

This module is the *only* place in the library allowed to import
``jax.experimental.pallas.tpu`` — lint rule R001 (``repro.analysis``)
enforces that every other module routes through these aliases.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu  # repro-lint: disable=R001

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
MemorySpace = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace

# Scratch-shape constructor for VMEM buffers: ``plc.VMEM((m, n), dtype)``.
VMEM = MemorySpace.VMEM
SMEM = MemorySpace.SMEM

# Grid spec with scalar prefetch (decode kernels' page tables); name has
# been stable but route it here so kernels never touch pltpu directly.
PrefetchScalarGridSpec = pltpu.PrefetchScalarGridSpec
