"""im2col / col2im Pallas kernels — the paper's signature transformation.

Caffe's original im2col is a penta-loop with loop-carried indices; the
paper's PHAST port *merges all loops and re-parameterizes with one flat
index* so every thread is independent.  The TPU-native re-think: the unit
of parallel work is not an element but a VMEM tile, and the (kh, kw) factor
of the flat index space is tiny and static — so we peel it into a static
Python loop *inside* the kernel (unrolled at trace time; each iteration is a
static slice, which Mosaic lowers to cheap vector moves), while the grid
runs over (batch, channel-block).  This keeps the "every output element is
written exactly once, no cross-cell dependency" property of the paper's
flat-index form.

im2col:  (N, C, H, W)            -> (N, C*KH*KW, OH*OW)
col2im:  (N, C*KH*KW, OH*OW)     -> (N, C, H, W)   [adjoint / scatter-add]

col2im is implemented in *gather* form (race-free: each input pixel sums
the ≤ KH*KW column entries that reference it) for stride == 1; other
strides fall back to the reference — recorded like the paper records its
partially-ported blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as plc

from repro.core.policy import interpret_default
from repro.kernels.ref import conv_out_size


def _im2col_kernel(x_ref, o_ref, *, kh, kw, stride, oh, ow, cb):
    # x_ref: (1, cb, HP, WP) padded input block
    # o_ref: (1, cb*kh*kw, oh*ow)
    x = x_ref[0]                                     # (cb, HP, WP)
    parts = []
    for i in range(kh):                              # static unroll: the
        for j in range(kw):                          # merged penta-loop's
            # (kh,kw) factor — each iter is a static strided slice
            win = jax.lax.slice(
                x,
                (0, i, j),
                (cb, i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1),
                (1, stride, stride),
            )                                        # (cb, oh, ow)
            parts.append(win.reshape(cb, 1, oh * ow))
    # row ordering matches the flat index (c, i, j): row = c*kh*kw + i*kw + j
    o_ref[0] = jnp.concatenate(parts, axis=1).reshape(cb * kh * kw, oh * ow)


@functools.partial(
    jax.jit, static_argnames=("kh", "kw", "stride", "pad", "interpret")
)
def im2col_pallas(
    x: jax.Array, kh: int, kw: int, stride: int = 1, pad: int = 0,
    interpret=None,
) -> jax.Array:
    if interpret is None:
        interpret = interpret_default()
    n, c, h, w = x.shape
    oh = conv_out_size(h, kh, stride, pad)
    ow = conv_out_size(w, kw, stride, pad)
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    hp, wp = xp.shape[2], xp.shape[3]
    cb = c  # channel block: LeNet-scale C fits VMEM whole; tune for big C
    grid = (n, c // cb)
    out = pl.pallas_call(
        functools.partial(
            _im2col_kernel, kh=kh, kw=kw, stride=stride, oh=oh, ow=ow, cb=cb
        ),
        grid=grid,
        in_specs=[pl.BlockSpec((1, cb, hp, wp), lambda i, j: (i, j, 0, 0))],
        out_specs=pl.BlockSpec(
            (1, cb * kh * kw, oh * ow), lambda i, j: (i, j, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((n, c * kh * kw, oh * ow), x.dtype),
        interpret=interpret,
        compiler_params=plc.CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        name="repro_im2col",
    )(xp)
    return out


def _col2im_kernel(c_ref, o_ref, *, kh, kw, oh, ow, h, w, pad, cb):
    # gather form, stride == 1:
    #   out[y, x] = sum_{i,j} cols[(i*kw+j), y+pad-i, x+pad-j]  (in-bounds)
    # Implemented by padding the (oh, ow) grid so every shift is a static
    # slice of the same padded buffer.
    cols = c_ref[0]                                  # (cb*kh*kw, oh*ow)
    cols = cols.reshape(cb, kh * kw, oh, ow)
    # pad the (oh, ow) grid so every (y+pad-i, x+pad-j) shift is a static
    # in-bounds slice of the same padded buffer
    acc = jnp.zeros((cb, h, w), jnp.float32)
    big = jnp.pad(
        cols,
        ((0, 0), (0, 0), (kh - 1, kh - 1), (kw - 1, kw - 1)),
    )  # (cb, kh*kw, oh + 2kh-2, ow + 2kw-2)
    for i in range(kh):
        for j in range(kw):
            # out[y,x] += cols[i*kw+j, y+pad-i, x+pad-j]
            # big index offset: (y + pad - i) + (kh-1) in padded coords
            ys = pad - i + (kh - 1)
            xs = pad - j + (kw - 1)
            acc = acc + jax.lax.slice(
                big[:, i * kw + j],
                (0, ys, xs),
                (cb, ys + h, xs + w),
            ).astype(jnp.float32)
    o_ref[0] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("x_shape", "kh", "kw", "stride", "pad", "interpret")
)
def col2im_pallas(
    cols: jax.Array,
    x_shape,
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
    interpret=None,
) -> jax.Array:
    if stride != 1:
        raise NotImplementedError("col2im_pallas supports stride=1; use ref")
    if interpret is None:
        interpret = interpret_default()
    n, c, h, w = x_shape
    oh = conv_out_size(h, kh, stride, pad)
    ow = conv_out_size(w, kw, stride, pad)
    cb = c
    grid = (n, c // cb)
    out = pl.pallas_call(
        functools.partial(
            _col2im_kernel, kh=kh, kw=kw, oh=oh, ow=ow, h=h, w=w, pad=pad, cb=cb
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cb * kh * kw, oh * ow), lambda i, j: (i, j, 0))
        ],
        out_specs=pl.BlockSpec((1, cb, h, w), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c, h, w), cols.dtype),
        interpret=interpret,
        compiler_params=plc.CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        name="repro_col2im",
    )(cols)
    return out
