"""Fused RMSNorm Pallas kernel (forward + backward).

Not in Caffe, but the LM-zoo's ubiquitous normalization; a textbook case of
the paper's "merge small activities into one kernel" lesson (mean-square,
rsqrt, scale, weight-multiply in one VMEM pass).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as plc

from repro.core.policy import interpret_default
from repro.core.registry import get_tuning
from repro.tuning.shapes import shape_class
from repro.kernels.gemm import pad_to


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = ((x * jax.lax.rsqrt(var + eps)).astype(o_ref.dtype)) * w_ref[...]


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm_pallas(x: jax.Array, w: jax.Array, eps: float = 1e-6, interpret=None):
    if interpret is None:
        interpret = interpret_default()
    orig = x.shape
    d = orig[-1]
    x2 = x.reshape(-1, d)
    r = x2.shape[0]
    t = get_tuning("rmsnorm", key=shape_class(d=d, r=r), br=256)
    br = min(t["br"], r)
    xp = pad_to(x2, (br, d))
    grid = (xp.shape[0] // br,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=interpret,
        compiler_params=plc.CompilerParams(dimension_semantics=("parallel",)),
        name="repro_rmsnorm",
    )(xp, w.reshape(1, d))
    return out[:r].reshape(orig)


def _rmsnorm_bwd_kernel(x_ref, w_ref, dy_ref, dx_ref, dwp_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    dy = dy_ref[...].astype(jnp.float32)
    d = x.shape[-1]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = x * inv
    dxhat = dy * w
    # dx = inv * (dxhat - xhat * mean(dxhat * xhat))
    dx = inv * (dxhat - xhat * jnp.mean(dxhat * xhat, axis=-1, keepdims=True))
    dx_ref[...] = dx.astype(dx_ref.dtype)
    dwp_ref[...] = jnp.sum(dy * xhat, axis=0, keepdims=True).astype(
        dwp_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm_bwd_pallas(
    x: jax.Array, w: jax.Array, dy: jax.Array, eps: float = 1e-6, interpret=None
):
    """Returns (dx, dw)."""
    if interpret is None:
        interpret = interpret_default()
    orig = x.shape
    d = orig[-1]
    x2, dy2 = x.reshape(-1, d), dy.reshape(-1, d)
    r = x2.shape[0]
    t = get_tuning("rmsnorm", key=shape_class(d=d, r=r), br=256)
    br = min(t["br"], r)
    xp, dyp = pad_to(x2, (br, d)), pad_to(dy2, (br, d))
    grid = (xp.shape[0] // br,)
    dx, dw_part = pl.pallas_call(
        functools.partial(_rmsnorm_bwd_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xp.shape, x.dtype),
            jax.ShapeDtypeStruct((grid[0], d), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=plc.CompilerParams(dimension_semantics=("parallel",)),
        name="repro_rmsnorm_bwd",
    )(xp, w.reshape(1, d), dyp)
    return dx[:r].reshape(orig), dw_part.sum(axis=0).astype(w.dtype)
