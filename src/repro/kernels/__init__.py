# Pallas TPU kernels for the compute hot-spots (each <name>.py holds the
# pl.pallas_call + BlockSpec tiling), with ops.py as the policy-dispatched
# differentiable wrappers and ref.py as the pure-jnp oracles.
from repro.kernels import ops, ref  # noqa: F401  (registers ops on import)
