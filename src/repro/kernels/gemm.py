"""Tiled GEMM Pallas kernel — the workhorse behind Caffe's im2col+GEMM conv
and the InnerProduct layer, and the LM stack's projections.

TPU adaptation of the paper's GEMM usage: instead of delegating to OpenBLAS
(CPU) / cuBLAS (GPU), the portable op carries its own MXU-tiled kernel.
Grid = (M/bm, N/bn, K/bk); the K axis is the innermost, sequential
("arbitrary") dimension so a VMEM f32 scratch accumulator persists across K
steps; output is written once on the last K step.  Block shapes are
MXU-aligned (multiples of 128 in the lane dim) and come from the tuning
registry — PHAST's "tuning parameters without source change".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pallas_compat as plc

from repro.core.policy import interpret_default
from repro.core.registry import get_tuning
from repro.tuning.shapes import shape_class


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def pad_to(x: jax.Array, mults: tuple) -> jax.Array:
    """Zero-pad trailing edges so every dim is a multiple of ``mults``."""
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def gemm_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    out_dtype=None,
    interpret: bool | None = None,
) -> jax.Array:
    """(M,K) @ (K,N) -> (M,N) via the tiled Pallas kernel."""
    if interpret is None:
        interpret = interpret_default()
    out_dtype = out_dtype or a.dtype
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    t = get_tuning("gemm", key=shape_class(m=m, n=n, k=k),
                   bm=128, bn=128, bk=128)
    bm, bn, bk = (min(t["bm"], m), min(t["bn"], n), min(t["bk"], k))
    ap = pad_to(a, (bm, bk))
    bp = pad_to(b, (bk, bn))
    mp, kp = ap.shape
    np_ = bp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=grid[2], out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[plc.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=plc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        name="repro_gemm",
    )(ap, bp)
    return out[:m, :n]
