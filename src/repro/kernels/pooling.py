"""MaxPool Pallas kernels (forward with argmax bookkeeping + backward).

Caffe's pooling stores, during forward, the index of the winning element of
each window; backward scatters gradients through that mapping.  The paper
parallelized only the outer loop; the TPU re-think parallelizes over
(batch, channel-block) grid cells with the whole spatial plane in VMEM and
unrolls the static k×k window loop — same flat-index independence property,
tile-sized work units.

Backward is implemented race-free in gather form for the non-overlapping
case (stride >= kernel, which covers LeNet's 2×2/2 and 3×3/3 pools);
overlapping pools fall back to the reference scatter (recorded, like the
paper's partially-ported blocks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as plc

from repro.core.policy import interpret_default
from repro.kernels.ref import conv_out_size


def _maxpool_kernel(x_ref, o_ref, a_ref, *, k, stride, oh, ow, wp, cb):
    x = x_ref[0]                                     # (cb, HP, WP)
    best = None
    arg = None
    for i in range(k):
        for j in range(k):
            win = jax.lax.slice(
                x,
                (0, i, j),
                (cb, i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1),
                (1, stride, stride),
            )                                        # (cb, oh, ow)
            # absolute flat index of this candidate in the padded plane
            rows = (jnp.arange(oh) * stride + i)[:, None]
            cols = (jnp.arange(ow) * stride + j)[None, :]
            idx = jnp.broadcast_to(rows * wp + cols, win.shape).astype(jnp.int32)
            if best is None:
                best, arg = win, idx
            else:
                take = win > best
                best = jnp.where(take, win, best)
                arg = jnp.where(take, idx, arg)
    o_ref[0] = best
    a_ref[0] = arg


@functools.partial(jax.jit, static_argnames=("k", "stride", "pad", "interpret"))
def maxpool_pallas(x: jax.Array, k: int, stride: int, pad: int = 0, interpret=None):
    if interpret is None:
        interpret = interpret_default()
    n, c, h, w = x.shape
    oh = conv_out_size(h, k, stride, pad)
    ow = conv_out_size(w, k, stride, pad)
    neg = jnp.finfo(x.dtype).min
    xp = jnp.pad(
        x, ((0, 0), (0, 0), (pad, pad), (pad, pad)), constant_values=neg
    )
    hp, wp = xp.shape[2], xp.shape[3]
    cb = c
    grid = (n, c // cb)
    out, arg = pl.pallas_call(
        functools.partial(
            _maxpool_kernel, k=k, stride=stride, oh=oh, ow=ow, wp=wp, cb=cb
        ),
        grid=grid,
        in_specs=[pl.BlockSpec((1, cb, hp, wp), lambda i, j: (i, j, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, cb, oh, ow), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, cb, oh, ow), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, c, oh, ow), x.dtype),
            jax.ShapeDtypeStruct((n, c, oh, ow), jnp.int32),
        ],
        interpret=interpret,
        compiler_params=plc.CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        name="repro_maxpool",
    )(xp)
    return out, arg


def _maxpool_bwd_kernel(dy_ref, a_ref, o_ref, *, k, stride, oh, ow, h, w, pad, wp, cb):
    # Non-overlapping gather form: input pixel (y, x) belongs to at most one
    # window (y // stride, x // stride); it receives dy iff the stored argmax
    # equals its own flat padded-plane index.  Pure broadcast/compare — no
    # scatter, no races.
    dy = dy_ref[0]                                   # (cb, oh, ow)
    arg = a_ref[0]
    hp = h + 2 * pad
    # upsample window values to pixel granularity (repeat = reshape+bcast)
    dy_up = jnp.repeat(jnp.repeat(dy, stride, axis=1), stride, axis=2)
    arg_up = jnp.repeat(jnp.repeat(arg, stride, axis=1), stride, axis=2)
    hh, ww_ = oh * stride, ow * stride
    rows = jax.lax.broadcasted_iota(jnp.int32, (hh, ww_), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (hh, ww_), 1)
    self_idx = rows * wp + cols                       # flat padded index
    grad = jnp.where(arg_up == self_idx[None], dy_up, 0)
    # embed into the padded plane (windows may not cover the bottom/right rim)
    grad = grad[:, : min(hh, hp), : min(ww_, wp)]
    grad = jnp.pad(
        grad,
        (
            (0, 0),
            (0, hp - grad.shape[1]),
            (0, wp - grad.shape[2]),
        ),
    )
    o_ref[0] = jax.lax.slice(grad, (0, pad, pad), (cb, pad + h, pad + w))


@functools.partial(
    jax.jit, static_argnames=("x_shape", "k", "stride", "pad", "interpret")
)
def maxpool_bwd_pallas(
    dy: jax.Array, argmax: jax.Array, x_shape, k: int, stride: int,
    pad: int = 0, interpret=None,
):
    if stride < k:
        raise NotImplementedError("overlapping pool bwd: use reference")
    if interpret is None:
        interpret = interpret_default()
    n, c, h, w = x_shape
    oh, ow = dy.shape[2], dy.shape[3]
    wp = w + 2 * pad
    cb = c
    grid = (n, c // cb)
    out = pl.pallas_call(
        functools.partial(
            _maxpool_bwd_kernel,
            k=k, stride=stride, oh=oh, ow=ow, h=h, w=w, pad=pad, wp=wp, cb=cb,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cb, oh, ow), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, cb, oh, ow), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, cb, h, w), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c, h, w), dy.dtype),
        interpret=interpret,
        compiler_params=plc.CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        name="repro_maxpool_bwd",
    )(dy, argmax)
    return out
