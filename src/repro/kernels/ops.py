"""Portable ops — the public, differentiable, backend-switched operator set.

Every op here is registered once in ``repro.core.registry`` with its two
lowerings and exposed as a plain function.  Model code (the Caffe port, the
LM zoo) calls *these*; whether a Pallas kernel or the jnp oracle runs is
decided by the policy switch — the paper's single-source property.

Differentiation strategy mirrors the paper's porting strategy:
  * REFERENCE backend: the jnp oracle is used directly (autodiff-able).
  * PALLAS backend: a ``jax.custom_vjp`` pairs the forward kernel with its
    hand-written backward kernel(s); ops whose backward is not yet ported
    (ssd_scan) fall back to the oracle's vjp — recorded in ``coverage()``
    exactly like the paper's Table 1 records partially-ported blocks.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.policy import Backend, current_backend
from repro.core.registry import get_tuning, register_op
from repro.tuning.shapes import shape_class
from repro.kernels import ref
from repro.kernels.eltwise import (
    bias_add_rows_pallas,
    relu_bwd_pallas,
    relu_pallas,
)
from repro.kernels.flash_attention import (
    flash_attention_bwd_pallas,
    flash_attention_pallas,
    flash_decode_paged_pallas,
    flash_decode_paged_quant_pallas,
    flash_decode_pallas,
    flash_prefill_chunk_paged_pallas,
    flash_prefill_chunk_paged_quant_pallas,
    flash_prefill_chunk_pallas,
)
from repro.kernels.gemm import gemm_pallas
from repro.kernels.im2col import col2im_pallas, im2col_pallas
from repro.kernels.mamba_scan import ssd_scan_pallas
from repro.kernels.pooling import maxpool_bwd_pallas, maxpool_pallas
from repro.kernels.rmsnorm import rmsnorm_bwd_pallas, rmsnorm_pallas
from repro.kernels.softmax_xent import (
    softmax_pallas,
    softmax_xent_bwd_pallas,
    softmax_xent_pallas,
)


def _pallas() -> bool:
    return current_backend() is Backend.PALLAS


# ---------------------------------------------------------------------------
# matmul  (InnerProduct / projections)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _matmul_p(a, b):
    return gemm_pallas(a, b)


def _matmul_p_fwd(a, b):
    return gemm_pallas(a, b), (a, b)


def _matmul_p_bwd(res, g):
    a, b = res
    da = gemm_pallas(g, b.T, out_dtype=a.dtype)
    db = gemm_pallas(a.T, g, out_dtype=b.dtype)
    return da, db


_matmul_p.defvjp(_matmul_p_fwd, _matmul_p_bwd)


@jax.custom_vjp
def _matmul_r(a, b):
    return ref.gemm(a, b)


def _matmul_r_fwd(a, b):
    return ref.gemm(a, b), (a, b)


def _matmul_r_bwd(res, g):
    # Mixed-precision backward: f32 MXU accumulation but cotangent WIRES in
    # the param dtype.  Without this, the vjp of dot(..., pet=f32).astype
    # produces f32 cotangents that flow through the whole backward graph,
    # doubling collective + HBM traffic (perf iteration L2, §Perf).
    a, b = res
    g = g.astype(a.dtype)
    da = ref.gemm(g, b.T, out_dtype=a.dtype)
    db = ref.gemm(a.T, g, out_dtype=b.dtype)
    return da, db


_matmul_r.defvjp(_matmul_r_fwd, _matmul_r_bwd)


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """(M,K) @ (K,N), f32 accumulation, param-dtype cotangents."""
    return _matmul_p(a, b) if _pallas() else _matmul_r(a, b)


# ---------------------------------------------------------------------------
# bias add over rows (the paper's matrixPlusVectorRows)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _bias_rows_p(m, v):
    return bias_add_rows_pallas(m, v)


def _bias_rows_p_fwd(m, v):
    return bias_add_rows_pallas(m, v), None


def _bias_rows_p_bwd(_, g):
    return g, g.sum(axis=0)


_bias_rows_p.defvjp(_bias_rows_p_fwd, _bias_rows_p_bwd)


def bias_add_rows(m: jax.Array, v: jax.Array) -> jax.Array:
    return _bias_rows_p(m, v) if _pallas() else ref.bias_add_rows(m, v)


# ---------------------------------------------------------------------------
# relu (Caffe's leaky-capable ReLU)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(1,))
def _relu_p(x, slope):
    return relu_pallas(x, slope)


def _relu_p_fwd(x, slope):
    return relu_pallas(x, slope), x


def _relu_p_bwd(slope, x, g):
    return (relu_bwd_pallas(x, g, slope),)


_relu_p.defvjp(_relu_p_fwd, _relu_p_bwd)


def relu(x: jax.Array, negative_slope: float = 0.0) -> jax.Array:
    return (
        _relu_p(x, negative_slope)
        if _pallas()
        else ref.relu(x, negative_slope)
    )


# ---------------------------------------------------------------------------
# im2col / col2im / conv2d (Caffe's Convolution block)
# ---------------------------------------------------------------------------

def im2col(x, kh, kw, stride=1, pad=0):
    if _pallas():
        return im2col_pallas(x, kh, kw, stride, pad)
    return ref.im2col(x, kh, kw, stride, pad)


def col2im(cols, x_shape, kh, kw, stride=1, pad=0):
    if _pallas() and stride == 1:
        return col2im_pallas(cols, tuple(x_shape), kh, kw, stride, pad)
    return ref.col2im(cols, x_shape, kh, kw, stride, pad)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _conv2d_p(x, w, b, stride, pad, has_bias):
    return _conv2d_fwd_impl(x, w, b, stride, pad, has_bias)


def _conv2d_fwd_impl(x, w, b, stride, pad, has_bias):
    n, c, h, wd = x.shape
    f, _, kh, kw = w.shape
    oh = ref.conv_out_size(h, kh, stride, pad)
    ow = ref.conv_out_size(wd, kw, stride, pad)
    cols = im2col_pallas(x, kh, kw, stride, pad)     # (n, k, o)
    wmat = w.reshape(f, c * kh * kw)
    # batched GEMM via flattening batch into the N dim: (f,k) @ (k, n*o)
    cols2 = cols.transpose(1, 0, 2).reshape(c * kh * kw, n * oh * ow)
    out = gemm_pallas(wmat, cols2)                   # (f, n*o)
    out = out.reshape(f, n, oh * ow).transpose(1, 0, 2)
    if has_bias:
        out = out + b[None, :, None]
    return out.reshape(n, f, oh, ow)


def _conv2d_p_fwd(x, w, b, stride, pad, has_bias):
    return _conv2d_fwd_impl(x, w, b, stride, pad, has_bias), (x, w)


def _conv2d_p_bwd(stride, pad, has_bias, res, dy):
    x, w = res
    n, c, h, wd = x.shape
    f, _, kh, kw = w.shape
    oh, ow = dy.shape[2], dy.shape[3]
    dy2 = dy.reshape(n, f, oh * ow)
    cols = im2col_pallas(x, kh, kw, stride, pad)
    # dW = sum_n dy_n @ cols_n^T  -> single GEMM over concatenated batch
    dy_flat = dy2.transpose(1, 0, 2).reshape(f, n * oh * ow)
    cols_flat = cols.transpose(1, 0, 2).reshape(c * kh * kw, n * oh * ow)
    dwmat = gemm_pallas(dy_flat, cols_flat.T, out_dtype=w.dtype)
    dw = dwmat.reshape(f, c, kh, kw)
    # dX = col2im(W^T @ dy)
    wmat = w.reshape(f, c * kh * kw)
    dcols = gemm_pallas(wmat.T, dy_flat, out_dtype=x.dtype)  # (k, n*o)
    dcols = dcols.reshape(c * kh * kw, n, oh * ow).transpose(1, 0, 2)
    dx = col2im(dcols, x.shape, kh, kw, stride, pad)
    db = dy.sum(axis=(0, 2, 3)) if has_bias else jnp.zeros((f,), dy.dtype)
    return dx, dw, db


_conv2d_p.defvjp(_conv2d_p_fwd, _conv2d_p_bwd)


def conv2d(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    stride: int = 1,
    pad: int = 0,
) -> jax.Array:
    if _pallas():
        has_bias = b is not None
        bb = b if has_bias else jnp.zeros((w.shape[0],), x.dtype)
        return _conv2d_p(x, w, bb, stride, pad, has_bias)
    return ref.conv2d(x, w, b, stride=stride, pad=pad)


# ---------------------------------------------------------------------------
# maxpool / avgpool (Caffe's Pooling block)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def _maxpool_arg_p(x, k, stride, pad):
    return maxpool_pallas(x, k, stride, pad)


def _maxpool_arg_p_fwd(x, k, stride, pad):
    out, arg = maxpool_pallas(x, k, stride, pad)
    return (out, arg), (arg, x.shape)


def _maxpool_arg_p_bwd(k, stride, pad, res, g):
    arg, x_shape = res
    dy = g[0]  # argmax cotangent is float0
    if stride >= k:
        return (maxpool_bwd_pallas(dy, arg, x_shape, k, stride, pad),)
    return (ref.maxpool_bwd(dy, arg, x_shape, k, stride, pad),)


_maxpool_arg_p.defvjp(_maxpool_arg_p_fwd, _maxpool_arg_p_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def _maxpool_arg_r(x, k, stride, pad):
    return ref.maxpool(x, k, stride, pad)


def _maxpool_arg_r_fwd(x, k, stride, pad):
    out, arg = ref.maxpool(x, k, stride, pad)
    return (out, arg), (arg, x.shape)


def _maxpool_arg_r_bwd(k, stride, pad, res, g):
    arg, x_shape = res
    return (ref.maxpool_bwd(g[0], arg, x_shape, k, stride, pad),)


_maxpool_arg_r.defvjp(_maxpool_arg_r_fwd, _maxpool_arg_r_bwd)


def maxpool_with_argmax(x: jax.Array, k: int, stride: int, pad: int = 0):
    """One pool evaluation returning ``(out, argmax)``.

    For callers that keep the argmax themselves (the Caffe Pooling layer
    stores the mapping for its explicit backward).  Running ``maxpool`` and
    then the oracle again just for the argmax would double the hot-path cost
    and could disagree on ties across backends; this dispatches once and
    returns both from the same kernel.  Differentiable in ``out``.
    """
    if _pallas():
        return _maxpool_arg_p(x, k, stride, pad)
    return _maxpool_arg_r(x, k, stride, pad)


def maxpool(x: jax.Array, k: int, stride: int, pad: int = 0) -> jax.Array:
    return maxpool_with_argmax(x, k, stride, pad)[0]


def avgpool(x: jax.Array, k: int, stride: int, pad: int = 0) -> jax.Array:
    return ref.avgpool(x, k, stride, pad)


# ---------------------------------------------------------------------------
# softmax / softmax-xent (Caffe's SoftMax / SoftMaxWithLoss)
# ---------------------------------------------------------------------------

def softmax(x: jax.Array, axis: int = -1) -> jax.Array:
    if _pallas() and axis in (-1, x.ndim - 1):
        return softmax_pallas(x)
    return ref.softmax(x, axis)


@jax.custom_vjp
def _xent_p(logits, labels):
    loss, _ = softmax_xent_pallas(logits, labels)
    return loss


def _xent_p_fwd(logits, labels):
    loss, probs = softmax_xent_pallas(logits, labels)
    return loss, (probs, labels)


def _xent_p_bwd(res, g):
    probs, labels = res
    return softmax_xent_bwd_pallas(probs, labels) * g, None


_xent_p.defvjp(_xent_p_fwd, _xent_p_bwd)


@jax.custom_vjp
def _xent_r(logits, labels):
    loss, _ = ref.softmax_xent(logits, labels)
    return loss


def _xent_r_fwd(logits, labels):
    loss, probs = ref.softmax_xent(logits, labels)
    return loss, (probs, labels)


def _xent_r_bwd(res, g):
    probs, labels = res
    return ref.softmax_xent_bwd(probs, labels) * g, None


_xent_r.defvjp(_xent_r_fwd, _xent_r_bwd)


def softmax_xent_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean NLL over rows; labels int32 (B,). Fused fwd+analytic bwd."""
    if _pallas():
        return _xent_p(logits, labels)
    return _xent_r(logits, labels)


def accuracy(logits: jax.Array, labels: jax.Array, top_k: int = 1) -> jax.Array:
    return ref.accuracy(logits, labels, top_k)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm_p(x, w, eps):
    return rmsnorm_pallas(x, w, eps)


def _rmsnorm_p_fwd(x, w, eps):
    return rmsnorm_pallas(x, w, eps), (x, w)


def _rmsnorm_p_bwd(eps, res, g):
    x, w = res
    return rmsnorm_bwd_pallas(x, w, g, eps)


_rmsnorm_p.defvjp(_rmsnorm_p_fwd, _rmsnorm_p_bwd)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    return _rmsnorm_p(x, w, eps) if _pallas() else ref.rmsnorm(x, w, eps)


def layernorm(x, w, b, eps: float = 1e-5):
    return ref.layernorm(x, w, b, eps)


# ---------------------------------------------------------------------------
# attention (flash) — custom_vjp pairs the fwd/bwd kernels
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _attn_p(q, k, v, causal, window, scale):
    out, _ = flash_attention_pallas(
        q, k, v, causal=causal, window=window, scale=scale
    )
    return out


def _attn_p_fwd(q, k, v, causal, window, scale):
    out, lse = flash_attention_pallas(
        q, k, v, causal=causal, window=window, scale=scale
    )
    return out, (q, k, v, out, lse)


def _attn_p_bwd(causal, window, scale, res, do):
    q, k, v, out, lse = res
    return flash_attention_bwd_pallas(
        q, k, v, out, lse, do, causal=causal, window=window, scale=scale
    )


_attn_p.defvjp(_attn_p_fwd, _attn_p_bwd)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """GQA attention (B,Sq,Hq,D)x(B,Sk,Hkv,D) -> (B,Sq,Hq,D)."""
    if _pallas():
        return _attn_p(q, k, v, causal, window, scale)
    return ref.mha_attention(q, k, v, causal=causal, window=window, scale=scale)


def _attention_decode_ref(q, k_cache, v_cache, cache_len, *,
                          window=None, scale=None):
    """jnp oracle: one query row per sequence against a (B,Smax,Hkv,D) cache."""
    b, hq, d = q.shape
    smax = k_cache.shape[1]
    # per-row valid lengths (continuous batching: rows at different depths)
    lens = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (b,)
    )
    kpos = jnp.arange(smax)
    mask = kpos[None, :] < lens[:, None]                    # (B, Smax)
    if window is not None:
        mask &= kpos[None, :] >= lens[:, None] - window
    hkv = k_cache.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    s = jnp.einsum(
        "bhgd,bshd->bhgs", qg, k_cache, preferred_element_type=jnp.float32
    ) * (scale if scale is not None else 1.0 / jnp.sqrt(d).astype(jnp.float32))
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(b, hq, d)


def _attention_decode_paged_ref(q, k_pages, v_pages, cache_len, block_table,
                                *, window=None, scale=None):
    """Paged oracle: gather each row's pages into a logical (B,S,Hkv,D)
    cache, then run the dense math.  Unmapped blocks (-1) gather page 0;
    their garbage keys sit at ``kpos >= cache_len`` and are masked."""
    b = q.shape[0]
    n_pages, page, hkv, d = k_pages.shape
    bt = jnp.clip(block_table, 0, n_pages - 1)
    k = k_pages[bt].reshape(b, -1, hkv, d)       # (B, max_blocks*page, ...)
    v = v_pages[bt].reshape(b, -1, hkv, d)
    return _attention_decode_ref(q, k, v, cache_len, window=window,
                                 scale=scale)


def _attention_decode_paged_quant_ref(q, k_pages, v_pages, k_scale, v_scale,
                                      cache_len, block_table, *,
                                      window=None, scale=None):
    """Quantized paged oracle: dequantize the int8 pool with its
    per-(page, head) scales (f32, R007), then delegate to the paged
    oracle — one dequant definition the Pallas kernel is held to."""
    kf = k_pages.astype(jnp.float32) * k_scale[:, None, :, None]
    vf = v_pages.astype(jnp.float32) * v_scale[:, None, :, None]
    return _attention_decode_paged_ref(q, kf, vf, cache_len, block_table,
                                       window=window, scale=scale)


def attention_decode(
    q: jax.Array,          # (B, Hq, D)
    k_cache: jax.Array,    # contiguous: (B, Smax, Hkv, D);
                           # paged: (n_pages, page_size, Hkv, D) page pool
    v_cache: jax.Array,
    cache_len: jax.Array,  # int32 () or (B,): valid prefix incl. current token
    *,
    block_table: Optional[jax.Array] = None,   # (B, max_blocks) int32, paged
    kv_scales=None,        # (ksc, vsc) (n_pages, Hkv) f32: int8 pool scales
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Single-token decode attention over a KV cache.

    The cache layout is the ``KVCacheLayout`` switch point: with
    ``block_table=None`` the caches are the contiguous per-row slab; with a
    block table they are a shared page pool (``repro.serving.pager``
    documents the contract).  ``kv_scales`` (paged only) marks the pool as
    per-(page, head)-scaled int8 and routes to the quantized lowerings,
    which dequantize in-kernel.  Every layout x dtype cell has a reference
    and a Pallas lowering kept in lock-step.
    """
    if block_table is not None:
        if kv_scales is not None:
            ksc, vsc = kv_scales
            if _pallas():
                return flash_decode_paged_quant_pallas(
                    q, k_cache, v_cache, ksc, vsc, cache_len, block_table,
                    window=window, scale=scale,
                )
            return _attention_decode_paged_quant_ref(
                q, k_cache, v_cache, ksc, vsc, cache_len, block_table,
                window=window, scale=scale,
            )
        if _pallas():
            return flash_decode_paged_pallas(
                q, k_cache, v_cache, cache_len, block_table,
                window=window, scale=scale,
            )
        return _attention_decode_paged_ref(
            q, k_cache, v_cache, cache_len, block_table,
            window=window, scale=scale,
        )
    if kv_scales is not None:
        raise ValueError(
            "kv_scales needs the paged layout (block_table) — the "
            "contiguous slab is never quantized"
        )
    if _pallas():
        return flash_decode_pallas(
            q, k_cache, v_cache, cache_len, window=window, scale=scale
        )
    return _attention_decode_ref(q, k_cache, v_cache, cache_len,
                                 window=window, scale=scale)


def _attention_prefill_chunk_ref(q, k_cache, v_cache, start, width, *,
                                 window=None, scale=None):
    """jnp oracle: C query rows per sequence vs a (B,Smax,Hkv,D) cache.

    Query i of row b sits at absolute position ``start[b] + i`` and sees
    keys at ``kpos <= start[b] + i`` (window-limited when set).  Padding
    rows (``i >= width[b]``) alias the last real position so every softmax
    row keeps at least one finite score — garbage-but-finite outputs the
    caller discards (a NaN would leak into real tokens via MoE dispatch).
    """
    b, c, hq, d = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    starts = jnp.broadcast_to(
        jnp.asarray(start, jnp.int32).reshape(-1), (b,)
    )
    widths = jnp.broadcast_to(
        jnp.asarray(width, jnp.int32).reshape(-1), (b,)
    )
    i = jnp.arange(c, dtype=jnp.int32)[None, :]
    qpos = starts[:, None] + jnp.minimum(i, widths[:, None] - 1)  # (B, C)
    kpos = jnp.arange(smax)
    mask = kpos[None, None, :] <= qpos[:, :, None]                # (B, C, S)
    if window is not None:
        mask &= kpos[None, None, :] > qpos[:, :, None] - window
    qg = q.reshape(b, c, hkv, g, d)
    s = jnp.einsum(
        "bchgd,bshd->bchgs", qg, k_cache, preferred_element_type=jnp.float32
    ) * (scale if scale is not None else 1.0 / jnp.sqrt(d).astype(jnp.float32))
    s = jnp.where(mask[:, :, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bchgs,bshd->bchgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(b, c, hq, d)


def _attention_prefill_chunk_paged_ref(q, k_pages, v_pages, start, width,
                                       block_table, *, window=None,
                                       scale=None):
    """Paged oracle: gather each row's pages into a logical cache, then run
    the dense chunk math.  Unmapped blocks (-1) gather page 0; their
    garbage keys sit past ``start + width - 1`` and are masked."""
    b = q.shape[0]
    n_pages, page, hkv, d = k_pages.shape
    bt = jnp.clip(block_table, 0, n_pages - 1)
    k = k_pages[bt].reshape(b, -1, hkv, d)
    v = v_pages[bt].reshape(b, -1, hkv, d)
    return _attention_prefill_chunk_ref(q, k, v, start, width,
                                        window=window, scale=scale)


def _attention_prefill_chunk_paged_quant_ref(q, k_pages, v_pages, k_scale,
                                             v_scale, start, width,
                                             block_table, *, window=None,
                                             scale=None):
    """Quantized paged chunk oracle: dequantize (f32, R007), then run the
    paged oracle — same single dequant definition as the decode path."""
    kf = k_pages.astype(jnp.float32) * k_scale[:, None, :, None]
    vf = v_pages.astype(jnp.float32) * v_scale[:, None, :, None]
    return _attention_prefill_chunk_paged_ref(q, kf, vf, start, width,
                                              block_table, window=window,
                                              scale=scale)


def attention_prefill_chunk(
    q: jax.Array,          # (B, C, Hq, D): C prompt tokens per sequence
    k_cache: jax.Array,    # contiguous: (B, Smax, Hkv, D);
                           # paged: (n_pages, page_size, Hkv, D) page pool
    v_cache: jax.Array,
    start: jax.Array,      # int32 () or (B,): absolute pos of chunk token 0
    width: jax.Array,      # int32 () or (B,): real tokens in the chunk
    *,
    block_table: Optional[jax.Array] = None,   # (B, max_blocks) int32, paged
    kv_scales=None,        # (ksc, vsc) (n_pages, Hkv) f32: int8 pool scales
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Chunked-prefill attention over a KV cache.

    The multi-token sibling of ``attention_decode`` and the same
    ``KVCacheLayout`` switch point: ``block_table=None`` selects the
    contiguous per-row slab, a block table selects the shared page pool
    (contract in ``repro.serving.pager``); ``kv_scales`` routes the paged
    pool through the quantized lowerings (in-kernel dequant).  The chunk's
    own K/V must be in the cache already; causality inside the chunk is
    pure masking.  Every cell has a reference and a Pallas lowering kept
    in lock-step.
    """
    if block_table is not None:
        if kv_scales is not None:
            ksc, vsc = kv_scales
            if _pallas():
                return flash_prefill_chunk_paged_quant_pallas(
                    q, k_cache, v_cache, ksc, vsc, start, width,
                    block_table, window=window, scale=scale,
                )
            return _attention_prefill_chunk_paged_quant_ref(
                q, k_cache, v_cache, ksc, vsc, start, width, block_table,
                window=window, scale=scale,
            )
        if _pallas():
            return flash_prefill_chunk_paged_pallas(
                q, k_cache, v_cache, start, width, block_table,
                window=window, scale=scale,
            )
        return _attention_prefill_chunk_paged_ref(
            q, k_cache, v_cache, start, width, block_table,
            window=window, scale=scale,
        )
    if kv_scales is not None:
        raise ValueError(
            "kv_scales needs the paged layout (block_table) — the "
            "contiguous slab is never quantized"
        )
    if _pallas():
        return flash_prefill_chunk_pallas(
            q, k_cache, v_cache, start, width, window=window, scale=scale
        )
    return _attention_prefill_chunk_ref(q, k_cache, v_cache, start, width,
                                        window=window, scale=scale)


# ---------------------------------------------------------------------------
# Mamba-2 SSD scan — pallas fwd; bwd falls back to oracle vjp (recorded)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(5,))
def _ssd_p(x, dt, A, B_, C, chunk):
    y, _ = ssd_scan_pallas(x, dt, A, B_, C, chunk=chunk)
    return y


def _ssd_p_fwd(x, dt, A, B_, C, chunk):
    y, _ = ssd_scan_pallas(x, dt, A, B_, C, chunk=chunk)
    return y, (x, dt, A, B_, C)


def _ssd_p_bwd(chunk, res, dy):
    x, dt, A, B_, C = res
    # backward not yet ported to Pallas: oracle vjp (paper-style partial port)
    _, vjp = jax.vjp(
        lambda *args: ref.ssd_scan(*args, chunk=chunk)[0], x, dt, A, B_, C
    )
    return vjp(dy)


_ssd_p.defvjp(_ssd_p_fwd, _ssd_p_bwd)


def ssd_scan(
    x, dt, A, B_, C, *, chunk: int = 64, initial_state=None, return_state=False
):
    """Mamba-2 SSD. B_/C: (B,S,G,N). Pallas path requires G==1."""
    if return_state or initial_state is not None:
        # stateful path (serving): no grad needed; direct dispatch
        if _pallas() and B_.shape[2] == 1:
            return ssd_scan_pallas(
                x, dt, A, B_, C, chunk=chunk, initial_state=initial_state
            )
        return ref.ssd_scan(
            x, dt, A, B_, C, chunk=chunk, initial_state=initial_state
        )
    if _pallas() and B_.shape[2] == 1:
        return _ssd_p(x, dt, A, B_, C, chunk)
    return ref.ssd_scan(x, dt, A, B_, C, chunk=chunk)[0]


def ssd_prefill_chunk(
    x: jax.Array,      # (B, C, H, P): C tokens per sequence
    dt: jax.Array,     # (B, C, H) f32; dt == 0 marks padding (state no-op)
    A: jax.Array,      # (H,)
    B_: jax.Array,     # (B, C, G, N)
    C: jax.Array,      # (B, C, G, N)
    state: jax.Array,  # (B, H, P, N) f32: carried recurrent state
    *,
    chunk: int = 64,
) -> tuple:
    """Chunked-SSD serving scan: C tokens against a carried recurrent state.

    The recurrent sibling of ``attention_prefill_chunk`` and the single
    dispatch point for every serving-time SSD recurrence: chunked prefill
    ingests whole token chunks through one scan (B*C-row GEMMs instead of
    C sequential dispatches), and single-token decode is the same call at
    C == 1 — the degenerate case of the chunked formulation, so prefill
    and decode share one accumulation order instead of maintaining two
    recurrences in parity by hand.  Per-row widths are expressed by
    zeroing ``dt`` at padding positions (exp(0) decay, zero input — an
    algebraic state no-op; see ``ref.ssd_scan``).  Returns
    ``(y (B,C,H,P), new_state (B,H,P,N) f32)``.

    The SSD chunk size is a tuning parameter (``get_tuning(
    "ssd_prefill_chunk")``), clamped to the token count so short chunks —
    and the C=1 decode case — never pad to a full training-size chunk.
    Both lowerings are registered and kept in lock-step
    (``ssd_prefill_chunk`` in ``coverage()``).
    """
    t = get_tuning("ssd_prefill_chunk", key=shape_class(s=x.shape[1]),
                   chunk=chunk)
    c = max(1, min(int(t["chunk"]), x.shape[1]))
    if _pallas() and B_.shape[2] == 1:
        # the kernel re-resolves its chunk from the tuning table; naming
        # this op's entry keeps the serving knob authoritative (idempotent
        # second lookup) instead of letting "ssd_scan" training tuning
        # override it
        return ssd_scan_pallas(x, dt, A, B_, C, chunk=c,
                               initial_state=state,
                               tuning_op="ssd_prefill_chunk")
    return ref.ssd_scan(x, dt, A, B_, C, chunk=c, initial_state=state)


# ---------------------------------------------------------------------------
# Registry entries (introspection / coverage reporting, Table-1 analogue)
# ---------------------------------------------------------------------------

register_op("matmul", reference=ref.gemm, pallas=gemm_pallas,
            doc="MXU-tiled GEMM", tuning="gemm")
register_op("bias_add_rows", reference=ref.bias_add_rows,
            pallas=bias_add_rows_pallas, doc="matrixPlusVectorRows functor",
            tuning="bias_add")
register_op("relu", reference=ref.relu, pallas=relu_pallas,
            doc="leaky-capable ReLU", tuning="relu")
register_op("im2col", reference=ref.im2col, pallas=im2col_pallas,
            doc="merged penta-loop im2col", tuning=())
register_op("col2im", reference=ref.col2im, pallas=col2im_pallas,
            doc="gather-form col2im (stride=1)", tuning=())
register_op("conv2d", reference=ref.conv2d, pallas=_conv2d_fwd_impl,
            doc="im2col+GEMM convolution", tuning="gemm")
from repro.kernels.conv_direct import conv2d_direct_pallas  # noqa: E402
register_op("conv2d_direct", reference=ref.conv2d,
            pallas=conv2d_direct_pallas,
            doc="fused direct conv (implicit GEMM; beyond-paper)",
            tuning="conv_direct")
register_op("maxpool", reference=ref.maxpool, pallas=maxpool_pallas,
            doc="argmax-tracking maxpool", tuning=())
register_op("avgpool", reference=ref.avgpool, pallas=None,
            doc="average pool (reference only)", reference_only=True)
register_op("softmax", reference=ref.softmax, pallas=softmax_pallas,
            doc="row softmax", tuning="softmax")
register_op("softmax_xent", reference=ref.softmax_xent,
            pallas=softmax_xent_pallas, doc="fused softmax+NLL",
            tuning="softmax_xent")
register_op("accuracy", reference=ref.accuracy, pallas=None,
            doc="top-k accuracy (reference only)", reference_only=True)
register_op("rmsnorm", reference=ref.rmsnorm, pallas=rmsnorm_pallas,
            doc="fused RMSNorm", tuning="rmsnorm")
register_op("layernorm", reference=ref.layernorm, pallas=None,
            doc="LayerNorm (reference only)", reference_only=True)
register_op("attention", reference=ref.mha_attention,
            pallas=flash_attention_pallas, doc="GQA flash attention",
            tuning="flash_attention")
register_op("attention_decode", reference=ref.mha_attention,
            pallas=flash_decode_pallas, doc="KV-cache decode attention",
            tuning="flash_decode")
register_op("attention_decode_paged", reference=_attention_decode_paged_ref,
            pallas=flash_decode_paged_pallas,
            doc="block-table paged decode attention", tuning=())
register_op("attention_prefill_chunk", reference=_attention_prefill_chunk_ref,
            pallas=flash_prefill_chunk_pallas,
            doc="chunked-prefill attention (C-token query block vs cache)",
            tuning="flash_prefill")
register_op("attention_prefill_chunk_paged",
            reference=_attention_prefill_chunk_paged_ref,
            pallas=flash_prefill_chunk_paged_pallas,
            doc="block-table paged chunked-prefill attention", tuning=())
register_op("attention_decode_paged_quant",
            reference=_attention_decode_paged_quant_ref,
            pallas=flash_decode_paged_quant_pallas,
            doc="int8 paged decode attention (in-kernel per-page dequant)",
            tuning="flash_decode_paged_quant")
register_op("attention_prefill_chunk_paged_quant",
            reference=_attention_prefill_chunk_paged_quant_ref,
            pallas=flash_prefill_chunk_paged_quant_pallas,
            doc="int8 paged chunked-prefill attention (in-kernel dequant)",
            tuning="flash_prefill_paged_quant")
register_op("ssd_scan", reference=ref.ssd_scan, pallas=ssd_scan_pallas,
            doc="Mamba-2 SSD chunked scan (fwd ported; bwd oracle vjp)",
            tuning="ssd_scan")
register_op("ssd_prefill_chunk", reference=ref.ssd_scan,
            pallas=ssd_scan_pallas,
            doc="chunked-SSD serving scan (C-token chunk vs carried state; "
                "decode is the C=1 case)",
            tuning="ssd_prefill_chunk")
