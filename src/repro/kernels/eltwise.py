"""Elementwise Pallas kernels: (leaky-)ReLU fwd/bwd and the paper's
``matrixPlusVectorRows`` bias functor.

These are the PHAST-functor-shaped ops: the functor body is trivial; the
point is the tiling.  On TPU the unit of work is a (sublane×lane) VMEM tile,
so the "one thread per element" CPU/GPU mapping becomes "one grid cell per
(bm, bn) tile" — the last dim a multiple of 128 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as plc

from repro.core.policy import interpret_default
from repro.core.registry import get_tuning
from repro.tuning.shapes import shape_class
from repro.kernels.gemm import pad_to


def _tile2d(x: jax.Array):
    """View any-rank array as 2-D (rows, lanes) for tiling."""
    if x.ndim == 0:
        return x.reshape(1, 1), x.shape
    last = x.shape[-1]
    return x.reshape(-1, last), x.shape


def _eltwise_call(kernel, out_dtype, *arrays, interpret=None, op_name="eltwise"):
    if interpret is None:
        interpret = interpret_default()
    x2, orig_shape = _tile2d(arrays[0])
    rest = [a.reshape(x2.shape) for a in arrays[1:]]
    m, n = x2.shape
    t = get_tuning(op_name, key=shape_class(m=m, n=n),
                   bm=256, bn=512)
    bm, bn = min(t["bm"], m), min(t["bn"], n)
    xs = [pad_to(a, (bm, bn)) for a in (x2, *rest)]
    mp, np_ = xs[0].shape
    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j)) for _ in xs],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        interpret=interpret,
        compiler_params=plc.CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        name=f"repro_{op_name}",
    )(*xs)
    return out[:m, :n].reshape(orig_shape)


def _relu_kernel(x_ref, o_ref, *, slope: float):
    x = x_ref[...]
    o_ref[...] = jnp.where(x > 0, x, slope * x)


def _relu_bwd_kernel(x_ref, dy_ref, o_ref, *, slope: float):
    x, dy = x_ref[...], dy_ref[...]
    o_ref[...] = jnp.where(x > 0, dy, slope * dy)


@functools.partial(jax.jit, static_argnames=("negative_slope", "interpret"))
def relu_pallas(x, negative_slope: float = 0.0, interpret=None):
    return _eltwise_call(
        functools.partial(_relu_kernel, slope=negative_slope),
        x.dtype,
        x,
        interpret=interpret,
        op_name="relu",
    )


@functools.partial(jax.jit, static_argnames=("negative_slope", "interpret"))
def relu_bwd_pallas(x, dy, negative_slope: float = 0.0, interpret=None):
    return _eltwise_call(
        functools.partial(_relu_bwd_kernel, slope=negative_slope),
        x.dtype,
        x,
        dy,
        interpret=interpret,
        op_name="relu",
    )


def _bias_rows_kernel(m_ref, v_ref, o_ref):
    # v block is (1, bn): broadcast down rows — the matrixPlusVectorRows functor
    o_ref[...] = m_ref[...] + v_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bias_add_rows_pallas(m: jax.Array, vec: jax.Array, interpret=None):
    """m: (M,N) += vec (N,) broadcast over rows (Listing 1.2's functor)."""
    if interpret is None:
        interpret = interpret_default()
    mm, n = m.shape
    t = get_tuning("bias_add", key=shape_class(m=mm, n=n),
                   bm=256, bn=512)
    bm, bn = min(t["bm"], mm), min(t["bn"], n)
    mp = pad_to(m, (bm, bn))
    vp = pad_to(vec.reshape(1, -1), (1, bn))
    grid = (mp.shape[0] // bm, mp.shape[1] // bn)
    out = pl.pallas_call(
        _bias_rows_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(mp.shape, m.dtype),
        interpret=interpret,
        compiler_params=plc.CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        name="repro_bias_add_rows",
    )(mp, vp)
    return out[:mm, :n]
