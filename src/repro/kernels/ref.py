"""Pure-jnp reference oracles for every kernel in ``repro.kernels``.

These are the "sequential-like, high-level" implementations in the paper's
sense: correct everywhere, used (a) as the REFERENCE backend lowering,
(b) as the ground truth every Pallas kernel is allclose-tested against,
(c) as the vjp fallback for kernels whose backward pass is not yet ported
(mirroring the paper's incremental-porting strategy).

Conventions:
  conv/pool tensors are NCHW (Caffe's layout);
  attention tensors are (B, S, H, D);
  matrices are row-major logical (M, K) @ (K, N) -> (M, N).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# GEMM
# ---------------------------------------------------------------------------

def gemm(a: jax.Array, b: jax.Array, *, out_dtype=None) -> jax.Array:
    """(M,K) @ (K,N) with f32 accumulation (MXU semantics)."""
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def bias_add_rows(m: jax.Array, vec: jax.Array) -> jax.Array:
    """The paper's matrixPlusVectorRows functor: m[i,:] += vec."""
    return m + vec[None, :]


# ---------------------------------------------------------------------------
# im2col / col2im  (the paper's merged penta-loop, flat-index form)
# ---------------------------------------------------------------------------

def conv_out_size(size: int, k: int, stride: int, pad: int) -> int:
    return (size + 2 * pad - k) // stride + 1


def im2col(
    x: jax.Array, kh: int, kw: int, stride: int = 1, pad: int = 0
) -> jax.Array:
    """NCHW image -> (N, C*KH*KW, OH*OW) column matrix.

    Caffe's original is a penta-loop over (c, kh, kw, oh, ow); the paper's
    port merges the loops into one flat index so each element is independent.
    Reference realization: a vectorized gather over the same flat index
    decomposition.
    """
    n, c, h, w = x.shape
    oh = conv_out_size(h, kh, stride, pad)
    ow = conv_out_size(w, kw, stride, pad)
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    # flat index space: (c, i, j, oy, ox); decompose exactly like the port
    i_idx = jnp.arange(kh)
    j_idx = jnp.arange(kw)
    oy = jnp.arange(oh) * stride
    ox = jnp.arange(ow) * stride
    rows = i_idx[:, None, None, None] + oy[None, None, :, None]   # (kh,1,oh,1)
    cols = j_idx[None, :, None, None] + ox[None, None, None, :]   # (1,kw,1,ow)
    rows = jnp.broadcast_to(rows, (kh, kw, oh, ow))
    cols = jnp.broadcast_to(cols, (kh, kw, oh, ow))
    patches = xp[:, :, rows, cols]                     # (n, c, kh, kw, oh, ow)
    return patches.reshape(n, c * kh * kw, oh * ow)


def col2im(
    cols: jax.Array,
    x_shape: Tuple[int, int, int, int],
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
) -> jax.Array:
    """Adjoint of im2col: scatter-add columns back to NCHW image."""
    n, c, h, w = x_shape
    oh = conv_out_size(h, kh, stride, pad)
    ow = conv_out_size(w, kw, stride, pad)
    patches = cols.reshape(n, c, kh, kw, oh, ow)
    hp, wp = h + 2 * pad, w + 2 * pad
    out = jnp.zeros((n, c, hp, wp), cols.dtype)
    i_idx = jnp.arange(kh)
    j_idx = jnp.arange(kw)
    oy = jnp.arange(oh) * stride
    ox = jnp.arange(ow) * stride
    rows = jnp.broadcast_to(
        i_idx[:, None, None, None] + oy[None, None, :, None], (kh, kw, oh, ow)
    )
    cols_ix = jnp.broadcast_to(
        j_idx[None, :, None, None] + ox[None, None, None, :], (kh, kw, oh, ow)
    )
    out = out.at[:, :, rows, cols_ix].add(patches)
    return out[:, :, pad : pad + h, pad : pad + w]


# ---------------------------------------------------------------------------
# Convolution (im2col + GEMM, Caffe style)
# ---------------------------------------------------------------------------

def conv2d(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    stride: int = 1,
    pad: int = 0,
) -> jax.Array:
    """x: (N,C,H,W), w: (F,C,KH,KW), b: (F,) -> (N,F,OH,OW)."""
    n, c, h, wd = x.shape
    f, _, kh, kw = w.shape
    oh = conv_out_size(h, kh, stride, pad)
    ow = conv_out_size(wd, kw, stride, pad)
    cols = im2col(x, kh, kw, stride, pad)              # (n, c*kh*kw, oh*ow)
    wmat = w.reshape(f, c * kh * kw)
    out = jnp.einsum(
        "fk,nko->nfo", wmat, cols, preferred_element_type=jnp.float32
    ).astype(x.dtype)
    if b is not None:
        out = out + b[None, :, None]
    return out.reshape(n, f, oh, ow)


def conv2d_bwd(
    x: jax.Array,
    w: jax.Array,
    dy: jax.Array,
    *,
    stride: int = 1,
    pad: int = 0,
    has_bias: bool = True,
):
    """Gradients of conv2d wrt (x, w, b). dy: (N,F,OH,OW)."""
    n, c, h, wd = x.shape
    f, _, kh, kw = w.shape
    oh, ow = dy.shape[2], dy.shape[3]
    dy_mat = dy.reshape(n, f, oh * ow)
    cols = im2col(x, kh, kw, stride, pad)              # (n, k, o)
    dwmat = jnp.einsum(
        "nfo,nko->fk", dy_mat, cols, preferred_element_type=jnp.float32
    ).astype(w.dtype)
    dw = dwmat.reshape(f, c, kh, kw)
    wmat = w.reshape(f, c * kh * kw)
    dcols = jnp.einsum(
        "fk,nfo->nko", wmat, dy_mat, preferred_element_type=jnp.float32
    ).astype(x.dtype)
    dx = col2im(dcols, x.shape, kh, kw, stride, pad)
    db = dy.sum(axis=(0, 2, 3)) if has_bias else None
    return dx, dw, db


# ---------------------------------------------------------------------------
# Pooling (max / average) with argmax bookkeeping (Caffe stores the mapping)
# ---------------------------------------------------------------------------

def maxpool(
    x: jax.Array, k: int, stride: int, pad: int = 0
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out, argmax_flat). argmax indexes into the padded HxW plane."""
    n, c, h, w = x.shape
    oh = conv_out_size(h, k, stride, pad)
    ow = conv_out_size(w, k, stride, pad)
    neg = jnp.finfo(x.dtype).min
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)), constant_values=neg)
    hp, wp = xp.shape[2], xp.shape[3]
    oy = jnp.arange(oh) * stride
    ox = jnp.arange(ow) * stride
    rows = oy[:, None, None, None] + jnp.arange(k)[None, None, :, None]
    cols = ox[None, :, None, None] + jnp.arange(k)[None, None, None, :]
    rows = jnp.broadcast_to(rows, (oh, ow, k, k))
    cols = jnp.broadcast_to(cols, (oh, ow, k, k))
    windows = xp[:, :, rows, cols]                      # (n,c,oh,ow,k,k)
    wflat = windows.reshape(n, c, oh, ow, k * k)
    out = wflat.max(axis=-1)
    arg_local = wflat.argmax(axis=-1)                   # index within window
    ky, kx = arg_local // k, arg_local % k
    arg_global = (rows[None, None, :, :, 0, 0][..., None, None] * 0)  # placeholder broadcast
    abs_r = oy[None, None, :, None] + ky
    abs_c = ox[None, None, None, :] + kx
    argmax = abs_r * wp + abs_c                          # flat into padded plane
    del arg_global
    return out, argmax


def maxpool_bwd(
    dy: jax.Array,
    argmax: jax.Array,
    x_shape: Tuple[int, int, int, int],
    k: int,
    stride: int,
    pad: int = 0,
) -> jax.Array:
    n, c, h, w = x_shape
    hp, wp = h + 2 * pad, w + 2 * pad
    flat = jnp.zeros((n, c, hp * wp), dy.dtype)
    oh, ow = dy.shape[2], dy.shape[3]
    flat = flat.at[
        jnp.arange(n)[:, None, None],
        jnp.arange(c)[None, :, None],
        argmax.reshape(n, c, oh * ow),
    ].add(dy.reshape(n, c, oh * ow))
    out = flat.reshape(n, c, hp, wp)
    return out[:, :, pad : pad + h, pad : pad + w]


def avgpool(x: jax.Array, k: int, stride: int, pad: int = 0) -> jax.Array:
    n, c, h, w = x.shape
    oh = conv_out_size(h, k, stride, pad)
    ow = conv_out_size(w, k, stride, pad)
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oy = jnp.arange(oh) * stride
    ox = jnp.arange(ow) * stride
    rows = jnp.broadcast_to(
        oy[:, None, None, None] + jnp.arange(k)[None, None, :, None], (oh, ow, k, k)
    )
    cols = jnp.broadcast_to(
        ox[None, :, None, None] + jnp.arange(k)[None, None, None, :], (oh, ow, k, k)
    )
    windows = xp[:, :, rows, cols]
    return windows.mean(axis=(-1, -2))


# ---------------------------------------------------------------------------
# Elementwise (Caffe's ReLU is leaky-capable)
# ---------------------------------------------------------------------------

def relu(x: jax.Array, negative_slope: float = 0.0) -> jax.Array:
    return jnp.where(x > 0, x, negative_slope * x)


def relu_bwd(x: jax.Array, dy: jax.Array, negative_slope: float = 0.0) -> jax.Array:
    return jnp.where(x > 0, dy, negative_slope * dy)


# ---------------------------------------------------------------------------
# Softmax / cross-entropy (fused, Caffe's SoftmaxWithLoss)
# ---------------------------------------------------------------------------

def softmax(x: jax.Array, axis: int = -1) -> jax.Array:
    m = jax.lax.stop_gradient(x.max(axis=axis, keepdims=True))
    e = jnp.exp(x - m)
    return e / e.sum(axis=axis, keepdims=True)


def softmax_xent(
    logits: jax.Array, labels: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """(B, V) logits, (B,) int labels -> (mean loss, probs)."""
    m = logits.max(axis=-1, keepdims=True)
    shifted = logits - m
    lse = jnp.log(jnp.exp(shifted).sum(axis=-1, keepdims=True))
    logp = shifted - lse
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return nll.mean(), jnp.exp(logp)


def softmax_xent_bwd(probs: jax.Array, labels: jax.Array) -> jax.Array:
    b, v = probs.shape
    onehot = jax.nn.one_hot(labels, v, dtype=probs.dtype)
    return (probs - onehot) / b


def accuracy(logits: jax.Array, labels: jax.Array, top_k: int = 1) -> jax.Array:
    if top_k == 1:
        return (logits.argmax(axis=-1) == labels).mean()
    _, idx = jax.lax.top_k(logits, top_k)
    return (idx == labels[:, None]).any(axis=-1).mean()


# ---------------------------------------------------------------------------
# Attention (GQA, optionally causal / sliding-window) — oracle for flash
# ---------------------------------------------------------------------------

def mha_attention(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Sk, Hkv, D)
    v: jax.Array,  # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    """Reference GQA attention. q_offset: absolute position of q[0] (decode)."""
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    qg = q.reshape(b, sq, hkv, g, d)
    logits = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32
    ) * scale
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(b, sq, hq, d)


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space duality) — chunked oracle
# ---------------------------------------------------------------------------

def ssd_scan(
    x: jax.Array,    # (B, S, H, P)   heads x headdim
    dt: jax.Array,   # (B, S, H)      softplus-activated step
    A: jax.Array,    # (H,)           negative decay rate
    B_: jax.Array,   # (B, S, G, N)   input proj (G state groups)
    C: jax.Array,    # (B, S, G, N)   output proj
    *,
    chunk: int = 64,
    initial_state: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Mamba-2 SSD: y_t = C_t^T h_t, h_t = exp(A dt_t) h_{t-1} + dt_t B_t x_t.

    Chunked formulation (arXiv:2405.21060): intra-chunk quadratic term +
    inter-chunk recurrent state passing. Returns (y, final_state); the
    state is kept in f32 regardless of ``x.dtype`` (it is the serving-time
    recurrent carry — downcasting it would compound across steps), matching
    the Pallas kernel's f32 state output.

    A position with ``dt == 0`` is an algebraic no-op on the state (decay
    ``exp(0) = 1``, input term 0) — the masking contract chunked prefill
    uses for per-row widths, and what makes the internal zero-padding to a
    chunk multiple exact rather than approximate.
    """
    b, s, h, p = x.shape
    g, n = B_.shape[2], B_.shape[3]
    assert h % g == 0
    if s % chunk != 0:
        pad_len = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad_len), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad_len), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad_len), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad_len), (0, 0), (0, 0)))
    s_pad = x.shape[1]
    nc = s_pad // chunk
    rep = h // g
    # reshape to chunks
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = jnp.repeat(B_.reshape(b, nc, chunk, g, n), rep, axis=3)  # (b,nc,L,h,n)
    Cc = jnp.repeat(C.reshape(b, nc, chunk, g, n), rep, axis=3)
    dA = dtc * A[None, None, None, :]                  # (b,nc,L,h)  log-decay
    cum = jnp.cumsum(dA, axis=2)                       # within-chunk cumsum
    # intra-chunk: y_intra[t] = sum_{u<=t} C_t . B_u x_u * exp(cum_t - cum_u) dt_u
    decay = jnp.exp(
        cum[:, :, :, None, :] - cum[:, :, None, :, :]
    )                                                   # (b,nc,t,u,h)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tri[None, None, :, :, None], decay, 0.0)
    cb = jnp.einsum("bclhn,bcuhn->bcluh", Cc, Bc)       # C_t . B_u
    att = cb * decay * dtc[:, :, None, :, :]            # (b,nc,t,u,h)
    y_intra = jnp.einsum("bcluh,bcuhp->bclhp", att, xc)
    # chunk summaries: state contribution of chunk  = sum_u exp(cumL - cum_u) dt_u B_u x_u
    chunk_decay = jnp.exp(cum[:, :, -1:, :] - cum)       # (b,nc,L,h)
    states = jnp.einsum(
        "bclh,bclhn,bclhp->bchpn", chunk_decay * dtc, Bc, xc
    )                                                    # (b,nc,h,p,n)
    # inter-chunk recurrence over nc
    total_decay = jnp.exp(cum[:, :, -1, :])              # (b,nc,h)
    h0 = (
        initial_state
        if initial_state is not None
        else jnp.zeros((b, h, p, n), x.dtype)
    )

    def step(carry, inp):
        st, td = inp                                     # (b,h,p,n), (b,h)
        new = carry * td[:, :, None, None] + st
        return new, carry                                # emit state *before* chunk

    fin, prev_states = jax.lax.scan(
        step,
        h0.astype(jnp.float32),
        (
            jnp.moveaxis(states, 1, 0).astype(jnp.float32),
            jnp.moveaxis(total_decay, 1, 0).astype(jnp.float32),
        ),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # (b,nc,h,p,n)
    # inter-chunk output: y_inter[t] = C_t . (exp(cum_t) h_prev)
    y_inter = jnp.einsum(
        "bclhn,bclh,bchpn->bclhp",
        Cc.astype(jnp.float32),
        jnp.exp(cum),
        prev_states,
    ).astype(x.dtype)
    y = (y_intra + y_inter).reshape(b, s_pad, h, p)[:, :s]
    return y, fin


def ssd_decode_step(
    x: jax.Array,   # (B, H, P)
    dt: jax.Array,  # (B, H)
    A: jax.Array,   # (H,)
    B_: jax.Array,  # (B, G, N)
    C: jax.Array,   # (B, G, N)
    state: jax.Array,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrent update — the closed form of the S=1 scan.

    Serving no longer dispatches this directly: decode is the C=1 case of
    the chunked SSD scan (``ops.ssd_prefill_chunk``), so prefill and
    decode share one accumulation order.  It stays as the sequential test
    oracle the chunked scan is checked against.
    """
    b, h, p = x.shape
    g, n = B_.shape[1], B_.shape[2]
    rep = h // g
    Bh = jnp.repeat(B_, rep, axis=1)     # (B,H,N)
    Ch = jnp.repeat(C, rep, axis=1)
    decay = jnp.exp(dt * A[None, :])     # (B,H)
    new_state = (
        state * decay[:, :, None, None]
        + (dt[:, :, None] * x)[..., None] * Bh[:, :, None, :]
    )
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * w


def layernorm(
    x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5
) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y.astype(x.dtype)) * w + b
