"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers program under-reports flops/bytes/collectives by ~n_layers.
This module re-derives the three roofline inputs from the optimized HLO
text, scaling every while body by its ``known_trip_count`` backend config
(emitted by XLA for lax.scan loops) and descending into fusions/calls.

Counting rules (per partitioned module = per device):
  flops:
    dot           2 * nelems(out) * K   (K = prod of lhs contracting dims)
    elementwise   nelems(out)
    reduce        nelems(in)
    while         trip * (body + cond)
    fusion/call   cost of called computation
  bytes (HBM traffic approximation):
    top-level ops: sum(operand bytes) + out bytes; fusion parameters whose
    only internal consumer is a dynamic-slice count the slice, not the full
    buffer (the scan-reads-one-layer pattern).
  collective bytes:
    max(in, out) per collective op (ring traffic ~ (n-1)/n * payload),
    counted at -start for async pairs, scaled by enclosing trip counts.

Validated against cost_analysis() on loop-free programs and against the
analytic 6*N*D for the scanned LMs (see tests/test_roofline.py).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e3m4": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "abs", "negate", "exponential", "exponential-minus-one", "log",
    "log-plus-one", "sqrt", "rsqrt", "cbrt", "tanh", "sine", "cosine",
    "logistic", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "sign", "atan2", "remainder", "and", "or", "xor", "not", "clamp",
    "select", "erf",
}

_ZERO_FLOP = {
    "parameter", "constant", "copy", "copy-start", "copy-done", "bitcast",
    "reshape", "transpose", "broadcast", "slice", "concatenate", "gather",
    "dynamic-slice", "dynamic-update-slice", "tuple", "get-tuple-element",
    "iota", "pad", "reverse", "convert", "compare", "reduce-precision",
    "after-all", "add-dependency", "partition-id", "replica-id", "domain",
    "rng", "rng-bit-generator", "rng-get-and-update-state", "infeed",
    "outfeed", "optimization-barrier", "send", "send-done", "recv",
    "recv-done", "is-finite",
}

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
}

_SHAPE_TOKEN = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]"
)
_NAME_EQ = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")


def _parse_op_line(line: str) -> Optional[Tuple[str, str, str]]:
    """'%name = TYPE opcode(...)' -> (name, type_str, opcode).

    TYPE may be a tuple containing comments like /*index=5*/ and layout
    annots like {2,1,0:T(8,128)(2,1)} — regexes break on these, so scan
    with balanced parens.
    """
    m = _NAME_EQ.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    n = len(line)
    if i < n and line[i] == "(":  # tuple type
        depth = 0
        j = i
        while j < n:
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        type_str = line[i : j + 1]
        i = j + 1
    else:  # array type token (may include layout braces)
        j = i
        while j < n and line[j] not in " ":
            j += 1
        type_str = line[i:j]
        i = j
    while i < n and line[i] == " ":
        i += 1
    j = i
    while j < n and (line[j].isalnum() or line[j] in "-_"):
        j += 1
    if j >= n or line[j] != "(":
        return None
    opcode = line[i:j]
    return name, type_str, opcode
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{$")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_TRIP = re.compile(r"known_trip_count[\"':{\s]+n[\"':\s]+(\d+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS = re.compile(r"%([\w.\-]+)")


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    """Total (elements, bytes) across all array shapes in a type string."""
    elems = 0
    nbytes = 0
    for m in _SHAPE_TOKEN.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    line: str

    @property
    def out_elems(self) -> int:
        return _shape_elems_bytes(self.type_str)[0]

    @property
    def out_bytes(self) -> int:
        return _shape_elems_bytes(self.type_str)[1]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_count: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __add__(self, o: "Cost") -> "Cost":
        kinds = dict(self.coll_by_kind)
        for k, v in o.coll_by_kind.items():
            kinds[k] = kinds.get(k, 0.0) + v
        return Cost(
            self.flops + o.flops, self.bytes + o.bytes,
            self.coll_bytes + o.coll_bytes, self.coll_count + o.coll_count,
            kinds,
        )

    def __mul__(self, k: float) -> "Cost":
        return Cost(
            self.flops * k, self.bytes * k, self.coll_bytes * k,
            self.coll_count * k,
            {kk: v * k for kk, v in self.coll_by_kind.items()},
        )


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[Op]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, Cost] = {}

    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if cur is None:
                m = _COMP_HEADER.match(line)
                if m:
                    cur = m.group(1)
                    self.computations[cur] = []
                    if line.startswith("ENTRY"):
                        self.entry = cur
                continue
            if line.startswith("}"):
                cur = None
                continue
            parsed = _parse_op_line(line)
            if parsed:
                self.computations[cur].append(Op(*parsed, line))

    # -- per-op helpers ----------------------------------------------------
    def _symbols(self, comp: str) -> Dict[str, str]:
        return {op.name: op.type_str for op in self.computations[comp]}

    def _operand_names(self, op: Op) -> List[str]:
        # operands are inside the first (...) after the opcode
        start = op.line.index(op.opcode + "(") + len(op.opcode) + 1
        depth, i = 1, start
        while i < len(op.line) and depth:
            if op.line[i] == "(":
                depth += 1
            elif op.line[i] == ")":
                depth -= 1
            i += 1
        return _OPERANDS.findall(op.line[start : i - 1])

    def _dot_flops(self, op: Op, syms: Dict[str, str]) -> float:
        ops_ = self._operand_names(op)
        if not ops_:
            return 0.0
        lhs_type = syms.get(ops_[0], "")
        m = _SHAPE_TOKEN.search(lhs_type)
        if not m:
            return 0.0
        dims = [int(d) for d in m.group(2).split(",") if d]
        cm = _CONTRACT.search(op.line)
        k = 1
        if cm and cm.group(1):
            for ix in cm.group(1).split(","):
                k *= dims[int(ix)] if int(ix) < len(dims) else 1
        return 2.0 * op.out_elems * k

    def _fusion_bytes(self, op: Op, syms: Dict[str, str]) -> float:
        """Operand+output bytes; a fused param consumed only by
        dynamic-slice counts the slice output instead (scan-layer reads)."""
        total = float(op.out_bytes)
        called = _CALLS.search(op.line)
        inner_ds: Dict[int, int] = {}
        if called and called.group(1) in self.computations:
            comp = self.computations[called.group(1)]
            # param index -> param op name
            params = {}
            for o in comp:
                if o.opcode == "parameter":
                    pm = re.search(r"parameter\((\d+)\)", o.line)
                    if pm:
                        params[o.name] = int(pm.group(1))
            consumers: Dict[str, List[Op]] = {}
            for o in comp:
                for nm in self._operand_names(o):
                    consumers.setdefault(nm, []).append(o)
            for pname, pix in params.items():
                cons = consumers.get(pname, [])
                if cons and all(c.opcode == "dynamic-slice" for c in cons):
                    inner_ds[pix] = sum(c.out_bytes for c in cons)
        operand_names = self._operand_names(op)
        for i, nm in enumerate(operand_names):
            if i in inner_ds:
                total += inner_ds[i]
            else:
                total += _shape_elems_bytes(syms.get(nm, ""))[1]
        return total

    # -- computation cost ----------------------------------------------------
    def cost_of(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = Cost()  # break cycles defensively
        total = Cost()
        syms = self._symbols(comp)
        for op in self.computations.get(comp, []):
            oc = op.opcode
            base = oc.replace("-start", "").replace("-done", "")
            if oc.endswith("-done"):
                continue
            if oc == "while":
                body = _BODY.search(op.line)
                cond = _COND.search(op.line)
                trip_m = _TRIP.search(op.line)
                trip = int(trip_m.group(1)) if trip_m else 1
                inner = Cost()
                if body and body.group(1) in self.computations:
                    inner = inner + self.cost_of(body.group(1))
                if cond and cond.group(1) in self.computations:
                    inner = inner + self.cost_of(cond.group(1))
                total = total + inner * trip
                continue
            if oc in ("fusion",):
                called = _CALLS.search(op.line)
                if called and called.group(1) in self.computations:
                    inner = self.cost_of(called.group(1))
                    total = total + Cost(flops=inner.flops,
                                         coll_bytes=inner.coll_bytes,
                                         coll_count=inner.coll_count,
                                         coll_by_kind=inner.coll_by_kind)
                total.bytes += self._fusion_bytes(op, syms)
                continue
            if oc in ("call", "custom-call", "conditional"):
                called = _CALLS.search(op.line)
                if called and called.group(1) in self.computations:
                    total = total + self.cost_of(called.group(1))
                total.bytes += float(op.out_bytes)
                continue
            if base in _COLLECTIVES:
                in_bytes = sum(
                    _shape_elems_bytes(syms.get(nm, ""))[1]
                    for nm in self._operand_names(op)
                )
                nb = float(max(op.out_bytes, in_bytes))
                total.coll_bytes += nb
                total.coll_count += 1
                total.coll_by_kind[base] = total.coll_by_kind.get(base, 0.0) + nb
                total.bytes += float(op.out_bytes)
                continue
            # flops
            if oc == "dot":
                total.flops += self._dot_flops(op, syms)
            elif oc in ("reduce", "reduce-window"):
                in_elems = sum(
                    _shape_elems_bytes(syms.get(nm, ""))[0]
                    for nm in self._operand_names(op)[: 1]
                )
                total.flops += float(in_elems)
            elif oc in _ELEMENTWISE:
                total.flops += float(op.out_elems)
            elif oc == "convolution":
                # not used by the LM stack; coarse lower bound
                total.flops += 2.0 * op.out_elems
            # bytes: top-level op reads operands, writes output
            if oc not in ("parameter", "constant", "tuple",
                          "get-tuple-element", "bitcast"):
                total.bytes += float(op.out_bytes)
                total.bytes += sum(
                    _shape_elems_bytes(syms.get(nm, ""))[1]
                    for nm in self._operand_names(op)
                )
        self._memo[comp] = total
        return total

    def entry_cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.cost_of(self.entry)


def cost_from_hlo_text(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).entry_cost()
