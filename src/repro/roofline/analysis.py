"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch, shape, mesh):

    compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
    memory     = HLO_bytes / (chips * HBM_BW)
    collective = collective_bytes / (chips * LINK_BW)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are
parsed from the optimized HLO text (operand bytes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute).

Hardware model (TPU v5e-like, per assignment):
    197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.  bf16[16,512,1024]{2,1,0}  or  f32[128]
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(",
)


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum *output* shape bytes of every collective op, by kind.

    Output bytes are the natural 'traffic' proxy: for all-gather it's the
    gathered result, for reduce-scatter the input is counted via the output
    of the paired ops; ring algorithms move ~(n-1)/n of the full tensor.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_part, kind = m.group(1), m.group(2)
        if "-done" in line.split("=")[1][:40] and "start" not in kind:
            # -done carries the same shape as -start; count once (on start)
            pass
        nbytes = _shape_bytes(shape_part)
        out[kind] += nbytes
        out["count"] += 1
    return out


def dedupe_async_collectives(hlo_text: str) -> str:
    """Drop -done lines so async collectives are counted once (at -start)."""
    keep = []
    for line in hlo_text.splitlines():
        if re.search(r"=\s*(\([^)]*\)|\S+)\s+[\w-]+-done\(", line):
            continue
        keep.append(line)
    return "\n".join(keep)


@dataclasses.dataclass
class Roofline:
    """All hlo_*/collective_* quantities are PER-DEVICE (XLA's
    cost_analysis and the compiled HLO module are per-partition; verified
    empirically: a (1024,1024)@(1024,1024) matmul sharded 8-way reports
    2*1024^3/8 flops)."""

    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collective_count: int
    model_flops: float               # GLOBAL analytic model flops
    bytes_per_device: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable fraction of compute roofline = t_compute / t_bound."""
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_compute / bound if bound else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(
            t_compute=self.t_compute,
            t_memory=self.t_memory,
            t_collective=self.t_collective,
            bottleneck=self.bottleneck,
            useful_flops_ratio=self.useful_flops_ratio,
            roofline_fraction=self.roofline_fraction,
        )
        return d


def model_flops_train(cfg, shape) -> float:
    """6 * N_active * D (tokens)."""
    tokens = shape.seq_len * shape.global_batch
    return 6.0 * cfg.active_param_count() * tokens


def model_flops_decode(cfg, shape) -> float:
    """One token per sequence: 2*N_active per token (fwd only) + attention
    over the cache (2 * 2 * L * Hkv... dominated by params at these sizes)."""
    return 2.0 * cfg.active_param_count() * shape.global_batch


def analyze(
    arch: str, shape_name: str, mesh_name: str, chips: int,
    cost: dict, hlo_text: str, model_flops: float,
    bytes_per_device: Optional[float] = None,
) -> Roofline:
    """Roofline terms via the trip-count-aware HLO cost model.

    XLA's cost_analysis() counts while (lax.scan) bodies once; the
    hlo_cost model scales by known_trip_count — mandatory for the
    scan-over-layers programs here (validated: tests/test_roofline.py).
    """
    from repro.roofline.hlo_cost import cost_from_hlo_text

    c = cost_from_hlo_text(hlo_text)
    analyze.last_by_kind = dict(c.coll_by_kind)  # exposed for records
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=float(c.flops), hlo_bytes=float(c.bytes),
        collective_bytes=float(c.coll_bytes),
        collective_count=int(c.coll_count),
        model_flops=model_flops,
        bytes_per_device=bytes_per_device,
    )
