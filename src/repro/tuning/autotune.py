"""Autotuner — sweep every tunable op x shape class, persist the winners.

PHAST's headline property is "tuning parameters without source change";
this module closes the loop by *choosing* those parameters empirically.
It enumerates every registered op from ``ops.coverage()`` (the Table-1
analogue), derives each tuning key's knob set and hand-set defaults from
the ``get_tuning`` call sites themselves
(``repro.analysis.coverage.collect_tuning_sites`` — the sweep space is
never hand-listed here), times a small deterministic candidate ladder
per serving-realistic shape case, and writes the winners to the
committed table (``tuning_table.json``, schema in ``repro.tuning.table``).

Discipline (ROADMAP standing notes):

* the backend is pinned with the *scoped* ``use_backend("pallas")`` —
  library code never calls ``set_default_backend`` (lint rule R004);
* the sweep runs under ``tuning_table({})`` so the baseline is the
  hand-set call-site defaults, not a previously committed table;
* every candidate is measured on a fresh jit (``jax.clear_caches()``
  first — tuning resolves at trace time) and its cache size is asserted
  to stay 1 across the timed repeats: a sweep value that forces retraces
  is rejected with ``RetraceRejected``, not recorded as fast;
* each shape case asserts ``registry.last_resolved(key)`` equals the
  class the driver computed — the sweep's bucketing provably matches
  the kernel call sites' bucketing;
* after the sweep, the chosen table is validated end-to-end: a tiny
  ``ServingEngine`` (attention + hybrid family) runs a mixed workload
  under ``jit_cache_audit`` with the new table loaded.

    PYTHONPATH=src python -m repro.tuning.autotune [--smoke] \
        [--ops gemm,flash_decode] [--out PATH] [--repeats N] [--no-validate]
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import use_backend
from repro.core.registry import (
    last_resolved,
    list_ops,
    tuning_overrides,
    tuning_table,
)
from repro.tuning import table as tt
from repro.tuning.shapes import shape_class


class RetraceRejected(RuntimeError):
    """A sweep candidate forced the jit cache past size 1."""


# ---------------------------------------------------------------------------
# Shape cases: serving-realistic input builders per tuning key.
#
# Each case is (name, dims, make) where ``dims`` feeds ``shape_class``
# exactly like the kernel call site does (asserted via ``last_resolved``)
# and ``make()`` returns a zero-arg thunk running the Pallas lowering.
# Sizes are kept modest: the sweep must finish in interpret mode on CPU
# (CI) yet still separate block-size candidates.
# ---------------------------------------------------------------------------

#: (case name, shape_class dims, build) — ``build()`` returns
#: ``(pallas_thunk, ref_fn, ref_args)`` over identical inputs: the sweep
#: times the zero-arg Pallas thunk; the perf snapshot lowers
#: ``ref_fn(*ref_args)`` with the arrays as *jit arguments* for the
#: per-op roofline (closed-over arrays become HLO constants and XLA
#: folds the whole op away — and the reference HLO, not the
#: interpret-mode Pallas emulation, is the stable arithmetic footprint).
Case = Tuple[str, Dict[str, int],
             Callable[[], Tuple[Callable[[], Any],
                                Callable[..., Any], tuple]]]


def _f32(rng: np.random.Generator, *shape: int) -> jax.Array:
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


def _gemm_cases(smoke: bool) -> List[Case]:
    from repro.kernels import ref
    from repro.kernels.gemm import gemm_pallas

    shapes = [("decode_proj", 8, 256, 256)]
    if not smoke:
        shapes.append(("prefill_proj", 256, 256, 256))

    def make(m, k, n):
        def build():
            rng = np.random.default_rng(0)
            a, b = _f32(rng, m, k), _f32(rng, k, n)
            return (lambda: gemm_pallas(a, b, interpret=True),
                    ref.gemm, (a, b))
        return build

    return [(nm, dict(m=m, n=n, k=k), make(m, k, n)) for nm, m, k, n in shapes]


def _eltwise_cases(key: str) -> List[Case]:
    from repro.kernels import ref
    from repro.kernels.eltwise import bias_add_rows_pallas, relu_pallas

    m, n = 256, 512

    def build():
        rng = np.random.default_rng(0)
        x = _f32(rng, m, n)
        if key == "bias_add":
            v = _f32(rng, n)
            return (lambda: bias_add_rows_pallas(x, v, interpret=True),
                    ref.bias_add_rows, (x, v))
        return (lambda: relu_pallas(x, interpret=True),
                lambda xx: ref.relu(xx, 0.0), (x,))

    return [("rows", dict(m=m, n=n), build)]


def _conv_direct_cases() -> List[Case]:
    from repro.kernels.conv_direct import conv2d_direct_pallas

    n, c, hw, f, kk = 2, 8, 16, 64, 3

    def build():
        from repro.kernels import ref
        rng = np.random.default_rng(0)
        x = _f32(rng, n, c, hw, hw)
        w = _f32(rng, f, c, kk, kk)
        return (lambda: conv2d_direct_pallas(x, w, stride=1, pad=1,
                                             interpret=True),
                lambda xx, ww: ref.conv2d(xx, ww, None, stride=1, pad=1),
                (x, w))

    return [("conv3x3", dict(c=c, f=f), build)]


def _rmsnorm_cases() -> List[Case]:
    from repro.kernels.rmsnorm import rmsnorm_pallas

    r, d = 512, 256

    def build():
        from repro.kernels import ref
        rng = np.random.default_rng(0)
        x, w = _f32(rng, r, d), _f32(rng, d)
        return (lambda: rmsnorm_pallas(x, w, interpret=True),
                ref.rmsnorm, (x, w))

    return [("prefill_rows", dict(d=d, r=r), build)]


def _softmax_cases() -> List[Case]:
    from repro.kernels.softmax_xent import softmax_pallas

    r, v = 256, 512

    def build():
        from repro.kernels import ref
        rng = np.random.default_rng(0)
        x = _f32(rng, r, v)
        return (lambda: softmax_pallas(x, interpret=True),
                lambda xx: ref.softmax(xx, -1), (x,))

    return [("logit_rows", dict(r=r, v=v), build)]


def _softmax_xent_cases() -> List[Case]:
    from repro.kernels.softmax_xent import softmax_xent_pallas

    b, v = 256, 512

    def build():
        from repro.kernels import ref
        rng = np.random.default_rng(0)
        logits = _f32(rng, b, v)
        labels = jnp.asarray(rng.integers(0, v, b), jnp.int32)
        return (lambda: softmax_xent_pallas(logits, labels, interpret=True),
                lambda ll, yy: ref.softmax_xent(ll, yy)[0],
                (logits, labels))

    return [("train_batch", dict(b=b, v=v), build)]


def _flash_attention_cases() -> List[Case]:
    from repro.kernels.flash_attention import flash_attention_pallas

    b, s, hq, hkv, d = 1, 128, 4, 2, 64

    def build():
        from repro.kernels import ref
        rng = np.random.default_rng(0)
        q = _f32(rng, b, s, hq, d)
        k = _f32(rng, b, s, hkv, d)
        v = _f32(rng, b, s, hkv, d)
        return (lambda: flash_attention_pallas(q, k, v, causal=True,
                                               interpret=True)[0],
                lambda qq, kk_, vv: ref.mha_attention(qq, kk_, vv,
                                                      causal=True),
                (q, k, v))

    return [("train_seq", dict(d=d, s=s), build)]


def _flash_decode_cases() -> List[Case]:
    from repro.kernels.flash_attention import flash_decode_pallas

    b, smax, hq, hkv, d = 4, 512, 4, 2, 64

    def build():
        from repro.kernels.ops import _attention_decode_ref
        rng = np.random.default_rng(0)
        q = _f32(rng, b, hq, d)
        kc = _f32(rng, b, smax, hkv, d)
        vc = _f32(rng, b, smax, hkv, d)
        lens = jnp.asarray(rng.integers(smax // 2, smax, b), jnp.int32)
        return (lambda: flash_decode_pallas(q, kc, vc, lens, interpret=True),
                _attention_decode_ref, (q, kc, vc, lens))

    return [("deep_cache", dict(s=smax), build)]


def _flash_prefill_cases() -> List[Case]:
    from repro.kernels.flash_attention import flash_prefill_chunk_pallas

    b, c, smax, hq, hkv, d = 2, 32, 512, 4, 2, 64

    def build():
        from repro.kernels.ops import _attention_prefill_chunk_ref
        rng = np.random.default_rng(0)
        q = _f32(rng, b, c, hq, d)
        kc = _f32(rng, b, smax, hkv, d)
        vc = _f32(rng, b, smax, hkv, d)
        start = jnp.asarray([64, 128], jnp.int32)
        width = jnp.asarray([c, c - 5], jnp.int32)
        return (lambda: flash_prefill_chunk_pallas(q, kc, vc, start, width,
                                                   interpret=True),
                _attention_prefill_chunk_ref, (q, kc, vc, start, width))

    return [("chunked_prompt", dict(c=c, s=smax), build)]


def _quant_pool(rng: np.random.Generator, n_pages: int, page: int,
                hkv: int, d: int) -> Tuple[jax.Array, jax.Array]:
    pool = jnp.asarray(
        rng.integers(-127, 128, (n_pages, page, hkv, d)), jnp.int8
    )
    sc = jnp.asarray(rng.uniform(0.01, 0.05, (n_pages, hkv)), jnp.float32)
    return pool, sc


def _flash_decode_paged_quant_cases() -> List[Case]:
    from repro.kernels.flash_attention import flash_decode_paged_quant_pallas

    b, n_pages, page, hq, hkv, d, maxb = 4, 32, 16, 4, 2, 64, 8

    def build():
        from repro.kernels.ops import _attention_decode_paged_quant_ref
        rng = np.random.default_rng(0)
        q = _f32(rng, b, hq, d)
        kp, ksc = _quant_pool(rng, n_pages, page, hkv, d)
        vp, vsc = _quant_pool(rng, n_pages, page, hkv, d)
        lens = jnp.asarray(
            rng.integers(page * maxb // 2, page * maxb, b), jnp.int32
        )
        bt = jnp.arange(b * maxb, dtype=jnp.int32).reshape(b, maxb)
        return (lambda: flash_decode_paged_quant_pallas(
                    q, kp, vp, ksc, vsc, lens, bt, interpret=True),
                _attention_decode_paged_quant_ref,
                (q, kp, vp, ksc, vsc, lens, bt))

    return [("quant_pool", dict(p=page), build)]


def _flash_prefill_paged_quant_cases() -> List[Case]:
    from repro.kernels.flash_attention import (
        flash_prefill_chunk_paged_quant_pallas,
    )

    b, c, n_pages, page, hq, hkv, d, maxb = 2, 32, 16, 16, 4, 2, 64, 8

    def build():
        from repro.kernels.ops import (
            _attention_prefill_chunk_paged_quant_ref,
        )
        rng = np.random.default_rng(0)
        q = _f32(rng, b, c, hq, d)
        kp, ksc = _quant_pool(rng, n_pages, page, hkv, d)
        vp, vsc = _quant_pool(rng, n_pages, page, hkv, d)
        start = jnp.asarray([64, 91], jnp.int32)
        width = jnp.asarray([c, c - 5], jnp.int32)
        bt = jnp.arange(b * maxb, dtype=jnp.int32).reshape(b, maxb)
        return (lambda: flash_prefill_chunk_paged_quant_pallas(
                    q, kp, vp, ksc, vsc, start, width, bt, interpret=True),
                _attention_prefill_chunk_paged_quant_ref,
                (q, kp, vp, ksc, vsc, start, width, bt))

    return [("quant_chunked_prompt", dict(c=c, p=page), build)]


def _ssd_cases(key: str) -> List[Case]:
    from repro.kernels.mamba_scan import ssd_scan_pallas

    if key == "ssd_scan":
        b, s, h, p, n = 1, 128, 4, 32, 32
    else:
        b, s, h, p, n = 2, 64, 4, 32, 32

    def build():
        from repro.kernels import ref
        rng = np.random.default_rng(0)
        x = _f32(rng, b, s, h, p)
        dt = jnp.abs(_f32(rng, b, s, h)) * 0.1
        a = -jnp.abs(_f32(rng, h))
        bb = _f32(rng, b, s, 1, n)
        cc = _f32(rng, b, s, 1, n)
        if key == "ssd_scan":
            return (lambda: ssd_scan_pallas(x, dt, a, bb, cc,
                                            interpret=True)[0],
                    lambda *args: ref.ssd_scan(*args, chunk=64)[0],
                    (x, dt, a, bb, cc))
        state = jnp.zeros((b, h, p, n), jnp.float32)
        return (lambda: ssd_scan_pallas(
                    x, dt, a, bb, cc, initial_state=state,
                    tuning_op="ssd_prefill_chunk", interpret=True)[0],
                lambda *args: ref.ssd_scan(
                    *args[:5], chunk=64, initial_state=args[5])[0],
                (x, dt, a, bb, cc, state))

    return [("serving_seq", dict(s=s), build)]


def shape_cases(key: str, smoke: bool) -> List[Case]:
    """The shape cases swept for one tuning key."""
    if key == "gemm":
        return _gemm_cases(smoke)
    if key in ("bias_add", "relu"):
        return _eltwise_cases(key)
    if key == "conv_direct":
        return _conv_direct_cases()
    if key == "rmsnorm":
        return _rmsnorm_cases()
    if key == "softmax":
        return _softmax_cases()
    if key == "softmax_xent":
        return _softmax_xent_cases()
    if key == "flash_attention":
        return _flash_attention_cases()
    if key == "flash_decode":
        return _flash_decode_cases()
    if key == "flash_prefill":
        return _flash_prefill_cases()
    if key == "flash_decode_paged_quant":
        return _flash_decode_paged_quant_cases()
    if key == "flash_prefill_paged_quant":
        return _flash_prefill_paged_quant_cases()
    if key in ("ssd_scan", "ssd_prefill_chunk"):
        return _ssd_cases(key)
    return []


# ---------------------------------------------------------------------------
# Candidate ladder
# ---------------------------------------------------------------------------

_MIN_KNOB = 8


def candidates(
    knobs: Dict[str, Optional[int]], smoke: bool
) -> List[Dict[str, int]]:
    """Deterministic sweep points around the hand-set defaults.

    Diagonal scaling (all knobs by one factor) plus per-knob deviations
    at default others — covers the joint and marginal directions without
    a full cartesian blow-up.  The all-defaults point is the baseline and
    is excluded.
    """
    base = {k: v for k, v in knobs.items() if isinstance(v, int)}
    if not base:
        return []
    factors = (2, 1, 2) if smoke else (4, 2, 2, 4)
    # encode factors as (divisors..., multipliers...): /4 /2 x2 x4
    ndiv = 1 if smoke else 2
    scales = [1.0 / f for f in factors[:ndiv]] + [
        float(f) for f in factors[ndiv:]
    ]

    def scaled(v: int, s: float) -> int:
        return max(_MIN_KNOB, int(round(v * s)))

    out: List[Dict[str, int]] = []
    seen = {tuple(sorted(base.items()))}
    for s in scales:
        cand = {k: scaled(v, s) for k, v in base.items()}
        t = tuple(sorted(cand.items()))
        if t not in seen:
            seen.add(t)
            out.append(cand)
    if len(base) > 1:
        for k in sorted(base):
            for s in scales:
                cand = dict(base)
                cand[k] = scaled(base[k], s)
                t = tuple(sorted(cand.items()))
                if t not in seen:
                    seen.add(t)
                    out.append(cand)
    return out


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------

def measure(thunk: Callable[[], Any], repeats: int) -> float:
    """Best-of-``repeats`` wall ms on a fresh jit; cache must stay size 1.

    ``jax.clear_caches()`` first: tuning resolves at trace time, so a
    stale cache would silently time the *previous* candidate's blocks.
    """
    jax.clear_caches()
    fn = jax.jit(thunk)
    jax.block_until_ready(fn())          # compile (untimed)
    if fn._cache_size() != 1:
        raise RetraceRejected(f"cache size {fn._cache_size()} after compile")
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    if fn._cache_size() != 1:
        raise RetraceRejected(
            f"candidate retraced: cache size {fn._cache_size()} after "
            f"{repeats} steady-state calls"
        )
    return 1e3 * best


def sweep_key(
    key: str,
    knobs: Dict[str, Optional[int]],
    *,
    smoke: bool,
    repeats: int,
    log: Callable[[str], None],
) -> Dict[str, Dict[str, Any]]:
    """Sweep one tuning key over its shape cases; returns table classes."""
    classes: Dict[str, Dict[str, Any]] = {}
    for case_name, dims, build in shape_cases(key, smoke):
        cls = shape_class(**dims)
        thunk = build()[0]
        default_ms = measure(thunk, repeats)
        got = last_resolved(key)
        if got != cls:
            raise AssertionError(
                f"{key}/{case_name}: driver classified {cls!r} but the "
                f"kernel call site resolved {got!r} — sweep bucketing "
                "diverged from the kernel's"
            )
        best_ms, best_params = default_ms, None
        for cand in candidates(knobs, smoke):
            with tuning_overrides(key, cls, **cand):
                try:
                    ms = measure(thunk, repeats)
                except RetraceRejected as exc:
                    log(f"    {key}[{cls}] {cand} rejected: {exc}")
                    continue
            if ms < best_ms:
                best_ms, best_params = ms, cand
        if best_params is None:
            log(f"    {key}[{cls}] ({case_name}): defaults win "
                f"({default_ms:.2f} ms)")
            continue
        classes[cls] = {
            "params": best_params,
            "ms": round(best_ms, 4),
            "default_ms": round(default_ms, 4),
            "speedup": round(default_ms / best_ms, 3),
            "case": case_name,
        }
        log(f"    {key}[{cls}] ({case_name}): {best_params} "
            f"{default_ms:.2f} -> {best_ms:.2f} ms "
            f"(x{default_ms / best_ms:.2f})")
    return classes


# ---------------------------------------------------------------------------
# Orchestration
# ---------------------------------------------------------------------------

def enumerate_cells(
    only: Optional[Sequence[str]] = None,
) -> List[Dict[str, Any]]:
    """One audit cell per registered op, in deterministic (sorted) order."""
    import repro.kernels.ops  # noqa: F401  - populates the registry

    cells: List[Dict[str, Any]] = []
    for name, entry in sorted(list_ops().items()):
        keys = sorted(entry.tuning or ())
        if entry.pallas is None:
            status = "reference_only"
        elif not keys:
            status = "no-knobs"
        elif only is not None and not any(k in only for k in keys):
            status = "skipped"
        else:
            status = "swept"
        cells.append({"op": name, "status": status, "keys": keys})
    return cells


def run_autotune(
    *,
    smoke: bool = False,
    only: Optional[Sequence[str]] = None,
    repeats: int = 3,
    log: Callable[[str], None] = lambda s: None,
) -> Dict[str, Any]:
    """Full sweep; returns a validated table document (not yet saved)."""
    from repro.analysis.coverage import collect_tuning_sites

    sites = collect_tuning_sites()
    cells = enumerate_cells(only)
    key_ops: Dict[str, List[str]] = {}
    for c in cells:
        for k in c["keys"]:
            key_ops.setdefault(k, []).append(c["op"])

    doc = tt.empty_doc()
    doc["environment"] = {
        "jax": jax.__version__,
        "device": jax.devices()[0].platform,
        "interpret": True,
        "smoke": bool(smoke),
        "repeats": int(repeats),
    }
    doc["cells"] = cells

    sweep_keys = sorted(
        k for c in cells if c["status"] == "swept" for k in c["keys"]
    )
    sweep_keys = sorted(set(sweep_keys))
    # pin the backend in scope (R004) and sweep against a clean slate so
    # the baseline is the hand-set call-site defaults
    with use_backend("pallas"), tuning_table(None):
        for key in sweep_keys:
            knobs = sites.get(key, {})
            if not any(isinstance(v, int) for v in knobs.values()):
                log(f"  {key}: no derivable knobs, skipped")
                continue
            log(f"  {key}: knobs {knobs}")
            classes = sweep_key(key, knobs, smoke=smoke, repeats=repeats,
                                log=log)
            if classes:
                for cell in classes.values():
                    cell["ops"] = key_ops.get(key, [])
                doc["entries"][key] = classes

    errors = tt.validate(doc)
    if errors:
        raise RuntimeError("autotune produced an invalid table: "
                           + "; ".join(errors))
    return doc


def validate_serving(doc: Dict[str, Any], log: Callable[[str], None]) -> None:
    """Prove the swept table serves cleanly: tiny engines, audited jit.

    Runs a mixed prefill/decode workload on an attention arch and the
    hybrid (attention+SSD) arch with the new table loaded; any retrace
    caused by a table value raises ``JitCacheRetrace``.
    """
    from repro.analysis.audit import jit_cache_audit
    from repro.configs.registry import get_arch
    from repro.models.model import build_model

    for arch in ("qwen2.5-3b-smoke", "zamba2-2.7b-smoke"):
        cfg = get_arch(arch)
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        rng = np.random.default_rng(7)
        from repro.serving.engine import ServingEngine

        with use_backend("pallas"), tuning_table(doc):
            eng = ServingEngine(model, params, batch=2, max_len=32,
                                steps_per_sync=4, prefill_chunk=4)
            with jit_cache_audit(eng):
                for _ in range(3):
                    toks = rng.integers(
                        0, cfg.vocab_size, rng.integers(3, 9)
                    ).tolist()
                    eng.submit(toks, 4)
                outs = eng.run()
        assert len(outs) == 3
        log(f"  serving validation ok: {arch}")


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tuning.autotune",
        description="sweep tunable ops and persist tuning_table.json",
    )
    ap.add_argument("--smoke", action="store_true",
                    help="fewer shape cases/candidates (CI round-trip test)")
    ap.add_argument("--ops", default=None,
                    help="comma-separated tuning keys to sweep (default all)")
    ap.add_argument("--out", default=None,
                    help="output path (default: the committed table)")
    ap.add_argument("--repeats", type=int, default=None,
                    help="timed repeats per candidate (default 3; smoke 1)")
    ap.add_argument("--no-validate", action="store_true",
                    help="skip the post-sweep serving validation")
    args = ap.parse_args(argv)

    only = args.ops.split(",") if args.ops else None
    repeats = args.repeats if args.repeats is not None else (
        1 if args.smoke else 3
    )

    def log(s: str) -> None:
        print(s, flush=True)

    log(f"autotune: smoke={args.smoke} repeats={repeats} "
        f"keys={only or 'all'}")
    doc = run_autotune(smoke=args.smoke, only=only, repeats=repeats, log=log)
    if not args.no_validate:
        validate_serving(doc, log)
    path = tt.save(doc, args.out)
    n = sum(len(v) for v in doc["entries"].values())
    log(f"wrote {n} entries ({len(doc['entries'])} keys) -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
