"""Persisted tuning table — load / save / validate ``tuning_table.json``.

The table is a committed artifact produced by ``python -m
repro.tuning.autotune`` (see the package docstring for the full format).
This module owns the schema; ``repro.core.registry`` consumes the
flattened ``{(op, shape_class): params}`` view at ``get_tuning`` time and
``repro.analysis.coverage`` lints the file against the live op registry
(C104/C105).

Deliberately dependency-free (stdlib only) so both the registry and the
linter can import it without cycles.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

SCHEMA_VERSION = 1

#: Environment override for the table location.  Set to a path to load a
#: different table, or to the empty string to disable table loading.
ENV_VAR = "REPRO_TUNING_TABLE"


def default_path() -> Path:
    """The committed table location: ``src/repro/tuning/tuning_table.json``."""
    return Path(__file__).resolve().parent / "tuning_table.json"


def resolved_path() -> Optional[Path]:
    """Default path after applying the ``REPRO_TUNING_TABLE`` override.

    Returns ``None`` when loading is disabled (env var set but empty).
    """
    env = os.environ.get(ENV_VAR)
    if env is None:
        return default_path()
    if not env:
        return None
    return Path(env)


def load(path: Optional[Path] = None) -> Dict[str, Any]:
    """Read and validate a table document; missing file -> empty doc."""
    path = Path(path) if path is not None else default_path()
    if not path.exists():
        return empty_doc()
    doc = json.loads(path.read_text(encoding="utf-8"))
    errors = validate(doc)
    if errors:
        raise ValueError(
            f"invalid tuning table {path}: " + "; ".join(errors)
        )
    return doc


def save(doc: Dict[str, Any], path: Optional[Path] = None) -> Path:
    """Validate and write ``doc``; returns the path written."""
    errors = validate(doc)
    if errors:
        raise ValueError("refusing to write invalid table: "
                         + "; ".join(errors))
    path = Path(path) if path is not None else default_path()
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n",
                    encoding="utf-8")
    return path


def empty_doc() -> Dict[str, Any]:
    return {
        "schema": SCHEMA_VERSION,
        "backend": "pallas",
        "environment": {},
        "cells": [],
        "entries": {},
    }


def validate(doc: Any) -> List[str]:
    """Schema check; returns a list of human-readable errors (empty = ok)."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return ["document is not an object"]
    if doc.get("schema") != SCHEMA_VERSION:
        errs.append(f"schema must be {SCHEMA_VERSION}, got "
                    f"{doc.get('schema')!r}")
    if doc.get("backend") != "pallas":
        errs.append("backend must be 'pallas' (the only tunable lowering)")
    entries = doc.get("entries")
    if not isinstance(entries, dict):
        return errs + ["'entries' must be an object"]
    for op, classes in entries.items():
        if not isinstance(op, str) or not op:
            errs.append(f"entry key {op!r} is not an op name")
            continue
        if not isinstance(classes, dict):
            errs.append(f"entries[{op!r}] must be an object")
            continue
        for cls, cell in classes.items():
            where = f"entries[{op!r}][{cls!r}]"
            if not isinstance(cell, dict):
                errs.append(f"{where} must be an object")
                continue
            params = cell.get("params")
            if not isinstance(params, dict) or not params:
                errs.append(f"{where}.params must be a non-empty object")
            else:
                for k, v in params.items():
                    if not isinstance(k, str) or not isinstance(v, int):
                        errs.append(
                            f"{where}.params[{k!r}] must map a knob name "
                            "to an int"
                        )
            for fld in ("ms", "default_ms", "speedup"):
                if fld in cell and not isinstance(
                    cell[fld], (int, float)
                ):
                    errs.append(f"{where}.{fld} must be a number")
    cells = doc.get("cells", [])
    if not isinstance(cells, list):
        errs.append("'cells' must be a list")
    else:
        for i, c in enumerate(cells):
            if not isinstance(c, dict) or "op" not in c or "status" not in c:
                errs.append(f"cells[{i}] must carry at least op and status")
    return errs


def flatten(doc: Dict[str, Any]) -> Dict[Tuple[str, str], Dict[str, int]]:
    """``{(op, shape_class): params}`` — the view ``get_tuning`` resolves."""
    out: Dict[Tuple[str, str], Dict[str, int]] = {}
    for op, classes in doc.get("entries", {}).items():
        for cls, cell in classes.items():
            out[(op, cls)] = dict(cell["params"])
    return out
