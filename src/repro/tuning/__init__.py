"""Autotuning harness — per-device kernel tuning as a committed artifact.

The paper's core lesson is that "code once, target many devices" only
pays off when per-device tuning is cheap and systematic: PHAST exposes
per-kernel tuning knobs exactly so the same source can be re-tuned per
architecture.  This package is that mechanism for the Pallas lowerings,
in the queryop idiom (enumerate every op x backend registration cell,
persist the result as a committed artifact):

    python -m repro.tuning.autotune          sweep, write the table
    src/repro/tuning/tuning_table.json       the committed result
    benchmarks/perf_snapshot.py              BENCH_*.json trajectory

Tuning-key / shape-class convention
-----------------------------------

Every op with a Pallas lowering declares its tuning key(s) at
``register_op(..., tuning=...)`` (enforced by the C102/C103 coverage
lint).  The kernel resolves its knobs at trace time as

    t = get_tuning("<key>", key=shape_class(<dims>), <knob>=<default>, ...)

where ``shape_class`` (:mod:`repro.tuning.shapes`) buckets each
classified dimension to the next power of two and joins them into a
canonical string (``"k256.m64.n256"``).  Which dims a key classifies is
part of its contract — the autotuner's cell drivers mirror the kernel
call sites and the sweep asserts agreement via
``registry.last_resolved``:

    key                 classified dims
    ------------------  ------------------------------------------
    gemm                m, n, k            (matmul / conv im2col GEMM)
    bias_add, relu      m, n               (flattened 2-D tile)
    conv_direct         c, f               (in/out channels)
    rmsnorm             d, r               (feature dim, rows)
    softmax             r, v               (rows, vocab/row width)
    softmax_xent        b, v               (batch rows, vocab)
    flash_attention     d, s               (head dim, sequence)
    flash_decode        s                  (max cache length)
    flash_prefill       c, s               (chunk width, max cache length)
    ssd_scan            s                  (sequence length)
    ssd_prefill_chunk   s                  (serving chunk width)

Resolution precedence (lowest to highest; ``repro.core.registry``):

    call-site defaults
        < table (op, "default")  < table (op, shape_class)
        < set_tuning (op, "default") < set_tuning (op, shape_class)

i.e. the persisted table supersedes the hand-set call-site defaults for
every shape class it covers, while an explicit ``set_tuning`` override
(tests, experiments) always beats the table.  A ``key=`` lookup that
misses every layer falls back cleanly to the call-site defaults.

Table format (``tuning_table.json``, schema 1)
----------------------------------------------

    {
      "schema": 1,
      "backend": "pallas",
      "environment": {"platform": "cpu", "interpret": true, ...},
      "cells":  [ {"op": ..., "status": "swept|no-knobs|reference_only",
                   ...}, ... ],            # full queryop-style enumeration
      "entries": {
        "<tuning key>": {
          "<shape class>": {
            "params": {"<knob>": <int>, ...},   # what get_tuning resolves
            "ms": <best candidate ms>,
            "default_ms": <call-site-default ms>,
            "speedup": <default_ms / ms>,
            "ops": ["<registered ops declaring this key>", ...]
          }
        }
      }
    }

``entries`` is what ``get_tuning`` reads (flattened by
:func:`repro.tuning.table.flatten`); ``cells`` is the audit trail — every
registered op appears exactly once with the reason it was or wasn't
swept.  The table is validated against the live registry by the C104/
C105 coverage lint: an entry whose op lost its Pallas lowering, or whose
params name a knob no kernel call site resolves anymore, fails
``scripts/ci.sh --lint``.

The sweep space is *derived*, not hand-listed: knob names and their
hand-set defaults are AST-scanned from the ``get_tuning`` call sites
under ``src/repro/kernels`` (the same scan the C103 lint uses), and
candidates are the power-of-two ladder around each default.  Sweeps pin
the backend with scoped ``use_backend("pallas")`` (R004: never
``set_default_backend`` in library code) and reject candidates that
retrace — a value is recorded only if repeated calls hit the jit cache.

``REPRO_TUNING_TABLE=<path>`` points the registry at a different table;
``REPRO_TUNING_TABLE=`` (empty) disables table loading entirely.
"""
from repro.tuning.shapes import bucket, parse_shape_class, shape_class
from repro.tuning.table import (
    SCHEMA_VERSION,
    default_path,
    flatten,
    load,
    save,
    validate,
)

__all__ = [
    "bucket",
    "parse_shape_class",
    "shape_class",
    "SCHEMA_VERSION",
    "default_path",
    "flatten",
    "load",
    "save",
    "validate",
]
