"""Shape classes — the bucketing that keys the persisted tuning table.

A *shape class* is a deterministic, coarse name for "shapes that should
share kernel tuning": every classified dimension is rounded up to the
next power of two and the buckets are joined into a canonical string,
e.g. ``shape_class(m=48, n=256, k=200)`` -> ``"k256.m64.n256"``.

Rules (load-bearing for the table contract):

  * dimension names are sorted, so the class string is independent of
    keyword order at the call site;
  * buckets are pure ceil-to-power-of-two (min 1, capped at ``_CAP``),
    so classification needs no tables and two call sites that classify
    the same dims always agree;
  * each kernel call site classifies the *dims its knobs depend on*
    (documented per key in the ``repro.tuning`` package docstring), and
    the autotuner's cell drivers must mirror that choice — the registry
    records the last key each op resolved (``registry.last_resolved``)
    so the autotuner can assert the two stayed in lock-step.

This module is deliberately dependency-free (no jax, no registry) so
kernel modules can import it without cycles.
"""
from __future__ import annotations

_CAP = 1 << 20


def bucket(n: int) -> int:
    """Smallest power of two >= ``n`` (floor 1, cap ``_CAP``)."""
    n = int(n)
    if n <= 1:
        return 1
    p = 1
    while p < n and p < _CAP:
        p <<= 1
    return p


def shape_class(**dims: int) -> str:
    """Canonical class string for the given dimensions.

    ``shape_class(m=48, n=256, k=200)`` -> ``"k256.m64.n256"``.
    """
    if not dims:
        raise ValueError("shape_class needs at least one dimension")
    return ".".join(f"{name}{bucket(v)}" for name, v in sorted(dims.items()))


def parse_shape_class(cls: str) -> dict:
    """Inverse of :func:`shape_class` (bucketed values, not originals)."""
    out = {}
    for part in cls.split("."):
        i = len(part)
        while i > 0 and part[i - 1].isdigit():
            i -= 1
        if i == 0 or i == len(part):
            raise ValueError(f"malformed shape-class component {part!r}")
        out[part[:i]] = int(part[i:])
    return out
