"""Op registry — one canonical name, two lowerings (reference / Pallas).

This is the load-bearing piece of the portability core.  PHAST ships every
algorithm once, as templated C++ whose innermost layers are specialized per
target at compile time.  The JAX analogue: each performance-critical op is
*registered* under a canonical name with

    reference : pure-jnp callable (the oracle; always correct; runs anywhere)
    pallas    : Pallas TPU kernel wrapper (same signature)

``dispatch(name)`` returns the callable selected by the active policy.  Ops
fall back to ``reference`` when no kernel exists (and record that fact, so
tests can assert full coverage where the paper's Table 1 asserts pass rates).

The tuning side-table mirrors PHAST's "tuning parameters without source
change": per-(op, key) kernel parameters (block shapes etc.) that kernels
look up at trace time.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

from repro.core.policy import Backend, current_backend


@dataclasses.dataclass
class OpEntry:
    name: str
    reference: Callable[..., Any]
    pallas: Optional[Callable[..., Any]] = None
    doc: str = ""
    # Coverage-lint declarations (repro.analysis.coverage).  ``tuning``
    # names the tuning-table keys the Pallas lowering resolves via
    # ``get_tuning`` — ``()`` declares "no tunable parameters", ``None``
    # means undeclared (a C102 finding for ops with a lowering).
    # ``reference_only=True`` records that the op intentionally has no
    # Pallas lowering (silences C101).
    tuning: Optional[Tuple[str, ...]] = None
    reference_only: bool = False

    def resolve(self, backend: Backend) -> Callable[..., Any]:
        if backend is Backend.PALLAS and self.pallas is not None:
            return self.pallas
        return self.reference


_OPS: Dict[str, OpEntry] = {}
_TUNING: Dict[tuple, Dict[str, Any]] = {}


def register_op(
    name: str,
    *,
    reference: Callable[..., Any],
    pallas: Optional[Callable[..., Any]] = None,
    doc: str = "",
    tuning: Optional[str | Sequence[str]] = None,
    reference_only: bool = False,
) -> OpEntry:
    if name in _OPS:
        raise ValueError(f"op {name!r} already registered")
    if reference_only and pallas is not None:
        raise ValueError(
            f"op {name!r}: reference_only=True with a pallas lowering"
        )
    if isinstance(tuning, str):
        tuning = (tuning,)
    elif tuning is not None:
        tuning = tuple(tuning)
    entry = OpEntry(
        name=name,
        reference=reference,
        pallas=pallas,
        doc=doc,
        tuning=tuning,
        reference_only=reference_only,
    )
    _OPS[name] = entry
    return entry


def attach_pallas(name: str, fn: Callable[..., Any]) -> None:
    """Attach/replace the Pallas lowering of an already-registered op."""
    _OPS[name].pallas = fn


def get_op(name: str) -> OpEntry:
    try:
        return _OPS[name]
    except KeyError as e:
        raise KeyError(
            f"op {name!r} not registered; known: {sorted(_OPS)}"
        ) from e


def dispatch(name: str) -> Callable[..., Any]:
    """Resolve op ``name`` under the current backend policy."""
    return get_op(name).resolve(current_backend())


def list_ops() -> Dict[str, OpEntry]:
    return dict(_OPS)


def coverage() -> Dict[str, bool]:
    """name -> has a Pallas lowering (the 'ported to PHAST' bit per block)."""
    return {name: e.pallas is not None for name, e in _OPS.items()}


# ---------------------------------------------------------------------------
# Tuning registry: per-(op, key) kernel parameters, settable from config.
# ---------------------------------------------------------------------------

def set_tuning(op: str, key: str = "default", **params: Any) -> None:
    _TUNING[(op, key)] = dict(params)


def get_tuning(op: str, key: str = "default", **defaults: Any) -> Dict[str, Any]:
    out = dict(defaults)
    out.update(_TUNING.get((op, "default"), {}))
    if key != "default":
        out.update(_TUNING.get((op, key), {}))
    return out


def clear_tuning() -> None:
    _TUNING.clear()
