"""Op registry — one canonical name, two lowerings (reference / Pallas).

This is the load-bearing piece of the portability core.  PHAST ships every
algorithm once, as templated C++ whose innermost layers are specialized per
target at compile time.  The JAX analogue: each performance-critical op is
*registered* under a canonical name with

    reference : pure-jnp callable (the oracle; always correct; runs anywhere)
    pallas    : Pallas TPU kernel wrapper (same signature)

``dispatch(name)`` returns the callable selected by the active policy.  Ops
fall back to ``reference`` when no kernel exists (and record that fact, so
tests can assert full coverage where the paper's Table 1 asserts pass rates).

The tuning side-table mirrors PHAST's "tuning parameters without source
change": per-(op, key) kernel parameters (block shapes etc.) that kernels
look up at trace time.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Dict, Iterator, Optional, Sequence, Tuple

from repro.core.policy import Backend, current_backend


@dataclasses.dataclass
class OpEntry:
    name: str
    reference: Callable[..., Any]
    pallas: Optional[Callable[..., Any]] = None
    doc: str = ""
    # Coverage-lint declarations (repro.analysis.coverage).  ``tuning``
    # names the tuning-table keys the Pallas lowering resolves via
    # ``get_tuning`` — ``()`` declares "no tunable parameters", ``None``
    # means undeclared (a C102 finding for ops with a lowering).
    # ``reference_only=True`` records that the op intentionally has no
    # Pallas lowering (silences C101).
    tuning: Optional[Tuple[str, ...]] = None
    reference_only: bool = False

    def resolve(self, backend: Backend) -> Callable[..., Any]:
        if backend is Backend.PALLAS and self.pallas is not None:
            return self.pallas
        return self.reference


_OPS: Dict[str, OpEntry] = {}
_TUNING: Dict[tuple, Dict[str, Any]] = {}


def register_op(
    name: str,
    *,
    reference: Callable[..., Any],
    pallas: Optional[Callable[..., Any]] = None,
    doc: str = "",
    tuning: Optional[str | Sequence[str]] = None,
    reference_only: bool = False,
) -> OpEntry:
    if name in _OPS:
        raise ValueError(f"op {name!r} already registered")
    if reference_only and pallas is not None:
        raise ValueError(
            f"op {name!r}: reference_only=True with a pallas lowering"
        )
    if isinstance(tuning, str):
        tuning = (tuning,)
    elif tuning is not None:
        tuning = tuple(tuning)
    entry = OpEntry(
        name=name,
        reference=reference,
        pallas=pallas,
        doc=doc,
        tuning=tuning,
        reference_only=reference_only,
    )
    _OPS[name] = entry
    return entry


def attach_pallas(name: str, fn: Callable[..., Any]) -> None:
    """Attach/replace the Pallas lowering of an already-registered op."""
    _OPS[name].pallas = fn


def get_op(name: str) -> OpEntry:
    try:
        return _OPS[name]
    except KeyError as e:
        raise KeyError(
            f"op {name!r} not registered; known: {sorted(_OPS)}"
        ) from e


def dispatch(name: str) -> Callable[..., Any]:
    """Resolve op ``name`` under the current backend policy."""
    return get_op(name).resolve(current_backend())


def list_ops() -> Dict[str, OpEntry]:
    return dict(_OPS)


def coverage() -> Dict[str, bool]:
    """name -> has a Pallas lowering (the 'ported to PHAST' bit per block)."""
    return {name: e.pallas is not None for name, e in _OPS.items()}


# ---------------------------------------------------------------------------
# Tuning registry: per-(op, key) kernel parameters.
#
# Three layers resolve at trace time, lowest to highest precedence:
#
#     call-site defaults (the hand-set values baked into kernel source)
#         < persisted table (op, "default") < persisted table (op, key)
#         < set_tuning (op, "default")      < set_tuning (op, key)
#
# The persisted table is the committed artifact of the autotuning sweep
# (src/repro/tuning/tuning_table.json, format documented in the
# ``repro.tuning`` package docstring); ``key`` is normally a shape class
# (``repro.tuning.shapes.shape_class``).  A ``key`` that misses every
# layer falls back cleanly to the call-site defaults — and the table
# always supersedes the hand-set defaults for classes it covers, while
# an explicit ``set_tuning`` (tests, experiments, config overrides)
# always beats the table.
# ---------------------------------------------------------------------------

_TABLE: Optional[Dict[tuple, Dict[str, Any]]] = None
_LAST_RESOLVED: Dict[str, str] = {}


def _table() -> Dict[tuple, Dict[str, Any]]:
    """Lazily load the persisted tuning table (flattened view)."""
    global _TABLE
    if _TABLE is None:
        from repro.tuning import table as _tt

        path = _tt.resolved_path()
        if path is None:
            _TABLE = {}
        else:
            try:
                _TABLE = _tt.flatten(_tt.load(path))
            except ValueError:
                # a corrupt table must not brick every op; the coverage
                # lint (C104/C105) is the loud path for table problems
                _TABLE = {}
    return _TABLE


def load_tuning_table(source: Any = None) -> int:
    """(Re)load the persisted table; returns the number of entries.

    ``source`` may be a path, a table document (dict with ``entries``),
    an already-flattened ``{(op, key): params}`` mapping, or ``None``
    for the default path (``REPRO_TUNING_TABLE`` respected).
    """
    global _TABLE
    from repro.tuning import table as _tt

    if source is None:
        _TABLE = None
        return len(_table())
    if isinstance(source, dict):
        if "entries" in source:
            _TABLE = _tt.flatten(source)
        else:
            _TABLE = {k: dict(v) for k, v in source.items()}
    else:
        _TABLE = _tt.flatten(_tt.load(source))
    return len(_TABLE)


@contextlib.contextmanager
def tuning_table(source: Any) -> Iterator[None]:
    """Scoped table replacement; ``{}`` (or ``None``) disables the table.

    Used by the autotuner (sweep against a clean slate) and by the perf
    snapshot (measure the hand-set defaults the table supersedes).
    """
    global _TABLE
    saved = _TABLE
    try:
        if source is None:
            _TABLE = {}
        else:
            _TABLE = None
            load_tuning_table(source)
        yield
    finally:
        _TABLE = saved


def set_tuning(op: str, key: str = "default", **params: Any) -> None:
    _TUNING[(op, key)] = dict(params)


@contextlib.contextmanager
def tuning_overrides(op: str, key: str = "default",
                     **params: Any) -> Iterator[None]:
    """Scoped ``set_tuning`` — the autotuner's per-candidate install."""
    saved = _TUNING.get((op, key))
    _TUNING[(op, key)] = dict(params)
    try:
        yield
    finally:
        if saved is None:
            _TUNING.pop((op, key), None)
        else:
            _TUNING[(op, key)] = saved


def get_tuning(op: str, key: str = "default", **defaults: Any) -> Dict[str, Any]:
    out = dict(defaults)
    tab = _table()
    out.update(tab.get((op, "default"), {}))
    if key != "default":
        out.update(tab.get((op, key), {}))
    out.update(_TUNING.get((op, "default"), {}))
    if key != "default":
        out.update(_TUNING.get((op, key), {}))
    _LAST_RESOLVED[op] = key
    return out


def last_resolved(op: str) -> Optional[str]:
    """The ``key`` the most recent ``get_tuning(op, ...)`` resolved.

    A debugging/self-check aid: the autotuner asserts its cell drivers
    classify shapes exactly like the kernel call sites do.
    """
    return _LAST_RESOLVED.get(op)


def clear_tuning() -> None:
    _TUNING.clear()
