"""Functors — PHAST's user-extensible parallel building block, in JAX.

A PHAST functor is a struct with ``operator()`` applied per element / per
row / per tile by ``phast::for_each`` etc.; linked captures (``vec.link``)
bring auxiliary containers into scope.  The paper's InnerProduct port
(Listing 1.2) defines ``matrixPlusVectorRows`` this way.

JAX equivalents implemented here:

  * ``for_each_elementwise(f, x, *linked)``   — vmapped scalar functor
  * ``for_each_rows(f, m, *linked)``          — functor over matrix rows i
  * ``for_each_tiles(f, x, tile, *linked)``   — functor over 2-D tiles
    (the TPU-native unit: PHAST's "one thread per element" becomes
    "one grid cell per (sublane×lane) tile"; used by the Pallas lowerings)

Functors stay *traceable*: they are plain Python callables over jnp values,
so the same functor body runs under the reference backend (vmap) or inside
a Pallas kernel body (where ``for_each_tiles`` supplies the tile).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def for_each_elementwise(f: Callable, x: jax.Array, *linked: jax.Array) -> jax.Array:
    """Apply scalar functor f(elem, *linked_elems) over every element.

    ``linked`` arrays are broadcast against x (like PHAST's .link of a
    compatible container).
    """
    flat = x.reshape(-1)
    linked_flat = [jnp.broadcast_to(l, x.shape).reshape(-1) for l in linked]
    out = jax.vmap(f)(flat, *linked_flat)
    return out.reshape(x.shape)


def for_each_rows(f: Callable, m: jax.Array, *linked: jax.Array) -> jax.Array:
    """Apply row functor f(row, *linked) over the leading axis of ``m``.

    The direct analogue of ``phast::for_each(matC.begin_i(), matC.end_i(),
    functor)`` in the paper's Listing 1.2.
    """
    return jax.vmap(lambda row: f(row, *linked))(m)


def matrix_plus_vector_rows(m: jax.Array, vec: jax.Array) -> jax.Array:
    """The paper's ``matrixPlusVectorRows`` functor: add vec to every row."""
    return for_each_rows(lambda row, v: row + v, m, vec)


def for_each_tiles(
    f: Callable[[jax.Array], jax.Array],
    x: jax.Array,
    tile: tuple[int, int],
) -> jax.Array:
    """Apply tile functor f(tile_2d) over a 2-D array in (th, tw) tiles.

    Reference lowering of the TPU execution model: pad to tile multiples,
    reshape into the tile grid, vmap the functor over grid cells, unpad.
    The Pallas lowering of the same functor is a pallas_call whose grid is
    the same tile grid — the point is that *f does not change*.
    """
    th, tw = tile
    h, w = x.shape
    ph, pw = (-h) % th, (-w) % tw
    xp = jnp.pad(x, ((0, ph), (0, pw)))
    gh, gw = xp.shape[0] // th, xp.shape[1] // tw
    tiles = xp.reshape(gh, th, gw, tw).transpose(0, 2, 1, 3)
    out = jax.vmap(jax.vmap(f))(tiles)
    out = out.transpose(0, 2, 1, 3).reshape(gh * th, gw * tw)
    return out[:h, :w]
