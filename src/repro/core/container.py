"""Containers — the JAX analogue of PHAST's vector/matrix/cube + Caffe's Blob.

PHAST's containers carry (a) the storage, (b) the logical rank
(vector/matrix/cube/grid), and (c) the memory layout assumption (row-major),
and the paper identifies layout mismatch at domain boundaries (row-major
PHAST vs column-major OpenBLAS) as possibly the single largest overhead.

In JAX, arrays are logical; layout is an XLA concern.  What *does* carry over:

  * ``Blob`` — Caffe's container: a ``data`` array and a ``diff`` (gradient)
    array with one shape.  Registered as a pytree so Blobs flow through jit/
    grad/scan unchanged.
  * ``MajorOrder`` tagging + ``as_layout`` — we keep an explicit major-order
    tag so the Caffe-port benchmarks can *reproduce and measure* the paper's
    boundary-transpose pathology (a real transpose is materialized whenever
    a row-major region hands a tensor to a column-major region, exactly like
    the host-side copies the paper describes).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class MajorOrder(enum.Enum):
    ROW = "row"        # PHAST / C order
    COLUMN = "column"  # OpenBLAS / Fortran order


def as_layout(x: jax.Array, src: MajorOrder, dst: MajorOrder) -> jax.Array:
    """Materialize a layout change (identity if src == dst).

    For a 2-D array, moving row->column order is a physical transpose of the
    storage while keeping the logical view; we model it as transpose+copy,
    which is what the paper's host-side conversion pays.
    """
    if src == dst or x.ndim < 2:
        return x
    perm = tuple(reversed(range(x.ndim)))
    # transpose twice = logical identity, but forces a materialized relayout
    return jnp.transpose(jnp.transpose(x, perm).copy(), perm)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Blob:
    """Caffe's Blob: data + diff of identical shape.

    ``diff`` is lazily allocated (None until someone writes a gradient), so
    inference-only nets never pay for it.
    """

    data: jax.Array
    diff: Optional[jax.Array] = None

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return (self.data, self.diff), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, diff = children
        return cls(data=data, diff=diff)

    # -- Caffe-like API ------------------------------------------------------
    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(self.data.shape)

    @property
    def count(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def num(self) -> int:
        return self.shape[0]

    @property
    def dtype(self):
        return self.data.dtype

    def with_data(self, data: jax.Array) -> "Blob":
        return Blob(data=data, diff=self.diff)

    def with_diff(self, diff: jax.Array) -> "Blob":
        return Blob(data=self.data, diff=diff)

    def ensure_diff(self) -> "Blob":
        if self.diff is None:
            return Blob(data=self.data, diff=jnp.zeros_like(self.data))
        return self

    @staticmethod
    def zeros(shape: Sequence[int], dtype=jnp.float32) -> "Blob":
        return Blob(data=jnp.zeros(tuple(shape), dtype=dtype))

    # reshape mirrors Caffe's Blob::Reshape (logical only)
    def reshape(self, shape: Sequence[int]) -> "Blob":
        return Blob(
            data=self.data.reshape(tuple(shape)),
            diff=None if self.diff is None else self.diff.reshape(tuple(shape)),
        )

    # PHAST-style typed views ------------------------------------------------
    def as_matrix(self, rows: int, cols: int, transpose: bool = False) -> jax.Array:
        m = self.data.reshape(rows, cols)
        return m.T if transpose else m

    def as_vector(self) -> jax.Array:
        return self.data.reshape(-1)
