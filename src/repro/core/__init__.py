# The paper's primary contribution, adapted to JAX/TPU: a single-source
# performance-portability core. One canonical op name -> {reference jnp,
# Pallas TPU} lowerings selected by a policy switch (the PHAST macro
# analogue), PHAST-style containers (Blob) and functors.
from repro.core.container import Blob, MajorOrder, as_layout
from repro.core.functor import (
    for_each_elementwise,
    for_each_rows,
    for_each_tiles,
    matrix_plus_vector_rows,
)
from repro.core.policy import (
    Backend,
    current_backend,
    interpret_default,
    on_tpu,
    set_default_backend,
    use_backend,
)
from repro.core.registry import (
    OpEntry,
    attach_pallas,
    clear_tuning,
    coverage,
    dispatch,
    get_op,
    get_tuning,
    last_resolved,
    list_ops,
    load_tuning_table,
    register_op,
    set_tuning,
    tuning_overrides,
    tuning_table,
)

__all__ = [
    "Blob",
    "MajorOrder",
    "as_layout",
    "Backend",
    "current_backend",
    "interpret_default",
    "on_tpu",
    "set_default_backend",
    "use_backend",
    "OpEntry",
    "attach_pallas",
    "clear_tuning",
    "coverage",
    "dispatch",
    "get_op",
    "get_tuning",
    "last_resolved",
    "list_ops",
    "load_tuning_table",
    "register_op",
    "set_tuning",
    "tuning_overrides",
    "tuning_table",
    "for_each_elementwise",
    "for_each_rows",
    "for_each_tiles",
    "matrix_plus_vector_rows",
]
