"""Backend policy — the JAX analogue of PHAST's ``PHAST_DEVICE`` macro.

PHAST selects CPU vs GPU by flipping a compile-time macro and swapping the
Makefile; the *source does not change*.  Here the same role is played by a
process-wide (optionally scoped) policy object that every registered op
consults at trace time to pick its lowering:

    * ``Backend.REFERENCE`` — the pure-jnp oracle ("sequential-like" code).
    * ``Backend.PALLAS``    — the Pallas TPU kernel (``pl.pallas_call``).
    * ``Backend.AUTO``      — PALLAS when a TPU is present, else REFERENCE.

Selection sources, in priority order:
    1. an active ``use_backend(...)`` context manager,
    2. explicit ``set_default_backend(...)``,
    3. the ``REPRO_BACKEND`` environment variable,
    4. AUTO.

``interpret_default()`` reports whether Pallas kernels should run in
interpret mode (true off-TPU), so the *same* kernel source validates on CPU
and compiles to Mosaic on TPU — the code-once / compile-twice property the
paper demonstrates with two Makefiles.
"""
from __future__ import annotations

import contextlib
import enum
import os
import threading
from typing import Iterator, Optional

import jax


class Backend(enum.Enum):
    """Which lowering an op should use."""

    REFERENCE = "reference"
    PALLAS = "pallas"
    AUTO = "auto"

    @staticmethod
    def parse(name: str) -> "Backend":
        try:
            return Backend(name.strip().lower())
        except ValueError as e:
            raise ValueError(
                f"unknown backend {name!r}; expected one of "
                f"{[b.value for b in Backend]}"
            ) from e


class _PolicyState(threading.local):
    """Thread-local ``use_backend`` stack.

    Only the *scoped* stack is per-thread; the process default deliberately
    is not — serving worker threads must see ``set_default_backend(...)``
    made from the main thread (a thread-local default silently reverted
    workers to AUTO).
    """

    def __init__(self) -> None:
        self.stack: list[Backend] = []


_STATE = _PolicyState()
_DEFAULT: Optional[Backend] = None


def _platform() -> str:
    return jax.devices()[0].platform


def on_tpu() -> bool:
    return _platform() == "tpu"


def set_default_backend(backend: Backend | str | None) -> None:
    """Process-default backend (overrides env, overridden by use_backend).

    Shared across threads: a worker thread spawned after (or before) this
    call observes the same default.  Pass ``None`` to clear.
    """
    global _DEFAULT
    if isinstance(backend, str):
        backend = Backend.parse(backend)
    _DEFAULT = backend


def current_backend() -> Backend:
    """Resolve the active backend to REFERENCE or PALLAS (never AUTO)."""
    if _STATE.stack:
        b = _STATE.stack[-1]
    elif _DEFAULT is not None:
        b = _DEFAULT
    else:
        b = Backend.parse(os.environ.get("REPRO_BACKEND", "auto"))
    if b is Backend.AUTO:
        b = Backend.PALLAS if on_tpu() else Backend.REFERENCE
    return b


@contextlib.contextmanager
def use_backend(backend: Backend | str) -> Iterator[None]:
    """Scoped backend override — the 'second Makefile' in one line."""
    if isinstance(backend, str):
        backend = Backend.parse(backend)
    _STATE.stack.append(backend)
    try:
        yield
    finally:
        _STATE.stack.pop()


def interpret_default() -> bool:
    """Pallas interpret mode: True anywhere but a real TPU."""
    return not on_tpu()
