"""``repro.analysis`` — invariant linter + runtime auditors for the stack.

The PHAST port survived because every ported layer was *checkable* against
the original; this package gives the repro stack the same property.  The
ROADMAP "Standing notes" (jit cache size 1, host-mirror scheduling without
device syncs, ``pallas_compat`` as the single Pallas-API import point,
scoped backend policy, f32 SSD state) are enforced here as failing checks
rather than prose.

Static rules (AST-based, run by ``scripts/lint.py`` / ``ci.sh --lint``)
========================================================================

R001  no-direct-tpu-import
    ``jax.experimental.pallas.tpu`` (and ``TPU*`` symbols from pallas) may
    only be imported by ``repro/kernels/pallas_compat.py``.  JAX renames
    these symbols across releases; the compat shim is the one place that
    absorbs the drift.  Fix: import ``pallas_compat as plc`` and use
    ``plc.VMEM`` / ``plc.CompilerParams`` / ``plc.MemorySpace`` / etc.

R002  no-implicit-host-sync
    The host-mirror scheduler and the traced ``engine_step`` paths
    (``serving/engine.py`` step-choice code, ``models/lm.py`` chunk-width
    logic) must not force device→host syncs: no ``.item()``,
    ``int()/bool()/float()`` on device values, ``np.asarray``/``np.array``
    on device arrays, ``jax.device_get`` or ``jax.block_until_ready``.
    The one sanctioned sync is the ``steps_per_sync`` harvest in
    ``ServingEngine.step`` (allowlisted).  Fix: keep scheduling decisions
    on the host mirror; batch device reads into the harvest.

R003  jit-must-donate
    Every ``jax.jit`` call site under ``serving/`` must declare
    ``donate_argnums`` (or ``donate_argnames``) so decode-state pytrees
    are donated instead of copied each step.  Fix: pass the state
    arguments' positions in ``donate_argnums=...``.

R004  no-process-wide-backend
    Library code under ``src/repro/`` must not call
    ``set_default_backend``: it mutates process-wide state and leaks
    across serving worker threads (the PR 1 lesson).  Fix: use the scoped
    ``use_backend(...)`` context-manager stack; ``set_default_backend``
    is for application entry points only.

R005  ssd-state-stays-f32
    The SSD scan's carried state must stay float32 end to end — a lower
    precision cast compounds across chunks.  In
    ``kernels/mamba_scan.py`` / ``models/components.py``, any
    ``.astype(...)`` of a state-carrying value (``state*``, ``h0*``,
    ``hf*``, ``ssm_state*``) to anything but ``jnp.float32`` is flagged.
    Fix: keep the cast as ``jnp.float32`` (the kernel's out_shape already
    declares f32) or rename the value if it is genuinely not scan state.

R006  no-raw-layout-kwargs
    Serving library code (``serving/*.py``) must not re-introduce the
    raw layout kwarg pile that ``repro.serving.config.CacheConfig``
    replaced: a function parameter named ``layout``, or two or more of
    ``page_size``/``n_pages``/``snapshots``/``host_spill`` on one
    signature, is flagged.  ``config.py`` (defines the fields) and
    ``pager.py`` (implements the paged layout) are out of scope.  Fix:
    accept ``cache: CacheConfig`` and read the fields from it.

R007  kv-scale-stays-f32
    The int8 paged-KV path quantizes only the payload; the per-(page,
    head) scale pools (``ksc``/``vsc``, host tier ``hksc``/``hvsc``) and
    the ``kv_scales`` tuples threaded into the kernels must stay float32
    — a sub-f32 scale multiplies into *every* dequantized read, the same
    compounding failure mode R005 guards against in the SSD scan.  In
    ``kernels/flash_attention.py`` / ``serving/pager.py`` /
    ``models/lm.py``, any ``.astype(...)`` of a scale-carrying value
    (``ksc*``, ``vsc*``, ``k_scale*``, ``v_scale*``, ``kv_scales*``, and
    host-tier variants) to anything but ``jnp.float32`` is flagged.
    Fix: keep scale math f32 (attention accumulation inside the kernels
    is f32 regardless of storage dtype).

Coverage lint (C101–C105, run by the same entry points)
=======================================================

C101  an op registered without a Pallas lowering must say so explicitly
      (``register_op(..., reference_only=True)``) — half-wired kernels
      can't hide behind a missing backend.
C102  an op with a Pallas lowering must declare which tuning-table keys
      it resolves (``register_op(..., tuning="gemm")``; ``tuning=()``
      declares "no tunable parameters").
C103  every declared tuning key must actually appear at a ``get_tuning``
      call site under ``src/repro/kernels`` — declarations can't go stale.
C104  every entry in the persisted tuning table
      (``src/repro/tuning/tuning_table.json``) must match a declared
      tuning key of an op that still *has* a Pallas lowering — a table
      entry whose op was deleted, renamed, or demoted to reference-only
      fails the lint instead of silently feeding dead values.  Schema
      violations in the table surface here too.
C105  the parameters a table entry sets must be knobs some ``get_tuning``
      call site still resolves (the sweep artifact can't outlive the
      kernel's knob set).

Suppression syntax
==================

Append ``# repro-lint: disable=R001`` (comma-separate several IDs, or
``disable=all``) to the offending line, or put the comment alone on the
line directly above it.  Suppressions are for sanctioned exceptions such
as the ``pallas_compat`` import itself — use sparingly.

Runtime auditors (``repro.analysis.audit``)
===========================================

``jit_cache_audit(engine)`` wraps the engine's jitted entry points
(``_step_n``/``_spec_n``/``_admit``/``_prefill``/``_release``/``_spill``/
``_restore`` — absent or ``None`` attributes are skipped) and raises
``JitCacheRetrace`` the moment any of them retraces (cache size > 1) —
run it over a mixed prefill/decode/admission workload to prove the
cache-size-1 standing note.  ``no_transfer_audit()`` arms
``jax.transfer_guard_device_to_host("disallow")`` so any *implicit*
device→host transfer between harvest syncs raises, while the explicit
``jax.device_get`` harvest (and host→device uploads) stay legal.
"""
from __future__ import annotations

from repro.analysis.audit import JitCacheRetrace, jit_cache_audit, no_transfer_audit
from repro.analysis.lint import Finding, lint_file, lint_paths, lint_source
from repro.analysis.coverage import (
    collect_tuning_sites,
    coverage_findings,
    table_findings,
)
from repro.analysis.rules import ALL_RULES

__all__ = [
    "ALL_RULES",
    "Finding",
    "JitCacheRetrace",
    "collect_tuning_sites",
    "coverage_findings",
    "table_findings",
    "jit_cache_audit",
    "lint_file",
    "lint_paths",
    "lint_source",
    "no_transfer_audit",
]
