"""Op-registry coverage lint (C101–C103) — new kernels can't ship half-wired.

Cross-checks the op registry's declarations against the kernel sources:

    C101  op without a Pallas lowering not declared ``reference_only``
    C102  op with a Pallas lowering but no declared tuning keys
    C103  declared tuning key never resolved by a ``get_tuning`` call
          site under ``src/repro/kernels`` (stale declaration)

Tuning keys at call sites are collected by AST scan: the literal first
argument of ``get_tuning(...)``, literal ``tuning_op=`` / ``op_name=``
keyword arguments (kernels that thread the key through a helper), and
literal defaults of parameters with those names.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional, Set

from repro.analysis.rules import Finding

_KEY_PARAMS = ("tuning_op", "op_name")


def _collect_tuning_keys(kernels_root: Path) -> Set[str]:
    keys: Set[str] = set()
    for fp in sorted(kernels_root.rglob("*.py")):
        tree = ast.parse(fp.read_text(encoding="utf-8"), filename=str(fp))
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                fn = node.func
                name = fn.id if isinstance(fn, ast.Name) else (
                    fn.attr if isinstance(fn, ast.Attribute) else None
                )
                if name == "get_tuning" and node.args:
                    first = node.args[0]
                    if isinstance(first, ast.Constant) and isinstance(
                        first.value, str
                    ):
                        keys.add(first.value)
                for kw in node.keywords:
                    if kw.arg in _KEY_PARAMS and isinstance(
                        kw.value, ast.Constant
                    ) and isinstance(kw.value.value, str):
                        keys.add(kw.value.value)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                args = node.args
                params = args.posonlyargs + args.args + args.kwonlyargs
                defaults = list(args.defaults) + list(args.kw_defaults)
                names = [a.arg for a in params][-len(defaults):] if defaults else []
                for pname, dflt in zip(names, defaults):
                    if (
                        pname in _KEY_PARAMS
                        and isinstance(dflt, ast.Constant)
                        and isinstance(dflt.value, str)
                    ):
                        keys.add(dflt.value)
    return keys


def coverage_findings(kernels_root: Optional[Path] = None) -> List[Finding]:
    """Run the coverage lint; importing the ops module registers everything."""
    import repro.kernels.ops  # noqa: F401  - populates the registry
    from repro.core.registry import list_ops

    if kernels_root is None:
        import repro.kernels

        kernels_root = Path(repro.kernels.__file__).resolve().parent
    call_site_keys = _collect_tuning_keys(kernels_root)
    path = "src/repro/kernels/ops.py"
    out: List[Finding] = []
    for name, entry in sorted(list_ops().items()):
        if entry.pallas is None and not entry.reference_only:
            out.append(
                Finding(
                    rule="C101",
                    path=path,
                    line=1,
                    col=1,
                    message=(
                        f"op {name!r} has no Pallas lowering and is not "
                        "declared reference_only"
                    ),
                    hint=(
                        "add the lowering, or register_op(..., "
                        "reference_only=True) to record the gap explicitly"
                    ),
                )
            )
        if entry.pallas is not None and entry.tuning is None:
            out.append(
                Finding(
                    rule="C102",
                    path=path,
                    line=1,
                    col=1,
                    message=(
                        f"op {name!r} has a Pallas lowering but no declared "
                        "tuning keys"
                    ),
                    hint=(
                        "register_op(..., tuning=\"<get_tuning key>\") — "
                        "use tuning=() if the kernel has no tunable knobs"
                    ),
                )
            )
        for key in entry.tuning or ():
            if key not in call_site_keys:
                out.append(
                    Finding(
                        rule="C103",
                        path=path,
                        line=1,
                        col=1,
                        message=(
                            f"op {name!r} declares tuning key {key!r} but no "
                            "get_tuning call site under kernels/ resolves it"
                        ),
                        hint=(
                            "fix the declared key or delete the stale "
                            "declaration"
                        ),
                    )
                )
    return out
