"""Op-registry coverage lint (C101–C105) — new kernels can't ship half-wired.

Cross-checks the op registry's declarations against the kernel sources
and the persisted tuning table:

    C101  op without a Pallas lowering not declared ``reference_only``
    C102  op with a Pallas lowering but no declared tuning keys
    C103  declared tuning key never resolved by a ``get_tuning`` call
          site under ``src/repro/kernels`` (stale declaration)
    C104  tuning-table entry for an unknown key, or for a key whose
          declaring op(s) lost their Pallas lowering (stale table)
    C105  tuning-table params name a knob no ``get_tuning`` call site
          resolves anymore (stale sweep artifact)

Tuning keys at call sites are collected by AST scan: the literal first
argument of ``get_tuning(...)``, literal ``tuning_op=`` / ``op_name=``
keyword arguments (kernels that thread the key through a helper), and
literal defaults of parameters with those names.  The same scan collects
each key's *knobs* — the keyword arguments of the ``get_tuning`` call
(``key=`` excluded) with their hand-set defaults, resolving a
``knob=knob`` pass-through to the enclosing function parameter's literal
default.  This is what makes the autotuner's sweep space derivable
instead of hand-listed (``repro.tuning.autotune``).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Set

from repro.analysis.rules import Finding

_KEY_PARAMS = ("tuning_op", "op_name")

#: knob-name -> hand-set default, per tuning key (None = default unknown)
KnobMap = Dict[str, Dict[str, Optional[int]]]


def _literal_param_defaults(fn: ast.FunctionDef) -> Dict[str, int]:
    """Parameter name -> literal int default, for one function def."""
    args = fn.args
    params = args.posonlyargs + args.args
    out: Dict[str, int] = {}
    for a, dflt in zip(params[len(params) - len(args.defaults):],
                       args.defaults):
        if isinstance(dflt, ast.Constant) and isinstance(dflt.value, int) \
                and not isinstance(dflt.value, bool):
            out[a.arg] = dflt.value
    for a, dflt in zip(args.kwonlyargs, args.kw_defaults):
        if isinstance(dflt, ast.Constant) and isinstance(dflt.value, int) \
                and not isinstance(dflt.value, bool):
            out[a.arg] = dflt.value
    return out


def _call_name(node: ast.Call) -> Optional[str]:
    fn = node.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return None


def _scan_file(tree: ast.AST, sites: KnobMap, keys: Set[str]) -> None:
    """One file's contribution to ``sites``/``keys``.

    ``get_tuning`` calls with a literal key attach their knobs to that
    key; calls whose key is threaded through a variable (``tuning_op`` /
    ``op_name``) attach to every key this *file* names via those params —
    the helper-kernel pattern (eltwise, mamba_scan).
    """
    file_keys: Set[str] = set()
    wildcard_knobs: Dict[str, Optional[int]] = {}

    # (enclosing-function literal defaults, call) pairs; module level uses {}
    contexts = [({}, n) for n in ast.iter_child_nodes(tree)
                if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            contexts.append((_literal_param_defaults(node), node))

    for params, scope in contexts:
        for node in ast.walk(scope):
            if not isinstance(node, ast.Call):
                continue
            for kw in node.keywords:
                if kw.arg in _KEY_PARAMS and isinstance(
                    kw.value, ast.Constant
                ) and isinstance(kw.value.value, str):
                    keys.add(kw.value.value)
                    file_keys.add(kw.value.value)
            if _call_name(node) != "get_tuning":
                continue
            knobs: Dict[str, Optional[int]] = {}
            for kw in node.keywords:
                if kw.arg is None or kw.arg == "key":
                    continue
                if isinstance(kw.value, ast.Constant) and isinstance(
                    kw.value.value, int
                ) and not isinstance(kw.value.value, bool):
                    knobs[kw.arg] = kw.value.value
                elif isinstance(kw.value, ast.Name):
                    knobs[kw.arg] = params.get(kw.value.id)
                else:
                    knobs[kw.arg] = None
            if node.args and isinstance(node.args[0], ast.Constant) \
                    and isinstance(node.args[0].value, str):
                key = node.args[0].value
                keys.add(key)
                merged = sites.setdefault(key, {})
                for k, v in knobs.items():
                    merged.setdefault(k, v)
            else:
                for k, v in knobs.items():
                    wildcard_knobs.setdefault(k, v)
        if isinstance(scope, (ast.FunctionDef, ast.AsyncFunctionDef)):
            a = scope.args
            pos = a.posonlyargs + a.args
            aligned = list(zip(pos[len(pos) - len(a.defaults):], a.defaults))
            aligned += [p for p in zip(a.kwonlyargs, a.kw_defaults)
                        if p[1] is not None]
            for param, dflt in aligned:
                if param.arg in _KEY_PARAMS and isinstance(dflt, ast.Constant) \
                        and isinstance(dflt.value, str):
                    keys.add(dflt.value)
                    file_keys.add(dflt.value)

    for key in file_keys:
        merged = sites.setdefault(key, {})
        for k, v in wildcard_knobs.items():
            merged.setdefault(k, v)


def collect_tuning_sites(kernels_root: Optional[Path] = None) -> KnobMap:
    """Tuning key -> {knob: hand-set default} from the kernel sources."""
    if kernels_root is None:
        import repro.kernels

        kernels_root = Path(repro.kernels.__file__).resolve().parent
    sites: KnobMap = {}
    keys: Set[str] = set()
    for fp in sorted(kernels_root.rglob("*.py")):
        tree = ast.parse(fp.read_text(encoding="utf-8"), filename=str(fp))
        _scan_file(tree, sites, keys)
    for key in keys:
        sites.setdefault(key, {})
    return sites


def _collect_tuning_keys(kernels_root: Path) -> Set[str]:
    return set(collect_tuning_sites(kernels_root))


def table_findings(
    doc: Optional[dict] = None,
    kernels_root: Optional[Path] = None,
) -> List[Finding]:
    """Lint the persisted tuning table against the live registry (C104/C105)."""
    import repro.kernels.ops  # noqa: F401  - populates the registry
    from repro.core.registry import list_ops
    from repro.tuning import table as tt

    path = "src/repro/tuning/tuning_table.json"
    if doc is None:
        fs_path = tt.resolved_path()
        if fs_path is None or not fs_path.exists():
            return []
        try:
            doc = tt.load(fs_path)
        except ValueError as exc:
            return [
                Finding(
                    rule="C104", path=path, line=1, col=1,
                    message=f"tuning table failed schema validation: {exc}",
                    hint="regenerate with python -m repro.tuning.autotune",
                )
            ]
    errs = tt.validate(doc)
    if errs:
        return [
            Finding(
                rule="C104", path=path, line=1, col=1,
                message=f"tuning table failed schema validation: {err}",
                hint="regenerate with python -m repro.tuning.autotune",
            )
            for err in errs
        ]

    declared_by: Dict[str, List[str]] = {}
    pallas_keys: Set[str] = set()
    for name, entry in sorted(list_ops().items()):
        for key in entry.tuning or ():
            declared_by.setdefault(key, []).append(name)
            if entry.pallas is not None:
                pallas_keys.add(key)
    sites = collect_tuning_sites(kernels_root)

    out: List[Finding] = []
    for key, classes in sorted(doc.get("entries", {}).items()):
        if key not in pallas_keys:
            if key in declared_by:
                msg = (
                    f"tuning-table entry {key!r}: declaring op(s) "
                    f"{declared_by[key]} no longer have a Pallas lowering"
                )
                hint = ("drop the entry or restore the lowering — tuned "
                        "values for a reference-only op are dead weight")
            else:
                msg = (
                    f"tuning-table entry {key!r} matches no registered "
                    "op's declared tuning keys"
                )
                hint = ("regenerate the table (python -m "
                        "repro.tuning.autotune) or remove the entry")
            out.append(Finding(rule="C104", path=path, line=1, col=1,
                               message=msg, hint=hint))
            continue
        knobs = sites.get(key, {})
        for cls, cell in sorted(classes.items()):
            for pname in sorted(cell.get("params", {})):
                if pname not in knobs:
                    out.append(
                        Finding(
                            rule="C105", path=path, line=1, col=1,
                            message=(
                                f"tuning-table entry {key!r}[{cls!r}] sets "
                                f"knob {pname!r} that no get_tuning call "
                                "site under kernels/ resolves"
                            ),
                            hint=("the kernel's knobs changed; regenerate "
                                  "the table"),
                        )
                    )
    return out


def coverage_findings(kernels_root: Optional[Path] = None) -> List[Finding]:
    """Run the coverage lint; importing the ops module registers everything."""
    import repro.kernels.ops  # noqa: F401  - populates the registry
    from repro.core.registry import list_ops

    call_site_keys = set(collect_tuning_sites(kernels_root))
    path = "src/repro/kernels/ops.py"
    out: List[Finding] = []
    for name, entry in sorted(list_ops().items()):
        if entry.pallas is None and not entry.reference_only:
            out.append(
                Finding(
                    rule="C101",
                    path=path,
                    line=1,
                    col=1,
                    message=(
                        f"op {name!r} has no Pallas lowering and is not "
                        "declared reference_only"
                    ),
                    hint=(
                        "add the lowering, or register_op(..., "
                        "reference_only=True) to record the gap explicitly"
                    ),
                )
            )
        if entry.pallas is not None and entry.tuning is None:
            out.append(
                Finding(
                    rule="C102",
                    path=path,
                    line=1,
                    col=1,
                    message=(
                        f"op {name!r} has a Pallas lowering but no declared "
                        "tuning keys"
                    ),
                    hint=(
                        "register_op(..., tuning=\"<get_tuning key>\") — "
                        "use tuning=() if the kernel has no tunable knobs"
                    ),
                )
            )
        for key in entry.tuning or ():
            if key not in call_site_keys:
                out.append(
                    Finding(
                        rule="C103",
                        path=path,
                        line=1,
                        col=1,
                        message=(
                            f"op {name!r} declares tuning key {key!r} but no "
                            "get_tuning call site under kernels/ resolves it"
                        ),
                        hint=(
                            "fix the declared key or delete the stale "
                            "declaration"
                        ),
                    )
                )
    out.extend(table_findings(kernels_root=kernels_root))
    return out
