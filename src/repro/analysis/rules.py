"""AST rule classes for the repro invariant linter.

Each rule is path-scoped: ``applies(path)`` decides whether a file is in
scope (paths are repo-relative posix strings, matched by suffix so the
linter works from any checkout root and on fixture files linted under a
virtual path), and ``check(tree, path, src)`` yields findings.  Rule
semantics are documented in the ``repro.analysis`` package docstring.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Iterable, Iterator, List, Optional, Sequence, Set, Tuple


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint finding, formatted as ``path:line:col: RXXX message``."""

    rule: str
    path: str
    line: int
    col: int
    message: str
    hint: str = ""

    def format(self) -> str:
        out = f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out


def _norm(path: str) -> str:
    return path.replace("\\", "/")


def _endswith(path: str, suffixes: Iterable[str]) -> bool:
    p = _norm(path)
    return any(p.endswith(s) for s in suffixes)


def _dotted(node: ast.AST) -> Optional[str]:
    """Reconstruct a dotted name from Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class Rule:
    rule_id: str = ""
    title: str = ""
    hint: str = ""

    def applies(self, path: str) -> bool:  # pragma: no cover - interface
        raise NotImplementedError

    def check(
        self, tree: ast.AST, path: str, src: str
    ) -> Iterator[Finding]:  # pragma: no cover - interface
        raise NotImplementedError

    def finding(self, path: str, node: ast.AST, message: str) -> Finding:
        return Finding(
            rule=self.rule_id,
            path=_norm(path),
            line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0) + 1,
            message=message,
            hint=self.hint,
        )


class R001DirectTpuImport(Rule):
    """No ``jax.experimental.pallas.tpu`` / ``TPU*`` imports outside compat."""

    rule_id = "R001"
    title = "no-direct-tpu-import"
    hint = (
        "import `repro.kernels.pallas_compat as plc` and use plc.VMEM / "
        "plc.CompilerParams / plc.MemorySpace — pallas_compat.py is the "
        "only module allowed to touch jax.experimental.pallas.tpu"
    )

    EXEMPT = ("repro/kernels/pallas_compat.py",)
    TPU_MOD = "jax.experimental.pallas.tpu"

    def applies(self, path: str) -> bool:
        return _norm(path).endswith(".py") and not _endswith(path, self.EXEMPT)

    def check(self, tree: ast.AST, path: str, src: str) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name.startswith(self.TPU_MOD):
                        yield self.finding(
                            path, node, f"direct import of {alias.name}"
                        )
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if mod.startswith(self.TPU_MOD):
                    yield self.finding(
                        path, node, f"direct import from {mod}"
                    )
                elif mod == "jax.experimental.pallas":
                    for alias in node.names:
                        if alias.name == "tpu" or alias.name.startswith("TPU"):
                            yield self.finding(
                                path,
                                node,
                                f"direct import of pallas.{alias.name}",
                            )


class _ScopedCallVisitor(ast.NodeVisitor):
    """Tracks the enclosing-function-name stack while visiting calls."""

    def __init__(self) -> None:
        self.stack: List[str] = []
        self.calls: List[Tuple[ast.Call, Tuple[str, ...]]] = []

    def _visit_fn(self, node) -> None:
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    def visit_Call(self, node: ast.Call) -> None:
        self.calls.append((node, tuple(self.stack)))
        self.generic_visit(node)


class R002ImplicitHostSync(Rule):
    """No implicit device→host syncs in scheduler / traced step paths."""

    rule_id = "R002"
    title = "no-implicit-host-sync"
    hint = (
        "keep step choice on the host mirror and batch device reads into "
        "the sanctioned steps_per_sync harvest in ServingEngine.step; "
        "inside traced code use jnp ops, never python scalar coercion"
    )

    # Functions (by name, at any nesting depth) that make up the
    # host-mirror scheduler and the traced step paths.  The harvest
    # allowlist marks the one function where explicit device reads are
    # sanctioned — everything else flags them.
    SCOPES = {
        "repro/serving/engine.py": frozenset(
            {
                "engine_step",
                "_sample",
                "_step_n",
                "_spec_step",
                "_spec_n",
                "_admit",
                "_prefill_step",
                "_release",
                "_spill",
                "_restore",
                "_refill",
                "_plan_admission",
                "_try_preempt",
                "_try_restore",
                "_expire_queued",
                "_apply_faults",
                "_effective_pages",
                "_req_key",
                "_drop_row",
                "cancel",
                "_advance_mirror",
                "_chunk_limit",
                "_prompt_phase_rows",
                "_match_prefix",
                "step",
            }
        ),
        "repro/serving/drafter.py": frozenset(
            {
                "propose",
                "ingest",
                "init_state",
                "_layers",
            }
        ),
        "repro/models/lm.py": frozenset(
            {
                "decode_step",
                "prefill_chunk",
                "_cache_index",
                "_cache_update",
                "_cache_update_chunk",
                "_paged_cow",
                "_paged_commit",
                "_snap_capture",
                "restore_snapshots",
                "reset_decode_rows",
                "spill_rows",
                "restore_rows",
            }
        ),
    }
    HARVEST_ALLOW = frozenset({"step"})

    SCALAR_COERCIONS = frozenset({"int", "float", "bool"})
    NP_NAMES = frozenset({"np", "numpy", "onp"})
    NP_SYNCS = frozenset({"asarray", "array"})
    JAX_SYNCS = frozenset({"device_get", "block_until_ready"})

    def _scope_for(self, path: str) -> Optional[frozenset]:
        p = _norm(path)
        for suffix, names in self.SCOPES.items():
            if p.endswith(suffix):
                return names
        return None

    def applies(self, path: str) -> bool:
        return self._scope_for(path) is not None

    def _classify(self, call: ast.Call) -> Optional[str]:
        """Return a description if the call is a host sync, else None."""
        fn = call.func
        if isinstance(fn, ast.Attribute):
            if fn.attr == "item":
                return ".item() forces a device->host sync"
            base = _dotted(fn.value)
            if base in self.NP_NAMES and fn.attr in self.NP_SYNCS:
                return f"{base}.{fn.attr}() on a device array syncs to host"
            if base == "jax" and fn.attr in self.JAX_SYNCS:
                return f"jax.{fn.attr}() outside the sanctioned harvest"
        elif isinstance(fn, ast.Name) and fn.id in self.SCALAR_COERCIONS:
            if call.args and not isinstance(call.args[0], ast.Constant):
                return (
                    f"{fn.id}() on a traced/device value forces a host sync"
                )
        return None

    def check(self, tree: ast.AST, path: str, src: str) -> Iterator[Finding]:
        scope = self._scope_for(path)
        assert scope is not None
        visitor = _ScopedCallVisitor()
        visitor.visit(tree)
        for call, stack in visitor.calls:
            if not any(name in scope for name in stack):
                continue
            if any(name in self.HARVEST_ALLOW for name in stack):
                continue
            desc = self._classify(call)
            if desc is not None:
                yield self.finding(path, call, desc)


class R003JitMustDonate(Rule):
    """``jax.jit`` in serving/ must declare donate_argnums."""

    rule_id = "R003"
    title = "jit-must-donate"
    hint = (
        "pass donate_argnums=(...) (or donate_argnames) naming the state "
        "pytree arguments so decode state is donated, not copied each step"
    )

    DONATE_KWS = frozenset({"donate_argnums", "donate_argnames"})

    def applies(self, path: str) -> bool:
        return "repro/serving/" in _norm(path) and path.endswith(".py")

    def _is_jit(self, node: ast.AST) -> bool:
        return _dotted(node) in ("jax.jit", "jit")

    def check(self, tree: ast.AST, path: str, src: str) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and self._is_jit(node.func):
                kws = {kw.arg for kw in node.keywords}
                if not kws & self.DONATE_KWS:
                    yield self.finding(
                        path, node, "jax.jit call without donate_argnums"
                    )
            elif isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef)
            ):
                for dec in node.decorator_list:
                    if not isinstance(dec, ast.Call) and self._is_jit(dec):
                        yield self.finding(
                            path,
                            dec,
                            "bare @jax.jit decorator without donate_argnums",
                        )


class R004NoProcessWideBackend(Rule):
    """Library code must not call process-wide ``set_default_backend``."""

    rule_id = "R004"
    title = "no-process-wide-backend"
    hint = (
        "use the scoped `with use_backend(...):` stack — "
        "set_default_backend mutates process-wide state and leaks across "
        "serving worker threads; it is for application entry points only"
    )

    # The definition site (and its package re-export) are not calls, so
    # they pass naturally; no file exemption needed.
    def applies(self, path: str) -> bool:
        p = _norm(path)
        return "repro/" in p and p.endswith(".py") and "tests/" not in p

    def check(self, tree: ast.AST, path: str, src: str) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            name = fn.id if isinstance(fn, ast.Name) else (
                fn.attr if isinstance(fn, ast.Attribute) else None
            )
            if name == "set_default_backend":
                yield self.finding(
                    path, node, "set_default_backend() call in library code"
                )


class R005SsdStateStaysF32(Rule):
    """Carried SSD-scan state must not be cast below float32."""

    rule_id = "R005"
    title = "ssd-state-stays-f32"
    hint = (
        "carry scan state as jnp.float32 end to end — a lower-precision "
        "cast compounds across chunks; if the value is not scan state, "
        "rename it so it does not look like one"
    )

    FILES = ("repro/kernels/mamba_scan.py", "repro/models/components.py")
    STATE_RE = re.compile(r"\b(ssm_state|state|h0|hf)\w*")
    F32_NAMES = frozenset({"jnp.float32", "np.float32", "float32"})

    def applies(self, path: str) -> bool:
        return _endswith(path, self.FILES)

    def _is_f32(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Constant) and node.value == "float32":
            return True
        return _dotted(node) in self.F32_NAMES

    def check(self, tree: ast.AST, path: str, src: str) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if not (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "astype"
                and node.args
            ):
                continue
            target = ast.get_source_segment(src, node.func.value) or ""
            if not self.STATE_RE.search(target):
                continue
            if not self._is_f32(node.args[0]):
                cast = ast.get_source_segment(src, node.args[0]) or "?"
                yield self.finding(
                    path,
                    node,
                    f"scan state `{target}` cast to {cast} (must stay f32)",
                )


class R006NoRawLayoutKwargs(Rule):
    """Serving library code must take ``CacheConfig``, not raw layout
    kwargs."""

    rule_id = "R006"
    title = "no-raw-layout-kwargs"
    hint = (
        "accept cache: CacheConfig (repro.serving.config) instead of "
        "re-introducing layout/page_size/n_pages/snapshots/host_spill "
        "parameters — the typed config is the one construction surface; "
        "pager.py (the layout implementation) and config.py itself are "
        "out of scope"
    )

    # config.py defines the fields; pager.py implements the paged layout
    # (its functions legitimately take page_size etc.)
    EXEMPT = ("repro/serving/config.py", "repro/serving/pager.py")
    #: a bare ``layout=`` parameter is damning on its own; the sizing
    #: knobs only flag in combination (a lone ``page_size`` argument on
    #: a helper is legitimate — a pile of them is a config bypass)
    PILE = frozenset({"page_size", "n_pages", "snapshots", "host_spill"})

    def applies(self, path: str) -> bool:
        p = _norm(path)
        return (
            "repro/serving/" in p
            and p.endswith(".py")
            and not _endswith(p, self.EXEMPT)
        )

    def check(self, tree: ast.AST, path: str, src: str) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            args = node.args
            names = {
                a.arg
                for a in (args.posonlyargs + args.args + args.kwonlyargs)
            }
            if "layout" in names:
                yield self.finding(
                    path,
                    node,
                    f"function {node.name}() takes a raw layout= parameter "
                    "(bypasses CacheConfig)",
                )
                continue
            pile = sorted(names & self.PILE)
            if len(pile) >= 2:
                yield self.finding(
                    path,
                    node,
                    f"function {node.name}() re-grows the layout kwarg "
                    f"pile {pile} (bypasses CacheConfig)",
                )


class R007KvScaleStaysF32(Rule):
    """Quantized-KV per-page scale pools must not be cast below float32."""

    rule_id = "R007"
    title = "kv-scale-stays-f32"
    hint = (
        "per-(page, head) quantization scales (ksc/vsc, the host tier "
        "hksc/hvsc, and kv_scales tuples derived from them) are the "
        "error budget of the int8 KV path — only the payload is int8; "
        "a sub-f32 scale compounds through every dequantized read, so "
        "keep the pools f32 end to end (and keep attention accumulation "
        "f32 inside the kernels)"
    )

    FILES = (
        "repro/kernels/flash_attention.py",
        "repro/serving/pager.py",
        "repro/models/lm.py",
    )
    SCALE_RE = re.compile(r"\b(h?ksc|h?vsc|k_scales?|v_scales?|kv_scales?)\w*")
    F32_NAMES = frozenset({"jnp.float32", "np.float32", "float32"})

    def applies(self, path: str) -> bool:
        return _endswith(path, self.FILES)

    def _is_f32(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Constant) and node.value == "float32":
            return True
        return _dotted(node) in self.F32_NAMES

    def check(self, tree: ast.AST, path: str, src: str) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if not (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "astype"
                and node.args
            ):
                continue
            target = ast.get_source_segment(src, node.func.value) or ""
            if not self.SCALE_RE.search(target):
                continue
            if not self._is_f32(node.args[0]):
                cast = ast.get_source_segment(src, node.args[0]) or "?"
                yield self.finding(
                    path,
                    node,
                    f"KV quantization scale `{target}` cast to {cast} "
                    "(must stay f32)",
                )


ALL_RULES: Tuple[Rule, ...] = (
    R001DirectTpuImport(),
    R002ImplicitHostSync(),
    R003JitMustDonate(),
    R004NoProcessWideBackend(),
    R005SsdStateStaysF32(),
    R006NoRawLayoutKwargs(),
    R007KvScaleStaysF32(),
)
