"""Runtime auditors for the serving engine's jit/transfer discipline.

``jit_cache_audit(engine)`` proves the "jit cache size stays 1" standing
note over a real workload; ``no_transfer_audit()`` proves the scheduler
never syncs device→host outside the sanctioned ``steps_per_sync``
harvest.  Both are context managers so tests and benchmarks can wrap an
unmodified engine run.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Iterator, Optional, Sequence

import jax


class JitCacheRetrace(AssertionError):
    """A jitted engine entry point retraced (cache size grew past 1)."""


#: Engine attributes wrapped by default — every jitted entry point
#: (``_prefill`` only exists with chunked prefill; ``_spill``/``_restore``
#: only on two-tier-pager engines; ``_spec_n`` only with speculative
#: decoding — absent/None attributes are skipped).
ENGINE_JIT_FNS = ("_step_n", "_spec_n", "_admit", "_prefill", "_release",
                  "_spill", "_restore")


class JitCacheReport:
    """Observed jit-cache sizes per wrapped function.

    ``growth(name)`` is the number of cache entries added *inside* the
    audited region (1 == the single expected compilation, or 0 if the
    function was already warm); ``max_sizes`` keeps the absolute size.
    """

    def __init__(self) -> None:
        self.starts: Dict[str, int] = {}
        self.max_sizes: Dict[str, int] = {}
        self.calls: Dict[str, int] = {}

    def record(self, name: str, size: int, start: int) -> None:
        self.starts[name] = start
        self.calls[name] = self.calls.get(name, 0) + 1
        self.max_sizes[name] = max(self.max_sizes.get(name, 0), size)

    def growth(self, name: str) -> int:
        return self.max_sizes[name] - self.starts[name]

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"JitCacheReport(starts={self.starts}, "
            f"max_sizes={self.max_sizes}, calls={self.calls})"
        )


@contextlib.contextmanager
def jit_cache_audit(
    engine,
    fn_names: Sequence[str] = ENGINE_JIT_FNS,
    max_cache_size: int = 1,
) -> Iterator[JitCacheReport]:
    """Assert the engine's jitted entry points never retrace.

    Wraps each ``fn_names`` attribute of ``engine`` (skipping absent
    ones) so that after every call the function's jit-cache *growth
    since the audit began* is checked against ``max_cache_size`` — a
    violation raises :class:`JitCacheRetrace` at the offending call,
    naming the function, instead of silently re-compiling (and, in a
    benchmark, reporting bogus tok/s).  Growth is measured against a
    baseline taken at wrap time because jax shares a jit cache between
    wrappers of the same underlying callable — a re-used engine (or one
    sharing a closure with a previous audit) may start with that cache
    warm; the invariant is "this workload compiled each entry point at
    most once", not an absolute cache size.  Yields a
    :class:`JitCacheReport`; originals are restored on exit.
    """
    report = JitCacheReport()
    saved = {}

    def _wrap(name: str, fn):
        start = fn._cache_size()

        def checked(*args, **kwargs):
            out = fn(*args, **kwargs)
            size = fn._cache_size()
            report.record(name, size, start)
            if size - start > max_cache_size:
                raise JitCacheRetrace(
                    f"{name} retraced: jit cache grew {size - start} > "
                    f"{max_cache_size} entries (size {start} -> {size}) "
                    f"over {report.calls[name]} call(s) — an argument "
                    "changed shape/dtype or a static arg varied"
                )
            return out

        return checked

    for name in fn_names:
        fn = getattr(engine, name, None)
        if fn is None:
            continue
        if not hasattr(fn, "_cache_size"):
            raise TypeError(
                f"{name} has no _cache_size(); is it a jax.jit function?"
            )
        saved[name] = fn
        setattr(engine, name, _wrap(name, fn))
    if not saved:
        raise ValueError(f"engine has none of {tuple(fn_names)} to audit")
    try:
        yield report
    finally:
        for name, fn in saved.items():
            setattr(engine, name, fn)


@contextlib.contextmanager
def no_transfer_audit() -> Iterator[None]:
    """Disallow *implicit* transfers inside the block.

    Arms ``jax.transfer_guard("disallow")``: any implicit sync —
    ``int()``/``float()``/``bool()`` on a device array, ``.item()``,
    ``np.asarray`` on a device value, or a host value smuggled into a
    jitted call — raises immediately, while *explicit* transfers (the
    engine's sanctioned ``jax.device_get`` harvest, ``jnp.asarray``
    uploads in ``_refill``) stay legal.  The full guard rather than the
    device→host one because on CPU backends device→host reads are
    zero-copy and never guarded — the host→device side is what actually
    trips when scheduler code touches device values implicitly.
    Wrapping ``ServingEngine.run()`` in this proves the "no device syncs
    for step choice" claim between harvest syncs.
    """
    with jax.transfer_guard("disallow"):
        yield
