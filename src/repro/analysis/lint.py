"""Driver for the repro invariant linter.

``lint_source`` lints one source string under a (possibly virtual) path —
used both for real files and for the known-bad fixtures in
``tests/fixtures/lint/`` which are linted *as if* they lived at the
canonical path their rule is scoped to.  ``lint_paths`` walks directories.

Suppression: a finding on line L is dropped when line L, or a
comment-only line L-1, carries ``# repro-lint: disable=RXXX`` (several
IDs comma-separated, or ``all``).
"""
from __future__ import annotations

import ast
import os
import re
from pathlib import Path
from typing import Iterable, List, Optional, Sequence

from repro.analysis.rules import ALL_RULES, Finding, Rule

_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Za-z0-9_,\s]+)")


def _suppressed_rules(line: str) -> frozenset:
    m = _SUPPRESS_RE.search(line)
    if not m:
        return frozenset()
    return frozenset(tok.strip() for tok in m.group(1).split(",") if tok.strip())


def _is_suppressed(finding: Finding, lines: Sequence[str]) -> bool:
    idx = finding.line - 1
    candidates = []
    if 0 <= idx < len(lines):
        candidates.append(lines[idx])
    if idx - 1 >= 0 and lines[idx - 1].lstrip().startswith("#"):
        candidates.append(lines[idx - 1])
    for line in candidates:
        ids = _suppressed_rules(line)
        if finding.rule in ids or "all" in ids:
            return True
    return False


def lint_source(
    src: str,
    path: str,
    rules: Optional[Sequence[Rule]] = None,
) -> List[Finding]:
    """Lint ``src`` as if it lived at ``path``; returns surviving findings."""
    rules = ALL_RULES if rules is None else rules
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as exc:
        return [
            Finding(
                rule="E000",
                path=path,
                line=exc.lineno or 1,
                col=(exc.offset or 0) + 1,
                message=f"syntax error: {exc.msg}",
            )
        ]
    lines = src.splitlines()
    out: List[Finding] = []
    for rule in rules:
        if not rule.applies(path):
            continue
        for finding in rule.check(tree, path, src):
            if not _is_suppressed(finding, lines):
                out.append(finding)
    out.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return out


def lint_file(
    file_path: str | Path,
    root: Optional[str | Path] = None,
    rules: Optional[Sequence[Rule]] = None,
) -> List[Finding]:
    """Lint one file; paths in findings are relative to ``root`` if given."""
    fp = Path(file_path)
    shown = fp
    if root is not None:
        try:
            shown = fp.resolve().relative_to(Path(root).resolve())
        except ValueError:
            shown = fp
    return lint_source(
        fp.read_text(encoding="utf-8"), shown.as_posix(), rules=rules
    )


def lint_paths(
    paths: Iterable[str | Path],
    root: Optional[str | Path] = None,
    rules: Optional[Sequence[Rule]] = None,
) -> List[Finding]:
    """Lint files and/or directory trees (``*.py``, sorted, deduped)."""
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        else:
            files.append(p)
    seen = set()
    out: List[Finding] = []
    for fp in files:
        key = os.path.realpath(fp)
        if key in seen:
            continue
        seen.add(key)
        out.extend(lint_file(fp, root=root, rules=rules))
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    """CLI body shared with ``scripts/lint.py``: AST rules + coverage lint."""
    import argparse

    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="repro invariant linter (R001-R005) + op coverage lint",
    )
    ap.add_argument(
        "paths",
        nargs="*",
        help="files/dirs to lint (default: src/repro relative to repo root)",
    )
    ap.add_argument(
        "--no-coverage",
        action="store_true",
        help="skip the op-registry coverage lint (no jax import needed)",
    )
    args = ap.parse_args(argv)

    repo_root = Path(__file__).resolve().parents[3]
    paths = [Path(p) for p in args.paths] or [repo_root / "src" / "repro"]
    findings = lint_paths(paths, root=repo_root)

    if not args.no_coverage:
        from repro.analysis.coverage import coverage_findings

        findings.extend(coverage_findings())

    for f in findings:
        print(f.format())
    n = len(findings)
    print(f"repro-lint: {n} finding{'s' if n != 1 else ''}")
    return 1 if findings else 0
