"""Deterministic synthetic data streams (offline container: no real MNIST/
CIFAR downloads).  Class-conditional image generators produce learnable
structure so the LeNet reproductions actually converge; the LM stream
produces a deterministic mixture of n-gram-ish token patterns.

All generators are keyed by (seed, step) — restartable from a checkpoint
step with no state, and shardable per host (each host materializes only its
slice), which is the fault-tolerance story for the input pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ImageStreamSpec:
    shape: Tuple[int, int, int]    # (C, H, W)
    num_classes: int
    batch_size: int
    seed: int = 0
    noise: float = 0.35


def _class_prototypes(spec: ImageStreamSpec) -> np.ndarray:
    """Smooth per-class prototype images (deterministic in seed)."""
    rng = np.random.default_rng(spec.seed)
    c, h, w = spec.shape
    protos = np.zeros((spec.num_classes, c, h, w), np.float32)
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    for cls in range(spec.num_classes):
        for ch in range(c):
            fx, fy = rng.uniform(0.5, 3.0, 2)
            px, py = rng.uniform(0, 2 * np.pi, 2)
            amp = rng.uniform(0.7, 1.3)
            protos[cls, ch] = amp * (
                np.sin(2 * np.pi * fx * xx / w + px)
                * np.cos(2 * np.pi * fy * yy / h + py)
            )
    return protos


class ImageStream:
    """Infinite class-conditional stream: batch(step) is pure in (seed, step)."""

    def __init__(self, spec: ImageStreamSpec):
        self.spec = spec
        self._protos = jnp.asarray(_class_prototypes(spec))

    def batch(self, step: int, batch_size: Optional[int] = None):
        bs = batch_size or self.spec.batch_size
        key = jax.random.fold_in(jax.random.PRNGKey(self.spec.seed), step)
        k1, k2 = jax.random.split(key)
        labels = jax.random.randint(k1, (bs,), 0, self.spec.num_classes)
        noise = self.spec.noise * jax.random.normal(
            k2, (bs, *self.spec.shape), jnp.float32
        )
        data = self._protos[labels] + noise
        return data, labels

    def __iter__(self) -> Iterator:
        step = 0
        while True:
            yield self.batch(step)
            step += 1

    def eval_iter(self, offset: int = 10_000) -> Iterator:
        step = offset
        while True:
            yield self.batch(step)
            step += 1


def mnist_like(batch_size: int, seed: int = 0) -> ImageStream:
    return ImageStream(ImageStreamSpec((1, 28, 28), 10, batch_size, seed))


def cifar10_like(batch_size: int, seed: int = 0) -> ImageStream:
    return ImageStream(ImageStreamSpec((3, 32, 32), 10, batch_size, seed))


@dataclasses.dataclass(frozen=True)
class TokenStreamSpec:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0


class TokenStream:
    """Deterministic LM token stream with learnable bigram structure."""

    def __init__(self, spec: TokenStreamSpec):
        self.spec = spec
        rng = np.random.default_rng(spec.seed)
        v = min(spec.vocab_size, 512)
        # sparse deterministic successor table over a reduced alphabet
        self._succ = jnp.asarray(rng.integers(0, v, size=(v,)), jnp.int32)
        self._v = v

    def batch(self, step: int, host_id: int = 0, num_hosts: int = 1):
        spec = self.spec
        bs = spec.batch_size // num_hosts
        key = jax.random.fold_in(
            jax.random.PRNGKey(spec.seed), step * num_hosts + host_id
        )
        k1, k2 = jax.random.split(key)
        start = jax.random.randint(k1, (bs, 1), 0, self._v)

        def body(tok, _):
            nxt = self._succ[tok[:, 0]][:, None]
            return nxt, nxt

        _, toks = jax.lax.scan(body, start, None, length=spec.seq_len)
        toks = jnp.swapaxes(toks[:, :, 0], 0, 1)      # (bs, seq)
        # inject noise tokens so the task isn't trivially deterministic
        noise = jax.random.bernoulli(k2, 0.1, toks.shape)
        rand_tok = jax.random.randint(k2, toks.shape, 0, self._v)
        toks = jnp.where(noise, rand_tok, toks)
        inputs = toks[:, :-1]
        targets = toks[:, 1:]
        return inputs, targets

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
