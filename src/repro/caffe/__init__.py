# The paper's case study: the Caffe subset ported to the portability core.
from repro.caffe.lenet import (
    lenet_cifar10,
    lenet_cifar10_solver,
    lenet_mnist,
    lenet_mnist_solver,
)
from repro.caffe.net import Net
from repro.caffe.solver import Solver
from repro.caffe.spec import LayerSpec, NetSpec, SolverSpec
