"""The paper's two evaluation networks, as NetSpecs.

1. LeNet for MNIST — Caffe's ``lenet_train_test.prototxt``: 6 layers
   (2 Convolution, 2 Pooling, 2 InnerProduct) + ReLU + SoftmaxWithLoss +
   Accuracy.
2. CIFAR-10 quick — Caffe's ``cifar10_quick_train_test.prototxt``: 8 layers
   (3 Convolution, 3 Pooling, 2 InnerProduct) + ReLUs + SoftmaxWithLoss +
   Accuracy, with overlapping 3/2 pools (max + 2 average).
"""
from __future__ import annotations

from repro.caffe.spec import LayerSpec, NetSpec, SolverSpec


def L(name, type, bottoms, tops, **kw):
    return LayerSpec(
        name=name, type=type, bottoms=tuple(bottoms), tops=tuple(tops), **kw
    )


def lenet_mnist() -> NetSpec:
    return NetSpec(
        name="lenet-mnist",
        input_shape=(1, 28, 28),
        num_classes=10,
        layers=(
            L("conv1", "Convolution", ["data"], ["conv1"],
              num_output=20, kernel_size=5, stride=1),
            L("pool1", "Pooling", ["conv1"], ["pool1"],
              kernel_size=2, stride=2, pool="max"),
            L("conv2", "Convolution", ["pool1"], ["conv2"],
              num_output=50, kernel_size=5, stride=1),
            L("pool2", "Pooling", ["conv2"], ["pool2"],
              kernel_size=2, stride=2, pool="max"),
            L("ip1", "InnerProduct", ["pool2"], ["ip1"], num_output=500),
            L("relu1", "ReLU", ["ip1"], ["ip1r"]),
            L("ip2", "InnerProduct", ["ip1r"], ["ip2"], num_output=10),
            L("loss", "SoftmaxWithLoss", ["ip2", "label"], ["loss"]),
            L("accuracy", "Accuracy", ["ip2", "label"], ["accuracy"]),
        ),
    )


def lenet_cifar10() -> NetSpec:
    return NetSpec(
        name="lenet-cifar10",
        input_shape=(3, 32, 32),
        num_classes=10,
        layers=(
            L("conv1", "Convolution", ["data"], ["conv1"],
              num_output=32, kernel_size=5, pad=2, weight_filler="gaussian",
              filler_std=1e-4),
            L("pool1", "Pooling", ["conv1"], ["pool1"],
              kernel_size=3, stride=2, pool="max"),
            L("relu1", "ReLU", ["pool1"], ["pool1r"]),
            L("conv2", "Convolution", ["pool1r"], ["conv2"],
              num_output=32, kernel_size=5, pad=2, weight_filler="gaussian",
              filler_std=0.01),
            L("relu2", "ReLU", ["conv2"], ["conv2r"]),
            L("pool2", "Pooling", ["conv2r"], ["pool2"],
              kernel_size=3, stride=2, pool="ave"),
            L("conv3", "Convolution", ["pool2"], ["conv3"],
              num_output=64, kernel_size=5, pad=2, weight_filler="gaussian",
              filler_std=0.01),
            L("relu3", "ReLU", ["conv3"], ["conv3r"]),
            L("pool3", "Pooling", ["conv3r"], ["pool3"],
              kernel_size=3, stride=2, pool="ave"),
            L("ip1", "InnerProduct", ["pool3"], ["ip1"], num_output=64,
              weight_filler="gaussian", filler_std=0.1),
            L("ip2", "InnerProduct", ["ip1"], ["ip2"], num_output=10,
              weight_filler="gaussian", filler_std=0.1),
            L("loss", "SoftmaxWithLoss", ["ip2", "label"], ["loss"]),
            L("accuracy", "Accuracy", ["ip2", "label"], ["accuracy"]),
        ),
    )


def lenet_mnist_solver(**overrides) -> SolverSpec:
    cfg = dict(
        base_lr=0.01, momentum=0.9, weight_decay=5e-4,
        lr_policy="inv", gamma=1e-4, power=0.75,
        max_iter=500, batch_size=64,
    )
    cfg.update(overrides)
    return SolverSpec(**cfg)


def lenet_cifar10_solver(**overrides) -> SolverSpec:
    cfg = dict(
        base_lr=0.001, momentum=0.9, weight_decay=4e-3,
        lr_policy="fixed", max_iter=500, batch_size=64,
    )
    cfg.update(overrides)
    return SolverSpec(**cfg)
