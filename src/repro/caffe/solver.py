"""Solver — Caffe's SGD(+momentum) training driver.

Caffe semantics: ``v = momentum*v + lr*(grad + weight_decay*w); w -= v``
with the ``inv`` learning-rate policy of the shipped LeNet solver.  The
train step is jit-compiled end-to-end; gradients come from jax.grad through
the portable ops (whose Pallas paths carry custom VJPs).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.caffe.net import Net
from repro.caffe.spec import SolverSpec


class Solver:
    def __init__(self, net: Net, spec: SolverSpec):
        self.net = net
        self.spec = spec

    def init(self, rng):
        params = self.net.init(rng, self.spec.batch_size)
        velocity = jax.tree.map(jnp.zeros_like, params)
        return {"params": params, "velocity": velocity, "iter": jnp.zeros((), jnp.int32)}

    def make_train_step(self) -> Callable:
        net, spec = self.net, self.spec

        def train_step(state, data, label):
            params, velocity, it = state["params"], state["velocity"], state["iter"]
            loss, grads = jax.value_and_grad(net.forward_loss)(params, data, label)
            lr = spec.learning_rate(it.astype(jnp.float32))

            def upd(w, v, g):
                v_new = spec.momentum * v + lr * (g + spec.weight_decay * w)
                return w - v_new, v_new

            flat_p, treedef = jax.tree.flatten(params)
            flat_v = jax.tree.leaves(velocity)
            flat_g = jax.tree.leaves(grads)
            new_p, new_v = [], []
            for w, v, g in zip(flat_p, flat_v, flat_g):
                wn, vn = upd(w, v, g)
                new_p.append(wn)
                new_v.append(vn)
            return {
                "params": jax.tree.unflatten(treedef, new_p),
                "velocity": jax.tree.unflatten(treedef, new_v),
                "iter": it + 1,
            }, loss

        # the paper's partial-port mode forces host round-trips -> cannot jit
        if net.boundary is None:
            return jax.jit(train_step)
        return train_step

    def make_eval_step(self) -> Callable:
        net = self.net

        def eval_step(params, data, label):
            return net.metrics(params, data, label)

        return jax.jit(eval_step) if net.boundary is None else eval_step

    def solve(
        self,
        rng,
        train_iter: Iterator[Tuple[jax.Array, jax.Array]],
        test_iter: Optional[Callable[[], Iterator]] = None,
        log: Optional[Callable[[str], None]] = None,
    ):
        state = self.init(rng)
        train_step = self.make_train_step()
        eval_step = self.make_eval_step()
        history = {"loss": [], "test_acc": []}
        for it in range(self.spec.max_iter):
            data, label = next(train_iter)
            state, loss = train_step(state, data, label)
            history["loss"].append(float(loss))
            if test_iter and (it + 1) % self.spec.test_interval == 0:
                accs, losses = [], []
                for bi, (d, l) in enumerate(test_iter()):
                    if bi >= self.spec.test_batches:
                        break
                    m = eval_step(state["params"], d, l)
                    accs.append(float(m.get("accuracy", 0.0)))
                    losses.append(float(m.get("loss", 0.0)))
                acc = sum(accs) / max(len(accs), 1)
                history["test_acc"].append((it + 1, acc))
                if log:
                    log(
                        f"iter {it+1}: loss={float(loss):.4f} "
                        f"test_acc={acc:.4f}"
                    )
        return state, history
