"""Prototxt-like network/solver specs (Caffe's .prototxt, as dataclasses).

A ``NetSpec`` is an ordered list of ``LayerSpec``s wired by named blobs —
the same containers/executors split the paper describes (Fig. 1): blobs are
containers, layers are executors.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    name: str
    type: str                      # Convolution | InnerProduct | Pooling | ...
    bottoms: Tuple[str, ...]
    tops: Tuple[str, ...]
    # Convolution / Pooling
    num_output: int = 0
    kernel_size: int = 0
    stride: int = 1
    pad: int = 0
    pool: str = "max"              # max | ave
    # ReLU
    negative_slope: float = 0.0
    # InnerProduct
    transpose: bool = False
    bias_term: bool = True
    # Loss
    loss_weight: float = 1.0
    # Accuracy
    top_k: int = 1
    # init
    weight_filler: str = "xavier"  # xavier | gaussian
    filler_std: float = 0.01

    def replace(self, **kw) -> "LayerSpec":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class NetSpec:
    name: str
    input_shape: Tuple[int, ...]   # per-example shape (C, H, W) or (D,)
    num_classes: int
    layers: Tuple[LayerSpec, ...]

    def layer(self, name: str) -> LayerSpec:
        for l in self.layers:
            if l.name == name:
                return l
        raise KeyError(name)


@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """Caffe's solver.prototxt: SGD with momentum + inv LR policy."""

    base_lr: float = 0.01
    momentum: float = 0.9
    weight_decay: float = 5e-4
    lr_policy: str = "inv"         # inv | fixed | step
    gamma: float = 1e-4
    power: float = 0.75
    step_size: int = 1000
    max_iter: int = 1000
    batch_size: int = 64
    test_interval: int = 100
    test_batches: int = 4
    seed: int = 0

    def learning_rate(self, it):
        import jax.numpy as jnp

        if self.lr_policy == "fixed":
            return jnp.asarray(self.base_lr, jnp.float32)
        if self.lr_policy == "inv":
            return self.base_lr * (1.0 + self.gamma * it) ** (-self.power)
        if self.lr_policy == "step":
            return self.base_lr * self.gamma ** (it // self.step_size)
        raise ValueError(self.lr_policy)
