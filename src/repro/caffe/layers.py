"""The ported Caffe blocks, as functional executors over portable ops.

Each layer implements Caffe's triple interface:

    init(rng, bottom_shapes)            -> (params, top_shapes)
    forward(params, bottoms, train)     -> (tops, cache)
    backward(params, cache, top_diffs)  -> (bottom_diffs, param_diffs)

``forward`` is built exclusively from ``repro.kernels.ops`` so the whole
net is single-source across backends (the paper's core claim), and is
autodiff-able (the solver uses jax.grad).  ``backward`` is the explicit
Caffe-style backprop — kept both for fidelity to the paper's porting of
back-propagation and as an independent oracle the tests compare against
autodiff (our Table-1 analogue).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.caffe.spec import LayerSpec
from repro.kernels import ops, ref


Params = Dict[str, jax.Array]


def _filler(rng, shape, spec: LayerSpec, fan_in: int, fan_out: int):
    if spec.weight_filler == "xavier":
        scale = np.sqrt(3.0 / fan_in)
        return jax.random.uniform(rng, shape, jnp.float32, -scale, scale)
    return spec.filler_std * jax.random.normal(rng, shape, jnp.float32)


class Layer:
    def __init__(self, spec: LayerSpec):
        self.spec = spec

    @property
    def name(self) -> str:
        return self.spec.name

    def init(self, rng, bottom_shapes):
        return {}, self.infer_shapes(bottom_shapes)

    def infer_shapes(self, bottom_shapes):
        raise NotImplementedError

    def forward(self, params, bottoms, train: bool):
        raise NotImplementedError

    def backward(self, params, cache, top_diffs):
        raise NotImplementedError


class Convolution(Layer):
    """im2col + GEMM convolution (the paper's §3.1)."""

    def infer_shapes(self, bottom_shapes):
        (n, c, h, w), = bottom_shapes
        s = self.spec
        oh = ref.conv_out_size(h, s.kernel_size, s.stride, s.pad)
        ow = ref.conv_out_size(w, s.kernel_size, s.stride, s.pad)
        return [(n, s.num_output, oh, ow)]

    def init(self, rng, bottom_shapes):
        (n, c, h, w), = bottom_shapes
        s = self.spec
        k = s.kernel_size
        r1, r2 = jax.random.split(rng)
        fan_in = c * k * k
        params = {"w": _filler(r1, (s.num_output, c, k, k), s, fan_in, s.num_output)}
        if s.bias_term:
            params["b"] = jnp.zeros((s.num_output,), jnp.float32)
        return params, self.infer_shapes(bottom_shapes)

    def forward(self, params, bottoms, train: bool):
        (x,) = bottoms
        s = self.spec
        y = ops.conv2d(
            x, params["w"], params.get("b"), stride=s.stride, pad=s.pad
        )
        return [y], {"x": x}

    def backward(self, params, cache, top_diffs):
        (dy,) = top_diffs
        s = self.spec
        x, w = cache["x"], params["w"]
        f, c, kh, kw = w.shape
        n = x.shape[0]
        oh, ow = dy.shape[2], dy.shape[3]
        cols = ops.im2col(x, kh, kw, s.stride, s.pad)
        dy_flat = dy.reshape(n, f, oh * ow).transpose(1, 0, 2).reshape(f, -1)
        cols_flat = cols.transpose(1, 0, 2).reshape(c * kh * kw, -1)
        dw = ops.matmul(dy_flat, cols_flat.T).reshape(w.shape)
        dcols = ops.matmul(w.reshape(f, -1).T, dy_flat)
        dcols = dcols.reshape(c * kh * kw, n, oh * ow).transpose(1, 0, 2)
        dx = ops.col2im(dcols, x.shape, kh, kw, s.stride, s.pad)
        grads = {"w": dw}
        if s.bias_term:
            grads["b"] = dy.sum(axis=(0, 2, 3))
        return [dx], grads


class InnerProduct(Layer):
    """GEMM + matrixPlusVectorRows (the paper's Listing 1.2)."""

    def infer_shapes(self, bottom_shapes):
        shp = bottom_shapes[0]
        n = shp[0]
        return [(n, self.spec.num_output)]

    def init(self, rng, bottom_shapes):
        shp = bottom_shapes[0]
        k = int(np.prod(shp[1:]))
        s = self.spec
        r1, r2 = jax.random.split(rng)
        params = {"w": _filler(r1, (k, s.num_output), s, k, s.num_output)}
        if s.bias_term:
            params["b"] = jnp.zeros((s.num_output,), jnp.float32)
        return params, self.infer_shapes(bottom_shapes)

    def forward(self, params, bottoms, train: bool):
        (x,) = bottoms
        n = x.shape[0]
        x2 = x.reshape(n, -1)
        y = ops.matmul(x2, params["w"])
        if self.spec.bias_term:
            y = ops.bias_add_rows(y, params["b"])
        return [y], {"x": x}

    def backward(self, params, cache, top_diffs):
        (dy,) = top_diffs
        x = cache["x"]
        n = x.shape[0]
        x2 = x.reshape(n, -1)
        dw = ops.matmul(x2.T, dy)
        dx = ops.matmul(dy, params["w"].T).reshape(x.shape)
        grads = {"w": dw}
        if self.spec.bias_term:
            grads["b"] = dy.sum(axis=0)
        return [dx], grads


class Pooling(Layer):
    def infer_shapes(self, bottom_shapes):
        (n, c, h, w), = bottom_shapes
        s = self.spec
        oh = ref.conv_out_size(h, s.kernel_size, s.stride, s.pad)
        ow = ref.conv_out_size(w, s.kernel_size, s.stride, s.pad)
        return [(n, c, oh, ow)]

    def forward(self, params, bottoms, train: bool):
        (x,) = bottoms
        s = self.spec
        if s.pool == "max":
            # single pool evaluation yields both the output and the argmax
            # (Caffe stores the mapping for the explicit backward)
            y, arg = ops.maxpool_with_argmax(x, s.kernel_size, s.stride, s.pad)
            return [y], {"arg": arg, "x_shape": x.shape}
        y = ops.avgpool(x, s.kernel_size, s.stride, s.pad)
        return [y], {"x_shape": x.shape}

    def backward(self, params, cache, top_diffs):
        (dy,) = top_diffs
        s = self.spec
        if s.pool == "max":
            dx = ref.maxpool_bwd(
                dy, cache["arg"], cache["x_shape"], s.kernel_size, s.stride, s.pad
            )
            return [dx], {}
        # average pool: spread gradient uniformly
        n, c, h, w = cache["x_shape"]
        k, st, pad = s.kernel_size, s.stride, s.pad
        dyk = dy / (k * k)
        dcols = jnp.broadcast_to(
            dyk.reshape(n, c, 1, -1), (n, c, k * k, dy.shape[2] * dy.shape[3])
        ).reshape(n, c * k * k, -1)
        dx = ref.col2im(dcols, cache["x_shape"], k, k, st, pad)
        return [dx], {}


class ReLU(Layer):
    """Caffe implements the leaky variant (paper §3, block list)."""

    def infer_shapes(self, bottom_shapes):
        return [bottom_shapes[0]]

    def forward(self, params, bottoms, train: bool):
        (x,) = bottoms
        return [ops.relu(x, self.spec.negative_slope)], {"x": x}

    def backward(self, params, cache, top_diffs):
        (dy,) = top_diffs
        return [ref.relu_bwd(cache["x"], dy, self.spec.negative_slope)], {}


class Softmax(Layer):
    def infer_shapes(self, bottom_shapes):
        return [bottom_shapes[0]]

    def forward(self, params, bottoms, train: bool):
        (x,) = bottoms
        p = ops.softmax(x)
        return [p], {"p": p}

    def backward(self, params, cache, top_diffs):
        (dy,) = top_diffs
        p = cache["p"]
        dx = p * (dy - jnp.sum(dy * p, axis=-1, keepdims=True))
        return [dx], {}


class SoftmaxWithLoss(Layer):
    def infer_shapes(self, bottom_shapes):
        return [()]

    def forward(self, params, bottoms, train: bool):
        logits, labels = bottoms
        loss = ops.softmax_xent_loss(logits, labels) * self.spec.loss_weight
        probs = ref.softmax(logits)
        return [loss], {"probs": probs, "labels": labels}

    def backward(self, params, cache, top_diffs):
        (dloss,) = top_diffs  # scalar
        dlogits = (
            ref.softmax_xent_bwd(cache["probs"], cache["labels"])
            * self.spec.loss_weight
            * dloss
        )
        return [dlogits, None], {}


class Accuracy(Layer):
    """Not a real layer (paper: 'implicitly included'); metric only."""

    def infer_shapes(self, bottom_shapes):
        return [()]

    def forward(self, params, bottoms, train: bool):
        logits, labels = bottoms
        return [ops.accuracy(logits, labels, self.spec.top_k)], {}

    def backward(self, params, cache, top_diffs):
        return [None, None], {}


LAYER_TYPES = {
    "Convolution": Convolution,
    "InnerProduct": InnerProduct,
    "Pooling": Pooling,
    "ReLU": ReLU,
    "Softmax": Softmax,
    "SoftmaxWithLoss": SoftmaxWithLoss,
    "Accuracy": Accuracy,
}


def build_layer(spec: LayerSpec) -> Layer:
    try:
        return LAYER_TYPES[spec.type](spec)
    except KeyError as e:
        raise KeyError(
            f"unknown layer type {spec.type!r}; known: {sorted(LAYER_TYPES)}"
        ) from e
