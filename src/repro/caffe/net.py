"""Net — Caffe's network graph executor over named blobs.

Forward walks the layer list feeding named blobs (containers) through
executors; ``forward_loss`` is the autodiff entry the solver differentiates;
``backward_manual`` is Caffe's explicit reverse pass over layer.backward
(used as the independent gradient oracle in tests).

The ``boundary`` hook reproduces the paper's §4.3 pathology for the
benchmarks: when set, every inter-layer blob crossing pays (a) a host
round-trip (device_get/put) and optionally (b) a row↔column major layout
conversion — the "unnecessary transfers + transpose per crossing" the paper
identifies as the dominant overhead of a partial port.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.caffe.layers import Layer, build_layer
from repro.caffe.spec import NetSpec
from repro.core.container import MajorOrder, as_layout


class Net:
    def __init__(self, spec: NetSpec, boundary: Optional[str] = None):
        """boundary: None | 'transfer' | 'transfer+transpose' (paper §4.3)."""
        self.spec = spec
        self.layers: List[Layer] = [build_layer(ls) for ls in spec.layers]
        self.boundary = boundary

    # -- init ---------------------------------------------------------------
    def init(self, rng, batch_size: int):
        shapes: Dict[str, Tuple[int, ...]] = {
            "data": (batch_size, *self.spec.input_shape),
            "label": (batch_size,),
        }
        params: Dict[str, dict] = {}
        rngs = jax.random.split(rng, len(self.layers))
        for layer, r in zip(self.layers, rngs):
            bshapes = [shapes[b] for b in layer.spec.bottoms]
            p, tshapes = layer.init(r, bshapes)
            if p:
                params[layer.name] = p
            for t, ts in zip(layer.spec.tops, tshapes):
                shapes[t] = ts
        self.blob_shapes = shapes
        return params

    # -- the paper's partial-port boundary crossing ---------------------------
    def _cross(self, x):
        if self.boundary is None or x is None or x.ndim == 0:
            return x
        if "transpose" in self.boundary and x.ndim >= 2:
            # row-major PHAST domain -> column-major OpenBLAS domain and back
            x = as_layout(x, MajorOrder.ROW, MajorOrder.COLUMN)
        # host round-trip (device -> orchestrating CPU -> device)
        x = jax.device_put(jax.device_get(x))
        return x

    # -- forward ------------------------------------------------------------
    def forward(self, params, data, label=None, train: bool = True):
        """Returns (blobs dict, caches dict)."""
        blobs: Dict[str, jax.Array] = {"data": data}
        if label is not None:
            blobs["label"] = label
        caches = {}
        for layer in self.layers:
            if any(b not in blobs for b in layer.spec.bottoms):
                continue  # e.g. loss layers at inference without labels
            bottoms = [self._cross(blobs[b]) for b in layer.spec.bottoms]
            tops, cache = layer.forward(
                params.get(layer.name, {}), bottoms, train
            )
            caches[layer.name] = cache
            for t, v in zip(layer.spec.tops, tops):
                blobs[t] = v
        return blobs, caches

    def forward_loss(self, params, data, label):
        """Scalar total loss (what the solver differentiates)."""
        blobs, _ = self.forward(params, data, label, train=True)
        loss = jnp.zeros((), jnp.float32)
        for layer in self.layers:
            if layer.spec.type == "SoftmaxWithLoss":
                loss = loss + blobs[layer.spec.tops[0]]
        return loss

    def metrics(self, params, data, label):
        blobs, _ = self.forward(params, data, label, train=False)
        out = {}
        for layer in self.layers:
            if layer.spec.type == "SoftmaxWithLoss":
                out["loss"] = blobs[layer.spec.tops[0]]
            if layer.spec.type == "Accuracy":
                out["accuracy"] = blobs[layer.spec.tops[0]]
        return out

    # -- Caffe-style explicit backward (gradient oracle for tests) -----------
    def backward_manual(self, params, data, label):
        blobs, caches = self.forward(params, data, label, train=True)
        diffs: Dict[str, jax.Array] = {}
        grads: Dict[str, dict] = {}
        for layer in reversed(self.layers):
            if layer.name not in caches:
                continue
            if layer.spec.type == "Accuracy":
                continue
            if layer.spec.type == "SoftmaxWithLoss":
                top_diffs = [jnp.ones((), jnp.float32)]
            else:
                top_diffs = [diffs.get(t) for t in layer.spec.tops]
                if all(d is None for d in top_diffs):
                    continue
                top_diffs = [
                    jnp.zeros(blobs[t].shape, blobs[t].dtype) if d is None else d
                    for d, t in zip(top_diffs, layer.spec.tops)
                ]
            bdiffs, pgrads = layer.backward(
                params.get(layer.name, {}), caches[layer.name], top_diffs
            )
            if pgrads:
                grads[layer.name] = pgrads
            for b, d in zip(layer.spec.bottoms, bdiffs):
                if d is None or b in ("data", "label"):
                    continue
                diffs[b] = diffs[b] + d if b in diffs else d
        return grads
