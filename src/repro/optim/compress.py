"""Gradient compression with error feedback — wire-format reduction for the
data-parallel all-reduce.

Two codecs:
  * bf16  — 2x reduction, no state beyond the error-feedback buffer.
  * int8  — 4x reduction, per-tensor symmetric scale.

Error feedback (Seide et al. / EF-SGD): the quantization residual is added
back into the next step's gradient, keeping SGD/Adam convergence.  Used by
the shard_map manual-DP training mode, where the psum really moves the
compressed payload; under GSPMD the codec still runs (correctness + tests)
but XLA owns the collective's wire type — recorded in DESIGN.md.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _encode_leaf(g: jax.Array, codec: str):
    g = g.astype(jnp.float32)
    if codec == "bf16":
        q = g.astype(jnp.bfloat16)
        return q, None
    if codec == "int8":
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        return q, scale
    raise ValueError(codec)


def _decode_leaf(q: jax.Array, scale, codec: str) -> jax.Array:
    if codec == "bf16":
        return q.astype(jnp.float32)
    return q.astype(jnp.float32) * scale


def compress(
    grads, ef, codec: str = "bf16"
) -> Tuple[Any, Any, Any]:
    """grads+ef -> (quantized payload, scales, new error feedback)."""
    if codec == "none":
        return grads, None, ef

    def enc(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = _encode_leaf(corrected, codec)
        deq = _decode_leaf(q, scale, codec)
        return q, scale, corrected - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    qs, scales, new_ef = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = enc(g, e)
        qs.append(q)
        scales.append(s)
        new_ef.append(ne)
    return (
        jax.tree.unflatten(treedef, qs),
        jax.tree.unflatten(treedef, scales) if codec == "int8" else None,
        jax.tree.unflatten(treedef, new_ef),
    )


def decompress(payload, scales, codec: str = "bf16"):
    if codec == "none":
        return payload
    if codec == "bf16":
        return jax.tree.map(lambda q: q.astype(jnp.float32), payload)
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, payload, scales
    )


def psum_compressed(grads, ef, axis_name: str, codec: str = "bf16"):
    """All-reduce mean of compressed gradients inside shard_map.

    int8 payloads are summed in int32 to avoid overflow across shards.
    Returns (reduced f32 grads, new error feedback).
    """
    payload, scales, new_ef = compress(grads, ef, codec)
    n = jax.lax.psum(1, axis_name)
    if codec == "none":
        red = jax.tree.map(
            lambda g: jax.lax.psum(g.astype(jnp.float32), axis_name) / n,
            payload,
        )
        return red, new_ef
    if codec == "bf16":
        red = jax.tree.map(
            lambda q: jax.lax.psum(q, axis_name).astype(jnp.float32) / n,
            payload,
        )
        return red, new_ef
    # int8: widen, sum, rescale with the max scale across shards
    def reduce_leaf(q, s):
        smax = jax.lax.pmax(s, axis_name)
        # renormalize local payload to the common scale before summing
        q32 = jnp.round(q.astype(jnp.float32) * (s / smax)).astype(jnp.int32)
        total = jax.lax.psum(q32, axis_name)
        return total.astype(jnp.float32) * smax / n

    red = jax.tree.map(reduce_leaf, payload, scales)
    return red, new_ef
