"""Optimizers — pure pytree transforms (no external deps).

Mixed-precision convention for LM training: compute/checkpoint params in
bf16; the optimizer holds f32 master weights + moments and re-casts after
each update (the usual large-scale recipe).  Caffe's solver uses the plain
SGD+momentum in ``repro.caffe.solver``; this module serves the LM stack.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # adamw | sgd
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    momentum: float = 0.9
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = [
        jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)
    ]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-6))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


def init_opt_state(cfg: OptConfig, params) -> Dict[str, Any]:
    f32 = lambda p: p.astype(jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(f32, params),
    }
    if cfg.name == "adamw":
        state["m"] = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        state["v"] = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    else:
        state["mom"] = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return state


def apply_updates(
    cfg: OptConfig, grads, opt_state, param_dtype
) -> Tuple[Any, Dict[str, Any]]:
    """Returns (new_params (cast to param_dtype), new_opt_state)."""
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    master = opt_state["master"]
    if cfg.name == "adamw":
        b1, b2 = cfg.b1, cfg.b2
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                         opt_state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                         opt_state["v"], grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(w, m_, v_):
            u = (m_ / c1) / (jnp.sqrt(v_ / c2) + cfg.eps)
            return w - lr * (u + cfg.weight_decay * w)

        new_master = jax.tree.map(upd, master, m, v)
        new_state = {"step": step, "master": new_master, "m": m, "v": v}
    else:
        mom = jax.tree.map(
            lambda v_, g, w: cfg.momentum * v_ + g + cfg.weight_decay * w,
            opt_state["mom"], grads, master,
        )
        new_master = jax.tree.map(lambda w, v_: w - lr * v_, master, mom)
        new_state = {"step": step, "master": new_master, "mom": mom}
    new_params = jax.tree.map(lambda w: w.astype(param_dtype), new_master)
    return new_params, new_state
