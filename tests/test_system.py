"""End-to-end system behaviour: the paper's single-source portability claim
at system level, op-registry coverage (Table-1 analogue), training loop
integration, and the launcher surface."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Backend, coverage, current_backend, dispatch, get_op, list_ops,
    set_default_backend, use_backend,
)
from repro.kernels import ops  # registers ops


def test_policy_resolution_order():
    # default on CPU: AUTO -> REFERENCE
    assert current_backend() is Backend.REFERENCE
    with use_backend("pallas"):
        assert current_backend() is Backend.PALLAS
        with use_backend(Backend.REFERENCE):
            assert current_backend() is Backend.REFERENCE
        assert current_backend() is Backend.PALLAS
    set_default_backend(Backend.PALLAS)
    try:
        assert current_backend() is Backend.PALLAS
    finally:
        set_default_backend(Backend.AUTO)


def test_registry_coverage_report():
    """Our Table 1: every required Caffe block's op has a Pallas lowering."""
    cov = coverage()
    required = ["matmul", "bias_add_rows", "relu", "im2col", "col2im",
                "conv2d", "maxpool", "softmax", "softmax_xent"]
    for name in required:
        assert cov[name], f"block {name} not ported"
    # LM hot-spots too (serving: decode is ssd_prefill_chunk's C=1 case)
    for name in ["attention", "attention_decode", "rmsnorm", "ssd_scan",
                 "attention_prefill_chunk", "ssd_prefill_chunk"]:
        assert cov[name], name


def test_dispatch_switches_implementation():
    e = get_op("matmul")
    assert e.resolve(Backend.REFERENCE) is not e.resolve(Backend.PALLAS)
    with use_backend("reference"):
        assert dispatch("matmul") is e.reference
    with use_backend("pallas"):
        assert dispatch("matmul") is e.pallas


def test_unknown_op_and_duplicate_registration():
    from repro.core import register_op

    with pytest.raises(KeyError):
        get_op("nonexistent-op")
    with pytest.raises(ValueError):
        register_op("matmul", reference=lambda: None)


def test_train_driver_end_to_end(tmp_path):
    """The launcher trains, checkpoints, survives an injected fault, and
    resumes — in one subprocess invocation each."""
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "qwen2.5-3b-smoke", "--steps", "12", "--batch", "4",
        "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
        "--fail-at", "8", "--log-every", "4",
    ]
    out = subprocess.run(
        cmd, capture_output=True, text=True, timeout=600, env=env,
        cwd="/root/repo",
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "recovered from 1 failure(s)" in out.stdout
    assert "done at step 12" in out.stdout
    # resume from the checkpoint dir
    cmd2 = [c for c in cmd if c not in ("--fail-at", "8")] + ["--resume"]
    cmd2[cmd2.index("--steps") + 1] = "14"
    out2 = subprocess.run(
        cmd2, capture_output=True, text=True, timeout=600, env=env,
        cwd="/root/repo",
    )
    assert out2.returncode == 0, out2.stderr[-2000:]
    assert "resumed from step" in out2.stdout


def test_microbatched_train_step_matches_full_batch():
    """Gradient accumulation is numerically equivalent to the full batch."""
    from repro.configs.registry import get_arch
    from repro.launch.steps import init_train_state, make_train_step
    from repro.optim.optimizers import OptConfig
    import dataclasses

    cfg = dataclasses.replace(get_arch("qwen2.5-3b").reduced(), dtype="float32")
    opt = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (4, 17), 0, cfg.vocab_size)}
    s0 = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    s1, l1 = make_train_step(cfg, opt, microbatches=1)(s0, batch)
    s0b = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    s2, l2 = make_train_step(cfg, opt, microbatches=2)(s0b, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    # Adam's rsqrt near v~0 amplifies accumulation-order noise: abs tol
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=5e-5)


def test_manual_dp_train_step_runs():
    """shard_map manual-DP path with compressed psum (1-device mesh)."""
    from repro.configs.registry import get_arch
    from repro.launch.steps import init_train_state, make_manual_dp_train_step
    from repro.optim.optimizers import OptConfig
    from repro.optim.compress import init_error_feedback

    cfg = get_arch("qwen2.5-3b").reduced()
    opt = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    mesh = jax.make_mesh((1,), ("data",))
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    state["opt"]["ef"] = init_error_feedback(state["params"])
    step = make_manual_dp_train_step(cfg, opt, mesh, codec="bf16")
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (4, 17), 0, cfg.vocab_size)}
    new_state, loss = step(state, batch)
    assert jnp.isfinite(loss)
    assert all(jnp.isfinite(x).all() for x in jax.tree.leaves(new_state))


def test_sharding_hints_are_noops_without_mesh():
    from repro.distributed.sharding import shard

    x = jnp.ones((4, 4))
    np.testing.assert_array_equal(shard(x, ("data", None)), x)


def test_mesh_factories():
    from repro.launch.mesh import make_mesh_for

    mesh = make_mesh_for(1, 1)
    assert mesh.axis_names == ("data", "model")
