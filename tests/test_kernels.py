"""Per-kernel correctness sweeps: every Pallas kernel (interpret mode on
CPU) against its ref.py oracle across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import set_tuning, clear_tuning
from repro.kernels import ref
from repro.kernels.eltwise import bias_add_rows_pallas, relu_bwd_pallas, relu_pallas
from repro.kernels.flash_attention import (
    flash_attention_bwd_pallas,
    flash_attention_pallas,
    flash_decode_pallas,
)
from repro.kernels.gemm import gemm_pallas
from repro.kernels.im2col import col2im_pallas, im2col_pallas
from repro.kernels.mamba_scan import ssd_scan_pallas
from repro.kernels.pooling import maxpool_bwd_pallas, maxpool_pallas
from repro.kernels.rmsnorm import rmsnorm_bwd_pallas, rmsnorm_pallas
from repro.kernels.softmax_xent import (
    softmax_pallas,
    softmax_xent_bwd_pallas,
    softmax_xent_pallas,
)


@pytest.fixture(autouse=True)
def _clear():
    clear_tuning()
    yield
    clear_tuning()


def key(i=0):
    return jax.random.PRNGKey(i)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "m,k,n", [(128, 128, 128), (200, 300, 170), (7, 5, 3), (256, 512, 384),
              (1, 1024, 8), (129, 257, 129)]
)
def test_gemm_shapes(m, k, n):
    a = jax.random.normal(key(0), (m, k), jnp.float32)
    b = jax.random.normal(key(1), (k, n), jnp.float32)
    np.testing.assert_allclose(
        gemm_pallas(a, b), ref.gemm(a, b), rtol=1e-4, atol=1e-4 * np.sqrt(k)
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_dtypes(dtype):
    a = jax.random.normal(key(0), (256, 256), dtype)
    b = jax.random.normal(key(1), (256, 128), dtype)
    got = np.asarray(gemm_pallas(a, b), np.float32)
    want = np.asarray(ref.gemm(a, b), np.float32)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 16)


def test_gemm_tuning_registry():
    set_tuning("gemm", bm=32, bn=64, bk=32)
    a = jax.random.normal(key(0), (100, 96), jnp.float32)
    b = jax.random.normal(key(1), (96, 72), jnp.float32)
    np.testing.assert_allclose(gemm_pallas(a, b), ref.gemm(a, b), rtol=1e-4,
                               atol=1e-3)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "n,c,h,w,kh,kw,s,p",
    [(2, 3, 8, 9, 3, 3, 1, 0), (2, 3, 8, 9, 3, 3, 1, 1),
     (1, 1, 28, 28, 5, 5, 1, 0), (2, 4, 10, 10, 2, 3, 2, 1),
     (2, 2, 7, 7, 3, 3, 3, 0)],
)
def test_im2col(n, c, h, w, kh, kw, s, p):
    x = jax.random.normal(key(0), (n, c, h, w), jnp.float32)
    np.testing.assert_array_equal(
        im2col_pallas(x, kh, kw, s, p), ref.im2col(x, kh, kw, s, p)
    )


@pytest.mark.parametrize(
    "n,c,h,w,kh,kw,p", [(2, 3, 8, 9, 3, 3, 0), (2, 3, 8, 9, 3, 3, 1),
                        (1, 2, 12, 12, 5, 5, 2)]
)
def test_col2im(n, c, h, w, kh, kw, p):
    oh = ref.conv_out_size(h, kh, 1, p)
    ow = ref.conv_out_size(w, kw, 1, p)
    cols = jax.random.normal(key(0), (n, c * kh * kw, oh * ow), jnp.float32)
    np.testing.assert_allclose(
        col2im_pallas(cols, (n, c, h, w), kh, kw, 1, p),
        ref.col2im(cols, (n, c, h, w), kh, kw, 1, p),
        rtol=1e-6, atol=1e-6,
    )


# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "n,c,h,w,k,s,p",
    [(2, 3, 8, 8, 2, 2, 0), (2, 3, 9, 9, 2, 2, 0), (1, 4, 28, 28, 2, 2, 0),
     (2, 2, 12, 12, 3, 3, 0), (1, 1, 8, 8, 2, 2, 1)],
)
def test_maxpool(n, c, h, w, k, s, p):
    x = jax.random.normal(key(0), (n, c, h, w), jnp.float32)
    out, arg = maxpool_pallas(x, k, s, p)
    rout, rarg = ref.maxpool(x, k, s, p)
    np.testing.assert_allclose(out, rout)
    np.testing.assert_array_equal(arg, rarg)
    dy = jax.random.normal(key(1), out.shape)
    np.testing.assert_allclose(
        maxpool_bwd_pallas(dy, arg, (n, c, h, w), k, s, p),
        ref.maxpool_bwd(dy, rarg, (n, c, h, w), k, s, p),
    )


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,v", [(4, 10), (130, 17), (256, 1000), (5, 7)])
def test_softmax_xent(b, v):
    x = jax.random.normal(key(0), (b, v), jnp.float32) * 3
    y = jax.random.randint(key(1), (b,), 0, v)
    np.testing.assert_allclose(softmax_pallas(x), ref.softmax(x),
                               rtol=1e-5, atol=1e-6)
    l, p = softmax_xent_pallas(x, y)
    rl, rp = ref.softmax_xent(x, y)
    np.testing.assert_allclose(l, rl, rtol=1e-5)
    np.testing.assert_allclose(p, rp, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        softmax_xent_bwd_pallas(p, y), ref.softmax_xent_bwd(rp, y),
        rtol=1e-5, atol=1e-7,
    )


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("r,d", [(8, 64), (300, 128), (17, 96)])
def test_rmsnorm(r, d):
    x = jax.random.normal(key(0), (r, d), jnp.float32)
    w = jax.random.normal(key(1), (d,))
    np.testing.assert_allclose(rmsnorm_pallas(x, w), ref.rmsnorm(x, w),
                               rtol=1e-5, atol=1e-6)
    dy = jax.random.normal(key(2), (r, d))
    dx, dw = rmsnorm_bwd_pallas(x, w, dy)
    gx, gw = jax.grad(lambda x, w: (ref.rmsnorm(x, w) * dy).sum(), (0, 1))(x, w)
    np.testing.assert_allclose(dx, gx, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(dw, gw, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,sq,sk,hq,hkv,d,causal,window",
    [(1, 32, 32, 4, 2, 16, True, None), (2, 33, 33, 4, 4, 16, True, None),
     (1, 48, 48, 8, 2, 32, True, 20), (2, 16, 16, 2, 1, 8, False, None)],
)
def test_flash_attention(b, sq, sk, hq, hkv, d, causal, window):
    set_tuning("flash_attention", bq=16, bk=16)
    q = jax.random.normal(key(0), (b, sq, hq, d), jnp.float32)
    k = jax.random.normal(key(1), (b, sk, hkv, d), jnp.float32)
    v = jax.random.normal(key(2), (b, sk, hkv, d), jnp.float32)
    o, lse = flash_attention_pallas(q, k, v, causal=causal, window=window)
    want = ref.mha_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(o, want, rtol=2e-4, atol=2e-4)
    do = jax.random.normal(key(3), o.shape)
    dq, dk, dv = flash_attention_bwd_pallas(
        q, k, v, o, lse, do, causal=causal, window=window
    )
    f = lambda q, k, v: (
        ref.mha_attention(q, k, v, causal=causal, window=window) * do
    ).sum()
    gq, gk, gv = jax.grad(f, (0, 1, 2))(q, k, v)
    np.testing.assert_allclose(dq, gq, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(dk, gk, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(dv, gv, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize(
    "b,hq,hkv,d,smax,ln,window",
    [(2, 4, 2, 16, 64, 37, None), (1, 8, 8, 32, 128, 128, None),
     (2, 4, 1, 16, 96, 50, 24)],
)
def test_flash_decode(b, hq, hkv, d, smax, ln, window):
    set_tuning("flash_decode", bk=16)
    q = jax.random.normal(key(0), (b, hq, d), jnp.float32)
    kc = jax.random.normal(key(1), (b, smax, hkv, d), jnp.float32)
    vc = jax.random.normal(key(2), (b, smax, hkv, d), jnp.float32)
    o = flash_decode_pallas(q, kc, vc, jnp.int32(ln), window=window)
    want = ref.mha_attention(
        q[:, None], kc[:, :ln], vc[:, :ln], causal=True, window=window,
        q_offset=ln - 1,
    )[:, 0]
    np.testing.assert_allclose(o, want, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "B,S,H,P,N,chunk", [(2, 32, 4, 8, 16, 8), (1, 37, 3, 16, 32, 16),
                        (2, 64, 2, 8, 8, 64)]
)
def test_ssd_scan(B, S, H, P, N, chunk):
    set_tuning("ssd_scan", chunk=chunk)
    x = jax.random.normal(key(0), (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(key(1), (B, S, H)))
    A = -jnp.exp(jax.random.normal(key(2), (H,)))
    Bm = jax.random.normal(key(3), (B, S, 1, N))
    C = jax.random.normal(key(4), (B, S, 1, N))
    y, hf = ssd_scan_pallas(x, dt, A, Bm, C, chunk=chunk)
    ry, rhf = ref.ssd_scan(x, dt, A, Bm, C, chunk=chunk)
    np.testing.assert_allclose(y, ry, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(hf, rhf, rtol=2e-4, atol=2e-4)


def test_ssd_matches_sequential_decode():
    B, S, H, P, N = 1, 12, 2, 4, 8
    x = jax.random.normal(key(0), (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(key(1), (B, S, H)))
    A = -jnp.exp(jax.random.normal(key(2), (H,)))
    Bm = jax.random.normal(key(3), (B, S, 1, N))
    C = jax.random.normal(key(4), (B, S, 1, N))
    y, fin = ref.ssd_scan(x, dt, A, Bm, C, chunk=4)
    state = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        yt, state = ref.ssd_decode_step(
            x[:, t], dt[:, t], A, Bm[:, t], C[:, t], state
        )
        ys.append(yt)
    np.testing.assert_allclose(y, jnp.stack(ys, 1), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(fin, state, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
def test_eltwise():
    x = jax.random.normal(key(0), (70, 130), jnp.float32)
    np.testing.assert_array_equal(relu_pallas(x), ref.relu(x))
    np.testing.assert_array_equal(relu_pallas(x, 0.1), ref.relu(x, 0.1))
    dy = jax.random.normal(key(1), x.shape)
    np.testing.assert_array_equal(
        relu_bwd_pallas(x, dy, 0.1), ref.relu_bwd(x, dy, 0.1)
    )
    v = jax.random.normal(key(2), (130,))
    np.testing.assert_allclose(
        bias_add_rows_pallas(x, v), ref.bias_add_rows(x, v), rtol=1e-6
    )
