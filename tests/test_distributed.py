"""Distributed-substrate tests: checkpoint/restart, fault tolerance,
elastic re-mesh planning, straggler policy, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.checkpoint import Checkpointer
from repro.distributed.fault_tolerance import (
    ElasticPlan, FaultInjector, StragglerPolicy, plan_after_failure,
    run_with_restarts,
)
from repro.optim import compress as GC
from repro.optim.optimizers import OptConfig, apply_updates, init_opt_state, schedule


# -- checkpoint ---------------------------------------------------------------

def _state():
    return {
        "params": {"w": jnp.arange(6.0).reshape(2, 3),
                   "b": jnp.ones((3,), jnp.bfloat16)},
        "opt": {"step": jnp.int32(7),
                "m": {"w": jnp.full((2, 3), 0.5), "b": jnp.zeros((3,))}},
    }


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    st = _state()
    ck.save(10, st)
    assert ck.all_steps() == [10]
    got = ck.restore(10, jax.tree.map(jnp.zeros_like, st))
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_retention_and_latest(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state())
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(tmp_path, keep=3)
    ck.save(5, _state(), blocking=False)
    ck.wait()
    assert ck.latest_step() == 5


def test_checkpoint_atomicity(tmp_path):
    """A leftover .tmp dir (crashed writer) is never listed as a step."""
    ck = Checkpointer(tmp_path)
    (tmp_path / "step_99.tmp").mkdir()
    assert ck.all_steps() == []
    ck.save(1, _state())
    assert ck.all_steps() == [1]


def test_checkpoint_manifest(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(3, _state(), extra={"mesh": "16x16", "data_position": 3})
    m = ck.manifest(3)
    assert m["step"] == 3 and m["mesh"] == "16x16"
    assert m["data_position"] == 3


# -- fault tolerance ----------------------------------------------------------

def test_run_with_restarts_recovers(tmp_path):
    ck = Checkpointer(tmp_path)
    inj = FaultInjector(fail_at=[5])
    calls = {"n": 0}

    def train(start, state):
        calls["n"] += 1
        for s in range(start, 10):
            inj.maybe_fail(s)
            state = {"x": state["x"] + 1}
            if (s + 1) % 2 == 0:
                ck.save(s + 1, state)
        return state, 10

    state, final, restarts = run_with_restarts(
        train, ck, {"x": jnp.zeros(())}, max_restarts=2
    )
    assert final == 10 and restarts == 1 and calls["n"] == 2
    # deterministic: x advanced exactly 10 - restart losses replayed
    assert float(state["x"]) == 10.0


def test_run_with_restarts_gives_up(tmp_path):
    ck = Checkpointer(tmp_path)

    def train(start, state):
        raise RuntimeError("always fails")

    with pytest.raises(RuntimeError):
        run_with_restarts(train, ck, {}, max_restarts=2)


# -- elastic -------------------------------------------------------------------

def test_elastic_plan_after_failure():
    plan = plan_after_failure(total_devices=256, lost=13, model_parallel=16)
    assert plan.viable()
    assert plan.data_parallel == 15          # 243 // 16
    assert plan.devices_used == 240
    assert plan.global_batch_for(16) == 240

    dead = plan_after_failure(total_devices=16, lost=8, model_parallel=16)
    assert not dead.viable()


def test_elastic_mesh_builds_on_available_devices():
    plan = ElasticPlan(n_devices=1, model_parallel=1)
    mesh = plan.make_mesh()
    assert mesh.shape == {"data": 1, "model": 1}


def test_elastic_restore_onto_new_mesh(tmp_path):
    """Checkpoints are mesh-agnostic: save, then restore with shardings for
    a (1,1) mesh (the container's surviving-device case)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    ck = Checkpointer(tmp_path)
    st = _state()
    ck.save(1, st)
    mesh = ElasticPlan(1, 1).make_mesh()
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), st)
    got = ck.restore(1, jax.tree.map(jnp.zeros_like, st), shardings=sh)
    np.testing.assert_array_equal(got["params"]["w"], st["params"]["w"])


# -- straggler policy ----------------------------------------------------------

def test_straggler_detection():
    pol = StragglerPolicy(n_hosts=8, threshold=2.0)
    normal = [1.0] * 8
    for _ in range(3):
        assert pol.observe(normal) == []
    slow = list(normal)
    slow[3] = 10.0
    for _ in range(5):
        bad = pol.observe(slow)
    assert bad == [3]


def test_straggler_reassignment_deterministic_and_excluding():
    pol = StragglerPolicy(n_hosts=8)
    a1 = pol.assignment(step=42, exclude=[3])
    a2 = pol.assignment(step=42, exclude=[3])
    assert a1 == a2                      # deterministic in step
    assert 3 not in set(a1.values())     # excluded host gets nothing
    assert set(a1.keys()) == set(range(8))  # every shard assigned


# -- gradient compression --------------------------------------------------------

@pytest.mark.parametrize("codec", ["bf16", "int8"])
def test_compress_roundtrip_error_bounded(codec):
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64)) * 0.1}
    ef = GC.init_error_feedback(g)
    q, scales, ef2 = GC.compress(g, ef, codec)
    deq = GC.decompress(q, scales, codec)
    err = jnp.abs(deq["w"] - g["w"]).max()
    bound = 2e-3 if codec == "bf16" else 2e-3
    assert float(err) < bound
    # residual stored for feedback
    np.testing.assert_allclose(
        np.asarray(ef2["w"]), np.asarray(g["w"] - deq["w"]), atol=1e-7
    )


def test_error_feedback_preserves_sum():
    """Over many steps, compressed-sum ~= true-sum (EF property)."""
    rng = jax.random.PRNGKey(0)
    g_total = jnp.zeros((32,))
    applied = jnp.zeros((32,))
    ef = {"g": jnp.zeros((32,))}
    for i in range(50):
        rng, k = jax.random.split(rng)
        g = {"g": jax.random.normal(k, (32,)) * 0.01}
        g_total = g_total + g["g"]
        q, s, ef = GC.compress(g, ef, "int8")
        applied = applied + GC.decompress(q, s, "int8")["g"]
    np.testing.assert_allclose(
        np.asarray(applied + ef["g"]), np.asarray(g_total), atol=1e-5
    )


def test_psum_compressed_single_device():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("d",))
    g = {"w": jnp.ones((4, 4))}
    ef = GC.init_error_feedback(g)

    def f(g, ef):
        return GC.psum_compressed(g, ef, "d", "bf16")[0]

    out = shard_map(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=P(), check_rep=False
    )(g, ef)
    np.testing.assert_allclose(np.asarray(out["w"]), np.ones((4, 4)))


# -- optimizer -------------------------------------------------------------------

def test_adamw_mixed_precision_master():
    cfg = OptConfig(lr=1e-2, warmup_steps=0, total_steps=100, weight_decay=0.0)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    st = init_opt_state(cfg, params)
    assert st["master"]["w"].dtype == jnp.float32
    g = {"w": jnp.full((4,), 0.5, jnp.float32)}
    new_p, st = apply_updates(cfg, g, st, jnp.bfloat16)
    assert new_p["w"].dtype == jnp.bfloat16
    assert float(st["master"]["w"][0]) < 1.0   # moved against gradient


def test_schedule_warmup_and_decay():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.int32(0))) == 0.0
    assert float(schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(schedule(cfg, jnp.int32(110))) == pytest.approx(0.1, rel=1e-3)
