"""Quantized paged-KV tests — int8 per-(page, head)-scaled storage must
hold through the whole decode stack: the Pallas dequant kernels lock-step
with the jnp oracles (including forced sub-tiling and windows), engine
token streams match the f32 cache on smoke horizons across families and
backends, per-step logits stay inside the quantization error budget, a
pool with half the f32 bytes admits the same workload the f32 pool can
only serve by preempting, and the bf16/int8 resident-byte ladder is
exact (1/2 and 1/4 of the f32 pool)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.core import use_backend
from repro.core.registry import clear_tuning, set_tuning
from repro.kernels.flash_attention import (
    flash_decode_paged_quant_pallas,
    flash_prefill_chunk_paged_quant_pallas,
)
from repro.kernels.ops import (
    _attention_decode_paged_quant_ref,
    _attention_prefill_chunk_paged_quant_ref,
)
from repro.models.model import build_model
from repro.serving import CacheConfig, EngineConfig, ServingEngine

BACKENDS = ["reference", "pallas"]
# one dense, one moe, one hybrid: every family with a KV pool to quantize
# (pure ssm has no attention cache — nothing to store in int8)
QUANT_ARCHS = ["qwen2.5-3b", "qwen3-moe-235b-a22b", "zamba2-2.7b"]


def _cfg(arch):
    cfg = dataclasses.replace(get_arch(arch).reduced(), dtype="float32")
    if cfg.n_experts:
        # no-drop regime: routing stays batch-composition-independent, so
        # any token drift would be attributable to quantization alone
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    return cfg


def _model_params(arch):
    cfg = _cfg(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _quant_pool(rng, n_pages, page, hkv, d):
    """An int8 page pool + f32 per-(page, head) scales with enough spread
    that a scale mix-up (wrong page or head) shifts the output visibly."""
    kp = jnp.asarray(
        rng.integers(-127, 128, (n_pages, page, hkv, d)), jnp.int8
    )
    sc = jnp.asarray(rng.uniform(0.01, 0.1, (n_pages, hkv)), jnp.float32)
    return kp, sc


# -- kernel <-> oracle lock-step --------------------------------------------

@pytest.mark.parametrize("window", [None, 6])
def test_decode_quant_kernel_matches_oracle(window):
    """The dequantizing decode kernel and the dequant-then-delegate oracle
    must agree on per-row cache lengths, unmapped table slots, and
    windows."""
    b, hq, hkv, d = 3, 4, 2, 8
    page, n_pages, maxb = 4, 12, 8
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    kp, ksc = _quant_pool(rng, n_pages, page, hkv, d)
    vp, vsc = _quant_pool(rng, n_pages, page, hkv, d)
    cache_len = jnp.asarray([5, 9, 17], jnp.int32)
    bt = np.full((b, maxb), -1, np.int32)
    bt[0, :2] = [0, 1]
    bt[1, :3] = [2, 3, 4]
    bt[2, :5] = [5, 6, 7, 8, 9]
    bt = jnp.asarray(bt)
    want = _attention_decode_paged_quant_ref(q, kp, vp, ksc, vsc,
                                             cache_len, bt, window=window)
    got = flash_decode_paged_quant_pallas(q, kp, vp, ksc, vsc, cache_len,
                                          bt, window=window, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("window", [None, 6])
def test_prefill_chunk_quant_kernel_matches_oracle(window):
    """Same lock-step for the chunked-prefill dequant kernel, with per-row
    starts/widths (padding rows included)."""
    b, c, hq, hkv, d = 3, 5, 4, 2, 8
    page, n_pages, maxb = 4, 12, 8
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(b, c, hq, d)), jnp.float32)
    kp, ksc = _quant_pool(rng, n_pages, page, hkv, d)
    vp, vsc = _quant_pool(rng, n_pages, page, hkv, d)
    start = jnp.asarray([0, 7, 20], jnp.int32)
    width = jnp.asarray([5, 3, 1], jnp.int32)
    bt = np.full((b, maxb), -1, np.int32)
    bt[0, :2] = [0, 1]
    bt[1, :3] = [2, 3, 4]
    bt[2, :6] = [5, 6, 7, 8, 9, 10]
    bt = jnp.asarray(bt)
    want = _attention_prefill_chunk_paged_quant_ref(
        q, kp, vp, ksc, vsc, start, width, bt, window=window
    )
    got = flash_prefill_chunk_paged_quant_pallas(
        q, kp, vp, ksc, vsc, start, width, bt, window=window, interpret=True
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_quant_kernels_forced_subtiling():
    """Force bs=2 sub-tiles (page_size=4) so both kernels walk several
    dequant sub-tiles per page — per-page scales must still land on the
    right rows."""
    b, c, hq, hkv, d = 2, 4, 4, 2, 8
    page, n_pages, maxb = 4, 10, 6
    rng = np.random.default_rng(2)
    qd = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    qc = jnp.asarray(rng.normal(size=(b, c, hq, d)), jnp.float32)
    kp, ksc = _quant_pool(rng, n_pages, page, hkv, d)
    vp, vsc = _quant_pool(rng, n_pages, page, hkv, d)
    bt = np.full((b, maxb), -1, np.int32)
    bt[0, :3] = [0, 1, 2]
    bt[1, :4] = [3, 4, 5, 6]
    bt = jnp.asarray(bt)
    cache_len = jnp.asarray([11, 14], jnp.int32)
    start, width = cache_len - jnp.asarray([4, 2]), jnp.asarray([4, 2])
    want_d = _attention_decode_paged_quant_ref(qd, kp, vp, ksc, vsc,
                                               cache_len, bt)
    want_c = _attention_prefill_chunk_paged_quant_ref(
        qc, kp, vp, ksc, vsc, start, width, bt
    )
    set_tuning("flash_decode_paged_quant", bs=2)
    set_tuning("flash_prefill_paged_quant", bs=2)
    try:
        got_d = flash_decode_paged_quant_pallas(qd, kp, vp, ksc, vsc,
                                                cache_len, bt,
                                                interpret=True)
        got_c = flash_prefill_chunk_paged_quant_pallas(
            qc, kp, vp, ksc, vsc, start, width, bt, interpret=True
        )
    finally:
        clear_tuning()
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_c), np.asarray(want_c),
                               rtol=1e-5, atol=1e-5)


# -- engine: quantized streams on smoke horizons ----------------------------

def _serve(model, params, reqs, kv_dtype, *, backend="reference", **ekw):
    cache = CacheConfig(layout="paged", page_size=4, kv_dtype=kv_dtype)
    eng = ServingEngine(model, params, batch=2, max_len=16, cache=cache,
                        config=EngineConfig(steps_per_sync=3, **ekw))
    rids = [eng.submit(t, g) for t, g in reqs]
    with use_backend(backend):
        got = eng.run()
    return eng, [got[r].tolist() for r in rids]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("arch", QUANT_ARCHS)
def test_engine_int8_matches_f32_on_smoke_horizon(arch, backend):
    """On smoke horizons the ~0.4% quantization error must not flip a
    greedy pick: int8 and f32 caches emit identical token streams for
    dense, moe, and hybrid families on both backends."""
    cfg, model, params = _model_params(arch)
    # prompts chosen away from greedy near-ties: the smoke-scale moe arch
    # has top-2 logit gaps down to ~1e-3, inside the int8 error envelope
    reqs = [([2, 9, 14, 6, 3, 8], 4), ([7, 12, 5], 4), ([10, 1, 10, 1, 6], 4)]
    _, f32 = _serve(model, params, reqs, "f32", backend=backend)
    eng, q8 = _serve(model, params, reqs, "int8", backend=backend)
    assert q8 == f32
    assert eng._mstate["kp"].dtype == jnp.int8
    assert eng._mstate["ksc"].dtype == jnp.float32


def test_engine_int8_chunked_prefill_smoke():
    """The chunked write path (write_page_chunk_quant through prefill)
    feeds the same streams as f32 on the smoke horizon."""
    cfg, model, params = _model_params("qwen2.5-3b")
    reqs = [(list(range(1, 10)), 4), ([5, 3, 5, 3, 5, 3], 4)]
    _, f32 = _serve(model, params, reqs, "f32", prefill_chunk=4)
    _, q8 = _serve(model, params, reqs, "int8", prefill_chunk=4)
    assert q8 == f32


# -- model: per-step logit error budget -------------------------------------

def test_decode_step_logits_within_quant_budget():
    """Per-step logits under the int8 cache stay within a small absolute
    envelope of the f32 run — the error is real (dtype check proves the
    quantized pool is live) but bounded, step after step."""
    cfg, model, params = _model_params("qwen2.5-3b")
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 10), 0,
                              cfg.vocab_size).astype(jnp.int32)
    states = {
        kd: model.init_decode_state(
            2, 16, cache=CacheConfig(layout="paged", page_size=4,
                                     kv_dtype=kd)
        )
        for kd in ("f32", "int8")
    }
    assert states["int8"]["kp"].dtype == jnp.int8
    assert states["f32"]["kp"].dtype == jnp.float32
    worst = 0.0
    for j in range(toks.shape[1]):
        lf, states["f32"] = model.decode_step(params, states["f32"],
                                              toks[:, j])
        lq, states["int8"] = model.decode_step(params, states["int8"],
                                               toks[:, j])
        step = float(jnp.max(jnp.abs(lf - lq)))
        worst = max(worst, step)
        assert step < 0.05, f"step {j}: logit drift {step:.4f}"
    assert worst > 0.0  # the quantized path really ran


# -- capacity: half the bytes, same workload --------------------------------

def _pressure_engine(model, params, n_pages, kv_dtype):
    cache = CacheConfig(layout="paged", page_size=4, n_pages=n_pages,
                        kv_dtype=kv_dtype)
    return ServingEngine(
        model, params, batch=2, max_len=32, cache=cache,
        config=EngineConfig(steps_per_sync=2, prefill_chunk=4),
    )


def test_int8_pool_at_half_bytes_admits_without_preemption():
    """The headline capacity claim: a 6-page f32 pool can only serve the
    contended pair by preempting; an int8 pool with HALF those bytes
    (12 pages at 1/4 the per-page cost) serves it with zero preemptions
    — and both finish every request."""
    cfg, model, params = _model_params("qwen2.5-3b")

    f32 = _pressure_engine(model, params, 6, "f32")
    f32.submit(list(range(1, 9)), 10, priority=0)    # reserves 5 pages
    f32.step()
    f32.submit(list(range(21, 27)), 8, priority=1)   # needs 4 more
    fouts = f32.run()
    assert f32.preemptions >= 1

    q8 = _pressure_engine(model, params, 12, "int8")
    assert q8.kv_bytes_per_page() * 4 == f32.kv_bytes_per_page()
    assert 12 * q8.kv_bytes_per_page() * 2 == 6 * f32.kv_bytes_per_page()
    q8.submit(list(range(1, 9)), 10, priority=0)
    q8.step()
    q8.submit(list(range(21, 27)), 8, priority=1)
    qouts = q8.run()
    assert q8.preemptions == 0
    assert len(qouts) == len(fouts) == 2
    for r in qouts:
        assert len(qouts[r]) > 0


# -- the resident-byte ladder is exact --------------------------------------

def test_kv_dtype_byte_ladder_is_exact():
    """bf16 = 1/2 and int8 = 1/4 of the f32 per-page bytes, exactly — the
    ladder BENCH_0004 publishes, measured off live engines."""
    cfg, model, params = _model_params("qwen2.5-3b")
    per_page = {}
    for kd in ("f32", "bf16", "int8"):
        eng = ServingEngine(
            model, params, batch=2, max_len=16,
            cache=CacheConfig(layout="paged", page_size=4, n_pages=8,
                              kv_dtype=kd),
        )
        eng.submit([1, 2, 3, 4, 5], 3)
        eng.run()
        per_page[kd] = eng.kv_bytes_per_page()
        if kd == "bf16":
            assert eng._mstate["kp"].dtype == jnp.bfloat16
            assert "ksc" not in eng._mstate  # storage-only: no scale pools
    assert per_page["bf16"] * 2 == per_page["f32"]
    assert per_page["int8"] * 4 == per_page["f32"]
    assert per_page["int8"] * 2 == per_page["bf16"]


def test_sub_f32_storage_requires_paged_layout():
    cfg, model, params = _model_params("qwen2.5-3b")
    for kd in ("bf16", "int8"):
        with pytest.raises(ValueError, match="paged"):
            CacheConfig(layout="contiguous", kv_dtype=kd)
        with pytest.raises(ValueError, match="paged"):
            model.init_decode_state(2, 16, kv_dtype=kd)
    with pytest.raises(ValueError, match="kv_dtype"):
        CacheConfig(layout="paged", kv_dtype="fp8")
