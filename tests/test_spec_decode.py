"""Speculative decoding through the chunked-prefill verifier, and the
typed config API that carries it.

The load-bearing property is *token identity*: greedy draft-and-verify
must emit exactly the tokens plain greedy decode would, for every family
x layout x backend cell — acceptance only ever skips forward through the
verifier's own argmax sequence.  The config tests pin the kwargs→config
adapter (round trip, one deprecation per call site, unchanged error
messages) so legacy call sites keep working verbatim.
"""
import dataclasses
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.audit import jit_cache_audit, no_transfer_audit
from repro.core import use_backend
from repro.configs.registry import get_arch
from repro.models.model import build_model
from repro.serving import (
    CacheConfig,
    EngineConfig,
    HybridSSMDrafter,
    PagerState,
    Request,
    RequestHandle,
    ServingEngine,
    SpecConfig,
    alloc_range,
    configs_from_flags,
    from_kwargs,
    init_block_table,
    init_pager,
    serve_all,
    validate_configs,
)

BACKENDS = ["reference", "pallas"]
SPEC_ARCHS = ["qwen2.5-3b", "qwen3-moe-235b-a22b", "zamba2-2.7b"]


def _cfg(arch):
    cfg = dataclasses.replace(get_arch(arch).reduced(), dtype="float32")
    if cfg.family == "moe":
        # the verifier routes B*(K+1) tokens through the experts in one
        # step; lift capacity so routing stays lossless at chunk width
        cfg = dataclasses.replace(
            cfg, capacity_factor=float(cfg.n_experts)
        )
    return cfg


def _model_params(cfg):
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return model, params


def _requests(cfg, n=4, gen=5, seed=7):
    rng = np.random.default_rng(seed)
    return [
        (
            rng.integers(
                0, cfg.vocab_size, size=int(rng.integers(3, 8))
            ).tolist(),
            gen,
        )
        for _ in range(n)
    ]


def _drain(model, params, reqs, *, cache=None, config=None, audit=True):
    eng = ServingEngine(
        model, params, batch=2, max_len=24, cache=cache, config=config
    )
    handles = [eng.submit(toks, gen) for toks, gen in reqs]
    if audit:
        with jit_cache_audit(eng), no_transfer_audit():
            got = eng.run()
    else:
        got = eng.run()
    return eng, [got[h].tolist() for h in handles]


# ---------------------------------------------------------------------------
# token identity: the tentpole invariant
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("arch", SPEC_ARCHS)
def test_greedy_spec_token_identity(arch, layout, backend):
    """dense/moe/hybrid x layout x backend: speculative decode emits the
    exact token sequence of plain greedy decode, with the jit caches at
    size 1 and no implicit transfers, through mid-stream admission (4
    requests over 2 slots)."""
    cfg = _cfg(arch)
    model, params = _model_params(cfg)
    reqs = _requests(cfg)
    with use_backend(backend):
        _, base = _drain(
            model, params, reqs,
            config=EngineConfig(steps_per_sync=3, prefill_chunk=4),
        )
        eng, spec = _drain(
            model, params, reqs,
            cache=CacheConfig(layout=layout, page_size=4),
            config=EngineConfig(
                steps_per_sync=3, prefill_chunk=4,
                spec=SpecConfig(k=3, ngram=2),
            ),
        )
    assert spec == base
    st = eng.stats()
    assert st["spec_proposed"] > 0 and st["spec_emitted"] > 0
    assert eng._spec_n._cache_size() == 1
    if layout == "paged":
        # rollback + completion released every page
        assert (np.asarray(eng._mstate["block_table"]) == -1).all()


def test_spec_accepts_drafts_on_repetitive_tail():
    """Prompt-lookup earns its keep: greedy continuations of a random-init
    model loop quickly, so the n-gram drafter's accept rate is > 0 and
    fewer verify steps than emitted tokens are needed."""
    cfg = _cfg("qwen2.5-3b")
    model, params = _model_params(cfg)
    reqs = _requests(cfg, gen=8)
    eng, spec = _drain(
        model, params, reqs,
        cache=CacheConfig(layout="paged", page_size=4),
        config=EngineConfig(
            steps_per_sync=3, prefill_chunk=4, spec=SpecConfig(k=4, ngram=2)
        ),
    )
    st = eng.stats()
    assert st["spec_accepted"] > 0
    assert 0.0 < st["spec_accept_rate"] <= 1.0
    # every accepted draft rode a verify step that also emitted the
    # verifier's own token, so emitted strictly exceeds accepted
    assert st["spec_emitted"] > st["spec_accepted"]


@pytest.mark.parametrize("layout", ["contiguous", "paged"])
def test_hybrid_ssm_drafter_token_identity(layout):
    """The self-drafting hybrid: Mamba layers propose, the full model
    verifies — still token-identical, and the drafter's private state
    rides the decode-state pytree (reset/donation safe)."""
    cfg = _cfg("zamba2-2.7b")
    model, params = _model_params(cfg)
    reqs = _requests(cfg)
    _, base = _drain(
        model, params, reqs,
        config=EngineConfig(steps_per_sync=3, prefill_chunk=4),
    )
    eng, spec = _drain(
        model, params, reqs,
        cache=CacheConfig(layout=layout, page_size=4),
        config=EngineConfig(
            steps_per_sync=3, prefill_chunk=4,
            spec=SpecConfig(k=3, drafter="hybrid_ssm"),
        ),
    )
    assert spec == base
    assert eng.stats()["spec_proposed"] > 0
    assert "drf_ssm" in eng._mstate and "drf_pos" in eng._mstate


def test_ssm_two_phase_verify_token_identity():
    """Pure-SSM family takes the discard-then-commit verify (the
    recurrence cannot rewind) — identity must still hold."""
    cfg = _cfg("mamba2-2.7b")
    model, params = _model_params(cfg)
    reqs = _requests(cfg)
    _, base = _drain(
        model, params, reqs,
        config=EngineConfig(steps_per_sync=3, prefill_chunk=4),
    )
    _, spec = _drain(
        model, params, reqs,
        config=EngineConfig(
            steps_per_sync=3, prefill_chunk=4, spec=SpecConfig(k=3)
        ),
    )
    assert spec == base


def test_alloc_range_maps_block_crossed_mid_page():
    """Regression: a range starting mid-page (spec verify chunks start at
    arbitrary positions) crosses into its next block fewer than page_size
    positions after start — the crossed block must still be mapped."""
    pager = init_pager(8)
    bt = init_block_table(1, 4)
    # positions 7..8 with page_size=4 touch blocks 1 and 2
    pager, bt = alloc_range(
        pager,
        bt,
        jnp.asarray([7], jnp.int32),
        jnp.asarray([8], jnp.int32),
        page_size=4,
        max_chunk=2,
    )
    got = np.asarray(bt)[0]
    assert got[1] >= 0 and got[2] >= 0, got
    assert int(pager.top) == 6


# ---------------------------------------------------------------------------
# typed config API: adapter round trip, deprecation, validation
# ---------------------------------------------------------------------------


def test_from_kwargs_round_trip():
    cache, config = from_kwargs(
        layout="paged", page_size=8, n_pages=32, snapshots=False,
        steps_per_sync=5, prefill_chunk=4, prefix_sharing=True,
        temperature=0.5, top_k=3, seed=11, prefill_budget=2,
    )
    assert cache == CacheConfig(layout="paged", page_size=8, n_pages=32)
    assert config == EngineConfig(
        steps_per_sync=5, prefill_chunk=4, prefix_sharing=True,
        temperature=0.5, top_k=3, seed=11, prefill_budget=2,
    )
    # empty call -> pure defaults, no warning
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert from_kwargs() == (CacheConfig(), EngineConfig())


def test_from_kwargs_warns_once_per_call_site():
    def legacy_site():
        return from_kwargs(layout="paged", page_size=4)

    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("default")
        legacy_site()
        legacy_site()  # same call site: the "default" filter dedupes
    msgs = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(msgs) == 1
    assert "CacheConfig" in str(msgs[0].message)


def test_from_kwargs_rejects_unknown_keys():
    with pytest.raises(TypeError, match="unknown engine kwargs"):
        from_kwargs(layotu="paged")


def test_legacy_kwargs_equal_config_objects():
    """An engine built from the kwarg pile produces byte-identical output
    to one built from the config objects (the adapter is semantics-free)."""
    cfg = _cfg("qwen2.5-3b")
    model, params = _model_params(cfg)
    reqs = _requests(cfg, n=2)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        eng = ServingEngine(
            model, params, batch=2, max_len=24,
            layout="paged", page_size=4, steps_per_sync=3, prefill_chunk=4,
        )
    hs = [eng.submit(t, g) for t, g in reqs]
    legacy = [eng.run()[h].tolist() for h in hs]
    _, typed = _drain(
        model, params, reqs,
        cache=CacheConfig(layout="paged", page_size=4),
        config=EngineConfig(steps_per_sync=3, prefill_chunk=4),
        audit=False,
    )
    assert legacy == typed
    assert eng.cache == CacheConfig(layout="paged", page_size=4)
    assert eng.config.steps_per_sync == 3


def test_engine_rejects_mixing_legacy_and_config():
    cfg = _cfg("qwen2.5-3b")
    model, params = _model_params(cfg)
    with pytest.raises(TypeError, match="not both"):
        ServingEngine(
            model, params, batch=2, max_len=16,
            cache=CacheConfig(), layout="paged",
        )


@pytest.mark.parametrize(
    "build, msg",
    [
        (lambda: CacheConfig(layout="ring"), "unknown KV-cache layout"),
        (lambda: CacheConfig(page_size=0), "page_size must be >= 1"),
        (
            lambda: CacheConfig(snapshots=True),
            "layout='paged' required",
        ),
        (lambda: SpecConfig(k=0), "spec.k must be >= 1"),
        (lambda: SpecConfig(drafter="oracle"), "unknown drafter"),
        (lambda: SpecConfig(ngram=0), "spec.ngram must be >= 1"),
        (
            lambda: EngineConfig(steps_per_sync=0),
            "steps_per_sync must be >= 1",
        ),
        (
            lambda: EngineConfig(prefill_budget=-1),
            "prefill_budget must be >= 0",
        ),
        (lambda: EngineConfig(top_k=-1), "top_k must be >= 0"),
    ],
)
def test_invalid_config_fields_raise(build, msg):
    with pytest.raises(ValueError, match=msg):
        build()


@pytest.mark.parametrize(
    "cache, config, msg",
    [
        (
            CacheConfig(),
            EngineConfig(prefix_sharing=True),
            "prefix sharing needs layout='paged'",
        ),
        (
            CacheConfig(layout="paged"),
            EngineConfig(prefill_chunk=1, spec=SpecConfig()),
            "prefill_chunk must be >= 2",
        ),
        (
            CacheConfig(layout="paged"),
            EngineConfig(
                prefill_chunk=4, temperature=1.0, spec=SpecConfig()
            ),
            "greedy-only",
        ),
        (
            CacheConfig(layout="paged"),
            EngineConfig(
                prefill_chunk=4, prefix_sharing=True,
                spec=SpecConfig(drafter="hybrid_ssm"),
            ),
            "incompatible with prefix_sharing",
        ),
    ],
)
def test_invalid_config_combinations_raise(cache, config, msg):
    with pytest.raises(ValueError, match=msg):
        validate_configs(cache, config)


def test_hybrid_ssm_drafter_requires_hybrid_family():
    cfg = _cfg("qwen2.5-3b")
    with pytest.raises(ValueError, match="family 'hybrid' required"):
        HybridSSMDrafter(SpecConfig(drafter="hybrid_ssm"), cfg)


def test_configs_from_flags_reads_spec_knobs():
    import argparse

    ns = argparse.Namespace(
        layout="paged", page_size=8, steps_per_sync=4, prefill_chunk=4,
        spec_k=3, spec_drafter="prompt_lookup", spec_ngram=2,
    )
    cache, config = configs_from_flags(ns)
    assert cache == CacheConfig(layout="paged", page_size=8)
    assert config.spec == SpecConfig(k=3, ngram=2)
    cache2, config2 = configs_from_flags(argparse.Namespace())
    assert (cache2, config2) == (CacheConfig(), EngineConfig())


# ---------------------------------------------------------------------------
# submit surface: Request specs, handles, real-clock deadlines
# ---------------------------------------------------------------------------


def test_submit_returns_usable_handle():
    cfg = _cfg("qwen2.5-3b")
    model, params = _model_params(cfg)
    eng = ServingEngine(
        model, params, batch=2, max_len=16,
        config=EngineConfig(steps_per_sync=3),
    )
    h = eng.submit([1, 2, 3], 4)
    assert isinstance(h, RequestHandle)
    assert h == 0 and h.rid == 0  # the handle *is* the rid
    out = eng.run()
    assert out[h].tolist() == out[0].tolist()  # indexable by either


def test_submit_accepts_request_spec():
    cfg = _cfg("qwen2.5-3b")
    model, params = _model_params(cfg)
    eng = ServingEngine(
        model, params, batch=2, max_len=16,
        config=EngineConfig(steps_per_sync=3),
    )
    h = eng.submit(Request.spec([1, 2, 3, 4], 6, priority=2))
    with pytest.raises(TypeError, match="must not also be passed"):
        eng.submit(Request.spec([1, 2], 3), 5)
    with pytest.raises(TypeError, match="needs max_new_tokens"):
        eng.submit([1, 2])
    out = eng.run()
    assert len(out[h]) == 6


def test_handle_cancel_and_deadline_drain():
    cfg = _cfg("qwen2.5-3b")
    model, params = _model_params(cfg)
    eng = ServingEngine(
        model, params, batch=2, max_len=16,
        config=EngineConfig(steps_per_sync=3),
    )
    keep = eng.submit([1, 2, 3], 4)
    gone = eng.submit([4, 5, 6], 4)
    assert gone.cancel() is True
    late = eng.submit([7, 8], 4, deadline_ms=0.0)
    time.sleep(0.005)  # the deadline clock is real (perf_counter)
    out = eng.run()
    assert keep.rid in out and len(out[keep]) == 4
    assert gone.rid in eng.cancelled and gone.rid not in out
    assert late.rid in eng.expired and late.rid not in out


def test_generous_deadline_completes():
    cfg = _cfg("qwen2.5-3b")
    model, params = _model_params(cfg)
    out = None
    eng = ServingEngine(
        model, params, batch=2, max_len=16,
        config=EngineConfig(steps_per_sync=3),
    )
    h = eng.submit([1, 2, 3], 4, deadline_ms=60_000.0)
    out = eng.run()
    assert len(out[h]) == 4 and not eng.expired


def test_serve_all_takes_config_objects():
    cfg = _cfg("qwen2.5-3b")
    model, params = _model_params(cfg)
    outs = serve_all(
        model, params, [([1, 2, 3], 4)], batch=2, max_len=16,
        config=EngineConfig(
            steps_per_sync=2, prefill_chunk=4, spec=SpecConfig(k=2)
        ),
    )
    assert len(outs[0]) == 4
