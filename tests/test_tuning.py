"""Tests for the autotuning stack: shape-class bucketing, the five-layer
``get_tuning`` precedence, table schema + persistence round-trips (same
process, fresh process via ``REPRO_TUNING_TABLE``, and a real smoke sweep
through ``repro.tuning.autotune``), the committed artifacts
(``tuning_table.json``, ``BENCH_*.json``), and the perf-trajectory
checker's regression detection."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core import registry
from repro.core.registry import (
    clear_tuning,
    get_tuning,
    last_resolved,
    set_tuning,
    tuning_overrides,
    tuning_table,
)
from repro.tuning import table as tt
from repro.tuning.shapes import bucket, parse_shape_class, shape_class

REPO = Path(__file__).resolve().parents[1]
if str(REPO) not in sys.path:          # for benchmarks.perf_snapshot
    sys.path.insert(0, str(REPO))

from benchmarks.perf_snapshot import (  # noqa: E402
    compare,
    validate_bench,
)


@pytest.fixture(autouse=True)
def _clean_tuning():
    clear_tuning()
    yield
    clear_tuning()


# ---------------------------------------------------------------------------
# shape classes
# ---------------------------------------------------------------------------

def test_bucket_pow2_ceiling():
    assert [bucket(n) for n in (1, 2, 3, 7, 8, 9, 1000)] == [
        1, 2, 4, 8, 8, 16, 1024,
    ]


def test_shape_class_deterministic_and_order_free():
    a = shape_class(m=48, n=256, k=200)
    b = shape_class(k=200, m=48, n=256)
    assert a == b == "k256.m64.n256"
    assert parse_shape_class(a) == {"k": 256, "m": 64, "n": 256}


def test_shape_class_bucketing_stable_within_bucket():
    # every size in (64, 128] lands in the same class -> same table cell
    assert len({shape_class(m=m) for m in range(65, 129)}) == 1


def test_shape_class_rejects_empty():
    with pytest.raises(ValueError):
        shape_class()


def test_kernel_call_site_agrees_with_driver_classification():
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels.gemm import gemm_pallas

    a = jnp.ones((48, 200), jnp.float32)
    b = jnp.ones((200, 96), jnp.float32)
    with tuning_table(None):
        gemm_pallas(a, b, interpret=True)
    assert last_resolved("gemm") == shape_class(m=48, n=96, k=200)


# ---------------------------------------------------------------------------
# get_tuning precedence
# ---------------------------------------------------------------------------

def test_precedence_call_site_defaults_lowest():
    with tuning_table(None):
        assert get_tuning("nosuch", key="m8", bm=128) == {"bm": 128}


def test_precedence_table_beats_call_site_defaults():
    with tuning_table({("gemm", "m8"): {"bm": 32}}):
        assert get_tuning("gemm", key="m8", bm=128) == {"bm": 32}


def test_precedence_table_class_beats_table_default():
    with tuning_table({("gemm", "default"): {"bm": 64},
                       ("gemm", "m8"): {"bm": 32}}):
        assert get_tuning("gemm", key="m8", bm=128) == {"bm": 32}
        # a class the table misses falls back to the table default
        assert get_tuning("gemm", key="m999", bm=128) == {"bm": 64}


def test_precedence_set_tuning_beats_table():
    # tests/experiments force values with set_tuning; the committed table
    # must never shadow them
    with tuning_table({("gemm", "m8"): {"bm": 32}}):
        set_tuning("gemm", "default", bm=16)
        assert get_tuning("gemm", key="m8", bm=128) == {"bm": 16}
        set_tuning("gemm", "m8", bm=8)
        assert get_tuning("gemm", key="m8", bm=128) == {"bm": 8}


def test_precedence_key_miss_falls_back_cleanly():
    with tuning_table({("gemm", "m8"): {"bm": 32}}):
        # unknown class, no defaults anywhere -> call-site values survive
        assert get_tuning("gemm", key="zz9", bm=128, bk=64) == {
            "bm": 128, "bk": 64,
        }


def test_tuning_overrides_scoped():
    with tuning_table(None):
        with tuning_overrides("gemm", "m8", bm=4):
            assert get_tuning("gemm", key="m8", bm=128) == {"bm": 4}
        assert get_tuning("gemm", key="m8", bm=128) == {"bm": 128}


def test_partial_table_entry_merges_over_defaults():
    with tuning_table({("gemm", "m8"): {"bm": 32}}):
        out = get_tuning("gemm", key="m8", bm=128, bn=256, bk=512)
        assert out == {"bm": 32, "bn": 256, "bk": 512}


# ---------------------------------------------------------------------------
# table schema + persistence
# ---------------------------------------------------------------------------

def test_validate_rejects_bad_documents():
    assert tt.validate([]) != []
    assert any("schema" in e for e in tt.validate({"schema": 99}))
    doc = tt.empty_doc()
    doc["entries"] = {"gemm": {"m8": {"params": {}}}}
    assert any("params" in e for e in tt.validate(doc))
    doc["entries"] = {"gemm": {"m8": {"params": {"bm": "big"}}}}
    assert any("int" in e for e in tt.validate(doc))
    doc["entries"] = {"gemm": {"m8": {"params": {"bm": 32}, "ms": "fast"}}}
    assert any("ms" in e for e in tt.validate(doc))
    doc = tt.empty_doc()
    doc["cells"] = [{"op": "matmul"}]          # missing status
    assert any("cells" in e for e in tt.validate(doc))


def test_save_load_roundtrip(tmp_path):
    doc = tt.empty_doc()
    doc["entries"] = {"gemm": {"m8": {"params": {"bm": 32}, "ms": 0.5}}}
    doc["cells"] = [{"op": "matmul", "status": "swept"}]
    path = tt.save(doc, tmp_path / "t.json")
    assert tt.load(path) == doc
    assert tt.flatten(doc) == {("gemm", "m8"): {"bm": 32}}


def test_save_refuses_invalid(tmp_path):
    doc = tt.empty_doc()
    doc["schema"] = 99
    with pytest.raises(ValueError):
        tt.save(doc, tmp_path / "t.json")


def test_fresh_process_resolves_from_env_table(tmp_path):
    """autotune -> persist -> a *new* process resolves the swept value."""
    doc = tt.empty_doc()
    doc["entries"] = {"gemm": {"m8.n8": {"params": {"bm": 7}}}}
    path = tt.save(doc, tmp_path / "t.json")
    code = (
        "from repro.core.registry import get_tuning;"
        "print(get_tuning('gemm', key='m8.n8', bm=128)['bm'])"
    )
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, check=True,
        env={"PYTHONPATH": str(REPO / "src"),
             "REPRO_TUNING_TABLE": str(path),
             "PATH": "/usr/bin:/bin"},
    )
    assert out.stdout.strip() == "7"


def test_env_var_empty_disables_table(monkeypatch):
    monkeypatch.setenv(tt.ENV_VAR, "")
    assert tt.resolved_path() is None


def test_autotune_smoke_roundtrip(tmp_path):
    """A real (smoke) sweep produces a valid, loadable, resolvable table."""
    from repro.tuning.autotune import run_autotune

    doc = run_autotune(smoke=True, only=["rmsnorm"], repeats=1)
    assert tt.validate(doc) == []
    cells = {c["op"]: c["status"] for c in doc["cells"]}
    assert cells["rmsnorm"] == "swept"
    assert cells["avgpool"] == "reference_only"
    assert cells["im2col"] == "no-knobs"
    assert cells["matmul"] == "skipped"
    path = tt.save(doc, tmp_path / "t.json")
    loaded = tt.flatten(tt.load(path))
    if loaded:  # defaults may win the sweep; if not, values must resolve
        (op, cls), params = sorted(loaded.items())[0]
        with tuning_table(loaded):
            assert get_tuning(op, key=cls) == params


def test_autotune_cell_enumeration_deterministic():
    from repro.tuning.autotune import enumerate_cells

    a = enumerate_cells()
    b = enumerate_cells()
    assert a == b
    assert [c["op"] for c in a] == sorted(c["op"] for c in a)
    assert {c["status"] for c in a} <= {
        "swept", "no-knobs", "reference_only", "skipped",
    }


def test_candidates_deterministic_and_exclude_baseline():
    from repro.tuning.autotune import candidates

    knobs = {"bm": 128, "bn": 128, "bk": 128}
    a = candidates(knobs, smoke=False)
    assert a == candidates(knobs, smoke=False)
    assert {"bm": 128, "bn": 128, "bk": 128} not in a
    assert all(all(v >= 8 for v in c.values()) for c in a)


def test_committed_table_is_valid_and_lint_clean():
    doc = tt.load(tt.default_path())
    assert tt.validate(doc) == []
    assert doc["entries"], "committed table has no entries"
    from repro.analysis.coverage import table_findings

    assert table_findings(doc) == []


# ---------------------------------------------------------------------------
# BENCH snapshots + trajectory checker
# ---------------------------------------------------------------------------

def _bench_doc():
    return {
        "schema": 1,
        "serving": {"default": {"tok_s": 100.0, "prefill_tok_s": 200.0,
                                "ttft_ms": 50.0, "ttft_ms_p99": 80.0,
                                "kv_bytes": 4096}},
        "ops": {"gemm[m8]": {"case": "decode", "shape_class": "m8",
                             "default_ms": 1.0, "tuned_ms": 0.5,
                             "speedup": 2.0, "roofline_fraction": 0.2}},
        "improved_ops": ["gemm[m8]"],
    }


def test_bench_schema_validation():
    assert validate_bench(_bench_doc()) == []
    bad = _bench_doc()
    del bad["ops"]["gemm[m8]"]["speedup"]
    assert validate_bench(bad) != []
    bad = _bench_doc()
    bad["serving"]["default"]["tok_s"] = "fast"
    assert validate_bench(bad) != []


def test_committed_bench_files_are_valid():
    files = sorted((REPO / "benchmarks" / "trajectory").glob("BENCH_*.json"))
    assert files, "no committed BENCH files"
    for f in files:
        doc = json.loads(f.read_text())
        assert validate_bench(doc) == [], f.name
    latest = json.loads(files[-1].read_text())
    assert len(latest["improved_ops"]) >= 3, (
        "the committed snapshot must show >=3 ops beating their hand-set "
        f"defaults; got {latest['improved_ops']}"
    )


def test_checker_passes_on_identical_snapshot():
    doc = _bench_doc()
    assert compare(doc, doc) == []


def test_checker_fails_on_throughput_collapse():
    old, new = _bench_doc(), _bench_doc()
    new["serving"]["default"]["tok_s"] = 10.0       # 10x collapse
    assert any("tok_s" in r for r in compare(old, new))


def test_checker_fails_on_kv_bytes_change():
    old, new = _bench_doc(), _bench_doc()
    new["serving"]["default"]["kv_bytes"] = 8192
    assert any("kv_bytes" in r for r in compare(old, new))


def test_checker_fails_on_roofline_shift():
    old, new = _bench_doc(), _bench_doc()
    new["ops"]["gemm[m8]"]["roofline_fraction"] = 0.5
    assert any("roofline_fraction" in r for r in compare(old, new))


def test_checker_fails_when_table_slows_op_down():
    old, new = _bench_doc(), _bench_doc()
    new["ops"]["gemm[m8]"]["speedup"] = 0.3
    assert any("speedup" in r for r in compare(old, new))


def test_checker_tolerates_timing_noise():
    old, new = _bench_doc(), _bench_doc()
    new["serving"]["default"]["tok_s"] = 80.0        # within the band
    new["ops"]["gemm[m8]"]["tuned_ms"] = 0.6
    assert compare(old, new) == []


def test_checker_ignores_cells_only_on_one_side():
    old, new = _bench_doc(), _bench_doc()
    new["ops"]["newop[x]"] = new["ops"]["gemm[m8]"]
    del new["ops"]["gemm[m8]"]
    old["serving"]["gone"] = old["serving"]["default"]
    assert compare(old, new) == []


def test_last_resolved_tracks_latest_key():
    with tuning_table(None):
        get_tuning("gemm", key="aaa", bm=1)
        assert last_resolved("gemm") == "aaa"
        get_tuning("gemm", key="bbb", bm=1)
        assert last_resolved("gemm") == "bbb"
    assert registry.last_resolved("never-called-op") is None
