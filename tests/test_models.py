"""Per-architecture smoke tests: every assigned arch instantiates its
REDUCED config and runs one forward/train step + one decode step on CPU,
asserting output shapes and no NaNs (full configs are exercised only via
the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, input_specs
from repro.configs.registry import all_archs, arch_ids, get_arch
from repro.models import lm as LM
from repro.models.model import build_model

ARCHS = arch_ids()


def _batch_for(cfg, b=2, s=33):
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.n_vision_tokens, cfg.d_model),
            cfg.dtype_())
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (b, 8, cfg.d_model), cfg.dtype_())
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_arch(arch).reduced()
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    loss = m.train_loss(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), (arch, loss)
    # one grad step must stay finite
    g = jax.grad(m.train_loss)(params, batch)
    assert all(jnp.isfinite(x).all() for x in jax.tree.leaves(g)), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_arch(arch).reduced()
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    state = m.init_decode_state(2, 64)
    if cfg.family == "vlm":
        vision = jax.random.normal(
            jax.random.PRNGKey(2), (2, cfg.n_vision_tokens, cfg.d_model),
            cfg.dtype_())
        state = LM.prefill_vlm_cross_cache(cfg, params, vision, state)
    logits, state = m.decode_step(params, state, jnp.array([1, 2]))
    assert logits.shape == (2, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), arch
    assert int(state["pos"]) == 1
    # second step advances
    logits2, state = m.decode_step(
        params, state, jnp.argmax(logits, -1).astype(jnp.int32)
    )
    assert int(state["pos"]) == 2
    assert jnp.isfinite(logits2).all()


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-2.7b", "zamba2-2.7b",
                                  "mixtral-8x7b"])
def test_decode_matches_teacher_forcing(arch):
    """Incremental decode through the cache == full forward at the last
    position (the paper's layer-by-layer regression discipline, applied to
    the serving path)."""
    cfg = get_arch(arch).reduced()
    # float32 for a tight comparison; no-drop MoE capacity so the capacity-
    # dropping train path and the per-token decode path route identically
    # (capacity dropping is a train-only semantics: DESIGN.md §MoE)
    import dataclasses
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        capacity_factor=float(max(cfg.n_experts, 1)),
    )
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, cfg.vocab_size)
    h = LM.forward(cfg, params, toks, remat=False)
    want = LM.lm_logits(cfg, params, h[:, -1:, :])[:, 0]
    state = m.init_decode_state(2, 16)
    got = None
    for i in range(9):
        got, state = m.decode_step(params, state, toks[:, i])
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3
    )


def test_sliding_window_cache_is_bounded():
    """Mixtral's SWA decode cache is a ring buffer of size window, not
    seq_len — the long_500k enabler."""
    cfg = get_arch("mixtral-8x7b").reduced()
    m = build_model(cfg)
    state = m.init_decode_state(2, 10_000)
    assert state["k"].shape[2] == cfg.window  # bounded by window


def test_param_count_matches_known_sizes():
    known = {
        "llama-3.2-vision-90b": 90e9,
        "deepseek-coder-33b": 33e9,
        "internlm2-20b": 20e9,
        "glm4-9b": 9.4e9,
        "mixtral-8x7b": 47e9,
        "qwen3-moe-235b-a22b": 235e9,
        "mamba2-2.7b": 2.7e9,
        "zamba2-2.7b": 2.7e9,
    }
    for arch, want in known.items():
        got = get_arch(arch).param_count()
        assert 0.8 * want < got < 1.25 * want, (arch, got, want)


def test_active_params_moe():
    mix = get_arch("mixtral-8x7b")
    assert mix.active_param_count() < 0.35 * mix.param_count()
    q3 = get_arch("qwen3-moe-235b-a22b")
    assert 18e9 < q3.active_param_count() < 26e9


def test_input_specs_cover_all_cells():
    count = 0
    for arch, cfg in all_archs().items():
        for name, shape in SHAPES.items():
            specs = input_specs(cfg, shape)
            assert specs, (arch, name)
            for v in specs.values():
                assert isinstance(v, jax.ShapeDtypeStruct)
            count += 1
    assert count == 40  # the full assigned grid


def test_long_500k_policy():
    """Sub-quadratic archs run long_500k; pure full-attention archs skip."""
    runs = {a for a, c in all_archs().items() if c.supports("long_500k")}
    assert runs == {"mamba2-2.7b", "zamba2-2.7b", "mixtral-8x7b"}
