"""Known-bad R007 fixture: quantized-KV scale pools cast below f32.
Linted under the virtual path ``src/repro/serving/pager.py``."""
import jax.numpy as jnp


def write(pool, ksc, new):
    return pool, ksc.astype(jnp.bfloat16)  # R007


def spill(hksc, out_dtype):
    return hksc.astype(out_dtype)  # R007: non-f32 target dtype


def dequant(k_pages, k_scale):
    return k_pages.astype(jnp.float32) * k_scale.astype(jnp.float16)  # R007
