"""Known-good R004 fixture: the scoped, thread-local backend stack."""
from repro.core import use_backend


def run_scoped(fn):
    with use_backend("pallas"):
        return fn()
