"""Known-bad R003 fixture: ``jax.jit`` in serving/ without donation.
Linted under the virtual path ``src/repro/serving/engine.py``."""
import jax


def build(step_fn):
    return jax.jit(step_fn)  # R003: no donate_argnums


@jax.jit  # R003: bare decorator cannot donate
def decorated(state):
    return state
