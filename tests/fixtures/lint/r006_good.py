"""Known-good R006 fixture: serving code takes the typed config objects
(one raw knob on a helper signature is below the pile threshold)."""


def build_engine(model, params, cache=None, config=None):
    return model, params, cache, config


def make_state(batch, max_len, page_size=16):
    # a single layout-adjacent knob on an internal helper is fine; two or
    # more is the pile R006 exists to stop
    return batch, max_len, page_size
