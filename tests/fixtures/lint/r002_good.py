"""Known-good R002 fixture: step choice stays on the device / host
mirror, and the one sanctioned sync lives in the ``step`` harvest."""
import jax
import jax.numpy as jnp


def _chunk_limit(mstate):
    return jnp.minimum(mstate["budget"], 8)


def engine_step(state, toks):
    halt = jnp.where(state["halt"], 0, toks)
    return halt, state


def step(fetch):
    # the steps_per_sync harvest: explicit, batched, allowlisted
    got = list(jax.device_get(tuple(fetch)))
    return int(got[0])
