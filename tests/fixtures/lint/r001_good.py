"""Known-good R001 fixture: the post-fix header — tpu-namespace symbols
routed through ``pallas_compat``."""
import jax.numpy as jnp
from jax.experimental import pallas as pl  # noqa: F401 - allowed
from repro.kernels import pallas_compat as plc


def scratch_shapes(bq, d):
    return [
        plc.VMEM((bq, d), jnp.float32),
        plc.VMEM((bq, 1), jnp.float32),
    ]
