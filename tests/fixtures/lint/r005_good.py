"""Known-good R005 fixture: scan state stays f32; non-state values may
cast freely."""
import jax.numpy as jnp


def finalize(hf_ref, state_ref):
    hf_ref[0, 0] = state_ref[...].astype(jnp.float32)


def project(y, x):
    return y.astype(x.dtype)  # not scan state: no finding
