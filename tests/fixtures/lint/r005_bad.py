"""Known-bad R005 fixture: SSD scan state cast below f32.  Linted under
the virtual path ``src/repro/kernels/mamba_scan.py``."""
import jax.numpy as jnp


def finalize(hf_ref, state_ref):
    hf_ref[0, 0] = state_ref[...].astype(jnp.bfloat16)  # R005


def carry(ssm_state, out_dtype):
    return ssm_state.astype(out_dtype)  # R005: non-f32 target dtype
