"""Known-good R007 fixture: scale pools stay f32; the int8 payload and
unrelated values cast freely."""
import jax.numpy as jnp


def write(pool, ksc, new):
    return pool.astype(jnp.int8), ksc.astype(jnp.float32)


def dequant(k_pages, k_scale):
    return k_pages.astype(jnp.float32) * k_scale  # payload upcast: fine


def project(y, x):
    return y.astype(x.dtype)  # not a scale pool: no finding
