"""Known-bad R006 fixture: serving code re-growing the raw layout kwarg
pile ``CacheConfig`` replaced.  Linted under the virtual path
``src/repro/serving/engine.py``."""


def build_engine(model, params, layout="contiguous"):  # R006: raw layout=
    return model, params, layout


def make_state(batch, max_len, page_size=16, n_pages=None):  # R006: pile
    return batch, max_len, page_size, n_pages
