"""Known-bad R004 fixture: library code mutating the process-wide
backend.  Linted under the virtual path ``src/repro/serving/worker.py``."""
from repro.core import set_default_backend


def setup_worker():
    set_default_backend("pallas")  # R004: leaks across worker threads
