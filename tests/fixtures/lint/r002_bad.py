"""Known-bad R002 fixture: implicit host syncs in scheduler / step-path
functions.  Linted under the virtual path ``src/repro/serving/engine.py``."""
import numpy as np


def _chunk_limit(mstate):
    budget = mstate["budget"]
    return int(budget)  # R002: int() on a device value


def engine_step(state, toks):
    flag = state["halt"].item()  # R002: .item()
    mirror = np.asarray(state["active"])  # R002: np.asarray on device array
    return flag, mirror


def outside_scope(x):
    return int(x)  # not a scoped function: no finding here
