"""Known-good R003 fixture: every serving jit site donates its state."""
import jax


def build(step_fn):
    return jax.jit(step_fn, donate_argnums=(1, 2))


def build_named(step_fn):
    return jax.jit(step_fn, donate_argnames=("state",))
