"""Known-bad R001 fixture: the pre-fix ``flash_attention.py`` header —
the seeded violation this PR removed, preserved so the rule provably
catches it.  Linted under the virtual path
``src/repro/kernels/flash_attention.py``."""
import jax.numpy as jnp
from jax.experimental import pallas as pl  # noqa: F401 - allowed
from jax.experimental.pallas import tpu as pltpu  # R001 fires here


def scratch_shapes(bq, d):
    return [
        pltpu.VMEM((bq, d), jnp.float32),
        pltpu.VMEM((bq, 1), jnp.float32),
    ]
