"""Roofline machinery tests: the trip-count-aware HLO cost model is
validated against XLA's own cost_analysis (loop-free) and against analytic
flop counts (scans)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import Roofline
from repro.roofline.hlo_cost import cost_from_hlo_text


def test_loop_free_matches_xla():
    f = jax.jit(lambda a, b: jnp.tanh(a @ b))
    comp = f.lower(
        jax.ShapeDtypeStruct((256, 512), jnp.float32),
        jax.ShapeDtypeStruct((512, 128), jnp.float32),
    ).compile()
    c = comp.cost_analysis()
    c = c[0] if isinstance(c, (list, tuple)) else c
    mine = cost_from_hlo_text(comp.as_text())
    assert abs(mine.flops - c["flops"]) / c["flops"] < 0.05
    assert abs(mine.bytes - c["bytes accessed"]) / c["bytes accessed"] < 0.2


def test_scan_trip_count_scaling():
    def g(c0, xs):
        def body(c, x):
            return c @ x, None
        y, _ = jax.lax.scan(body, c0, xs)
        return y

    comp = jax.jit(g).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((7, 128, 128), jnp.float32),
    ).compile()
    mine = cost_from_hlo_text(comp.as_text())
    analytic = 7 * 2 * 128**3
    assert abs(mine.flops - analytic) / analytic < 0.01
    # XLA counts the body once — our whole point
    c = comp.cost_analysis()
    c = c[0] if isinstance(c, (list, tuple)) else c
    assert c["flops"] < analytic / 2


def test_nested_scan():
    def g(c0, xs):
        def outer(c, x):
            def inner(ci, _):
                return ci @ x, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, c0, xs)
        return y

    comp = jax.jit(g).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((5, 64, 64), jnp.float32),
    ).compile()
    mine = cost_from_hlo_text(comp.as_text())
    analytic = 5 * 3 * 2 * 64**3
    assert abs(mine.flops - analytic) / analytic < 0.02


def test_model_flops_ratio_sane_on_lm():
    """Compiled-vs-analytic flops for a reduced LM train step: the compiled
    program should be within [1x, 3x] of 6*N*D (remat + attention extra)."""
    from repro.configs.base import ShapeSpec
    from repro.configs.registry import get_arch
    from repro.launch.steps import make_train_step, train_state_shape
    from repro.optim.optimizers import OptConfig
    from repro.configs.base import input_specs

    cfg = get_arch("qwen2.5-3b").reduced()
    shape = ShapeSpec("t", 64, 4, "train")
    opt = OptConfig()
    step = make_train_step(cfg, opt)
    st = train_state_shape(cfg, opt)
    bs = input_specs(cfg, shape)
    comp = jax.jit(step).lower(st, bs).compile()
    mine = cost_from_hlo_text(comp.as_text())
    analytic = 6.0 * cfg.param_count() * shape.seq_len * shape.global_batch
    # embeddings dominate tiny configs; just require the right ballpark
    assert mine.flops > 0.5 * analytic
    assert mine.flops < 10 * analytic


def test_roofline_terms_and_bottleneck():
    r = Roofline(
        arch="a", shape="s", mesh="16x16", chips=256,
        hlo_flops=197e12, hlo_bytes=819e9 * 2, collective_bytes=50e9 * 0.5,
        collective_count=3, model_flops=197e12 * 256 * 0.5,
    )
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.bottleneck == "memory"
    assert r.roofline_fraction == pytest.approx(0.5)
    assert r.useful_flops_ratio == pytest.approx(0.5)


def test_collectives_counted_in_loops():
    from jax.sharding import NamedSharding, PartitionSpec as P

    if jax.device_count() < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1,), ("x",))

    def h(c0, xs):
        def body(c, x):
            return jax.lax.with_sharding_constraint(
                c @ x, NamedSharding(mesh, P())
            ), None
        y, _ = jax.lax.scan(body, c0, xs)
        return y

    comp = jax.jit(h).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
        jax.ShapeDtypeStruct((4, 32, 32), jnp.float32),
    ).compile()
    mine = cost_from_hlo_text(comp.as_text())
    assert mine.flops == pytest.approx(4 * 2 * 32**3, rel=0.01)
