"""Suite-wide fixtures.

Every XLA-CPU executable keeps an mmap'd code region alive for as long
as jax's internal caches reference it, and the kernel caps a process at
``vm.max_map_count`` regions (65530 by default).  A full ``pytest -x``
run compiles enough engine/kernel variants to cross that cap, at which
point the *next* compile segfaults inside LLVM's section allocator —
deterministically, at whatever test the cumulative count happens to
land on.  Dropping the caches at module boundaries keeps the map count
bounded; the only cost is recompiling jits that would not have been
shared across modules anyway.
"""
import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _bound_jax_code_maps():
    yield
    jax.clear_caches()
