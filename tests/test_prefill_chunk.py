"""Chunked-prefill tests — multi-token prompt ingestion must be
token-identical to token-by-token ingestion through the whole decode stack
(kernel, ops dispatch, pager, model, engine), across KV layouts and
backends, including chunk widths that don't divide the prompt length and
requests admitted mid-stream into a busy batch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import use_backend
from repro.configs.registry import get_arch
from repro.kernels.flash_attention import (
    flash_prefill_chunk_paged_pallas,
    flash_prefill_chunk_pallas,
)
from repro.kernels.ops import (
    _attention_prefill_chunk_paged_ref,
    _attention_prefill_chunk_ref,
)
from repro.models.model import build_model
from repro.serving import ServingEngine
from repro.serving.pager import (
    PagerState,
    alloc_range,
    init_block_table,
    init_pager,
    write_page_chunk,
)

BACKENDS = ["reference", "pallas"]
# one dense, one moe, one hybrid, one pure ssm: the chunk path must cover
# chunked attention, chunked MoE dispatch, and the chunked SSD scan with
# carried recurrent state (decode is its C=1 case — same accumulation
# order, so chunked and token-by-token ingestion stay token-identical)
CHUNK_ARCHS = ["qwen2.5-3b", "qwen3-moe-235b-a22b", "zamba2-2.7b",
               "mamba2-2.7b"]


def _cfg(arch):
    cfg = get_arch(arch).reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.n_experts:
        # chunked steps route B*C tokens where decode routes B; only the
        # no-drop regime is batch-composition-independent (engine docstring)
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    return cfg


def _model_params(arch):
    cfg = _cfg(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _serve(model, params, reqs, **kw):
    eng = ServingEngine(model, params, batch=2, max_len=16,
                        steps_per_sync=3, **kw)
    rids = [eng.submit(t, g) for t, g in reqs]
    got = eng.run()
    return eng, [got[r].tolist() for r in rids]


# -- kernel <-> oracle lock-step --------------------------------------------

@pytest.mark.parametrize("window", [None, 6])
def test_prefill_chunk_kernel_matches_oracle(window):
    """The Pallas chunk kernels and the jnp oracles must agree on both
    layouts, including per-row starts/widths (padding rows) and windows."""
    b, c, hq, hkv, d, smax = 3, 5, 4, 2, 8, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (b, c, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, smax, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, smax, hkv, d), jnp.float32)
    start = jnp.asarray([0, 7, 20], jnp.int32)
    width = jnp.asarray([5, 3, 1], jnp.int32)
    want = _attention_prefill_chunk_ref(q, k, v, start, width, window=window)
    got = flash_prefill_chunk_pallas(q, k, v, start, width, window=window,
                                     interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)

    # paged: same math through a block table over a shared pool
    page, n_pages, maxb = 4, 12, 8
    kp = jax.random.normal(ks[1], (n_pages, page, hkv, d), jnp.float32)
    vp = jax.random.normal(ks[3], (n_pages, page, hkv, d), jnp.float32)
    bt = np.full((b, maxb), -1, np.int32)
    bt[0, :2] = [0, 1]
    bt[1, :3] = [2, 3, 4]
    bt[2, :6] = [5, 6, 7, 8, 9, 10]
    bt = jnp.asarray(bt)
    want = _attention_prefill_chunk_paged_ref(q, kp, vp, start, width, bt,
                                              window=window)
    got = flash_prefill_chunk_paged_pallas(q, kp, vp, start, width, bt,
                                           window=window, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_prefill_chunk_kernel_tiled_and_padded():
    """Force small KV tiles (bk=8) on a non-multiple cache length so the
    kernel walks several tiles and a padded tail — padded keys must stay
    masked for every chunk row."""
    from repro.core.registry import clear_tuning, set_tuning

    b, c, hq, hkv, d, smax = 2, 4, 4, 2, 8, 27
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (b, c, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, smax, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, smax, hkv, d), jnp.float32)
    start = jnp.asarray([23, 10], jnp.int32)
    width = jnp.asarray([4, 2], jnp.int32)
    want = _attention_prefill_chunk_ref(q, k, v, start, width)
    set_tuning("flash_prefill", bk=8)
    try:
        got = flash_prefill_chunk_pallas(q, k, v, start, width,
                                         interpret=True)
    finally:
        clear_tuning()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# -- pager: multi-page-per-step allocation ----------------------------------

def test_alloc_range_maps_exact_blocks_and_conserves():
    """alloc_range must map exactly the blocks covering start..end (per-row
    widths), keep the free-list/block-table partition intact, and compose
    with write_page_chunk so padding positions never touch the pool."""
    page_size, n_pages, b, maxb = 4, 16, 3, 6
    pager = init_pager(n_pages)
    bt = init_block_table(b, maxb)
    start = jnp.asarray([0, 6, 21], jnp.int32)
    width = jnp.asarray([7, 1, 3], jnp.int32)   # rows straddle 2 / 1 / 1 blk
    pager, bt = alloc_range(pager, bt, start, start + width - 1,
                            page_size=page_size, max_chunk=8)
    bt_np = np.asarray(bt)
    mapped = [sorted(np.nonzero(r >= 0)[0].tolist()) for r in bt_np]
    assert mapped == [[0, 1], [1], [5]]
    n_mapped = int((bt_np >= 0).sum())
    assert int(pager.top) == n_pages - n_mapped
    # partition: free prefix + mapped pages == all pages, no duplicates
    owned = sorted(
        np.asarray(pager.free)[: int(pager.top)].tolist()
        + bt_np[bt_np >= 0].tolist()
    )
    assert owned == list(range(n_pages))
    # chunk write: padding (i >= width) and unmapped blocks must drop
    pool = jnp.zeros((n_pages, page_size, 1, 2), jnp.float32)
    new = jnp.ones((b, 8, 1, 2), jnp.float32)
    pool = write_page_chunk(pool, new, bt, start, width)
    written = int((np.asarray(pool) != 0).sum() // 2)
    assert written == int(width.sum())


# -- engine: chunked == token-by-token --------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("arch", CHUNK_ARCHS)
def test_chunked_prefill_matches_token_by_token(arch, backend):
    """prefill_chunk=4 over prompts of length 3..9 (widths that don't
    divide the chunk), 5 requests through 2 slots (mid-stream admissions
    at heterogeneous depths), both KV layouts: every generated token must
    equal the token-by-token engine's, and all three jitted entry points
    must stay at cache size 1."""
    cfg, model, params = _model_params(arch)
    rng = np.random.default_rng(17)
    reqs = [
        (rng.integers(0, cfg.vocab_size, size=int(n)).tolist(), 4)
        for n in (5, 9, 3, 7, 6)
    ]
    with use_backend(backend):
        _, base = _serve(model, params, reqs)        # contiguous, unchunked
        for layout in ("contiguous", "paged"):
            kw = {"page_size": 4} if layout == "paged" else {}
            eng, got = _serve(model, params, reqs, layout=layout,
                              prefill_chunk=4, **kw)
            assert got == base, f"{layout} chunked diverges"
            assert eng._step_n._cache_size() == 1
            assert eng._admit._cache_size() == 1
            assert eng._prefill._cache_size() == 1
            assert eng.prefill_steps > 0


def test_mamba_prefill_block_matches_sequential_decode():
    """The recurrent-state unification at the block level: one chunked
    call of ``mamba_prefill_block`` (B*C-row GEMMs + one seeded SSD scan)
    must reproduce the token-sequential ``mamba_decode_block`` — per-row
    non-dividing widths, a zero-width row (carry untouched), carried
    state across consecutive chunks, both backends."""
    from repro.models import components as C

    cfg = _cfg("mamba2-2.7b")
    p = C.init_mamba(cfg, jax.random.PRNGKey(0))
    b, c = 3, 5
    x = jax.random.normal(jax.random.PRNGKey(1), (b, 2 * c, cfg.d_model),
                          jnp.float32)
    widths = np.asarray([[5, 3, 0], [2, 5, 4]])
    for backend in BACKENDS:
        with use_backend(backend):
            ssm = jnp.zeros((b, cfg.ssm_heads, cfg.ssm_head_dim,
                             cfg.ssm_state), jnp.float32)
            conv = jnp.zeros((b, cfg.ssm_conv - 1, cfg.d_inner), jnp.float32)
            s1, s2 = ssm, conv
            ys = []
            for t in range(2 * c):
                w = widths[t // c]
                y, n1, n2 = C.mamba_decode_block(cfg, p, x[:, t], s1, s2)
                vi = jnp.asarray(t % c < w)
                s1 = jnp.where(vi[:, None, None, None], n1, s1)
                s2 = jnp.where(vi[:, None, None], n2, s2)
                ys.append(y)
            ys = jnp.stack(ys, 1)
            c1, c2 = ssm, conv
            for k, w in enumerate(widths):
                xs = x[:, k * c : (k + 1) * c]
                valid = jnp.arange(c)[None, :] < jnp.asarray(w)[:, None]
                yc, c1, c2 = C.mamba_prefill_block(cfg, p, xs, c1, c2, valid)
                for r in range(b):
                    if w[r]:
                        np.testing.assert_allclose(
                            np.asarray(yc[r, : w[r]]),
                            np.asarray(ys[r, k * c : k * c + w[r]]),
                            rtol=2e-4, atol=2e-4,
                        )
            np.testing.assert_allclose(np.asarray(c1), np.asarray(s1),
                                       rtol=2e-4, atol=2e-4)
            np.testing.assert_allclose(np.asarray(c2), np.asarray(s2),
                                       rtol=2e-4, atol=2e-4)


def test_ssd_prefill_chunk_registered_and_tuned():
    """The chunked-SSD serving path is a first-class op: registered with
    both lowerings (coverage reports the port) and tunable — the SSD
    chunk size comes from the tuning table and any setting yields the
    same math (chunk invariance), clamped so short chunks never pad."""
    from repro.core.registry import clear_tuning, coverage, set_tuning
    from repro.kernels import ops

    assert coverage()["ssd_prefill_chunk"] is True
    b, s, h, p, n = 2, 7, 3, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, 1, n))
    C = jax.random.normal(ks[4], (b, s, 1, n))
    st = jax.random.normal(jax.random.PRNGKey(9), (b, h, p, n))
    y0, f0 = ops.ssd_prefill_chunk(x, dt, A, Bm, C, st)
    try:
        for chunk in (1, 3, 64):
            set_tuning("ssd_prefill_chunk", chunk=chunk)
            y, f = ops.ssd_prefill_chunk(x, dt, A, Bm, C, st)
            np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                                       rtol=2e-4, atol=2e-4)
            np.testing.assert_allclose(np.asarray(f), np.asarray(f0),
                                       rtol=2e-4, atol=2e-4)
    finally:
        clear_tuning()


@pytest.mark.parametrize("backend", BACKENDS)
def test_windowed_chunked_prefill_needs_paged(backend):
    """Sliding-window archs: chunking works on the paged layout (absolute
    positions, window applied as masking) and must be token-identical;
    the contiguous ring cache cannot host chunks and is rejected."""
    cfg = _cfg("mixtral-8x7b")
    cfg = dataclasses.replace(cfg, window=5)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        ServingEngine(model, params, batch=2, max_len=16, prefill_chunk=4)
    rng = np.random.default_rng(29)
    reqs = [
        (rng.integers(0, cfg.vocab_size, size=int(n)).tolist(), 4)
        for n in (8, 10, 6, 9)
    ]
    with use_backend(backend):
        _, base = _serve(model, params, reqs, layout="paged", page_size=4)
        _, got = _serve(model, params, reqs, layout="paged", page_size=4,
                        prefill_chunk=4)
    assert got == base


def test_prefill_accounting():
    """The host mirror's byproducts: every request gets a TTFT stamp, the
    ingested-prompt count is exact, and chunked ingestion takes the
    expected ceil(P/C) prefill steps for a lone request."""
    cfg, model, params = _model_params("qwen2.5-3b")
    toks = list(range(1, 10))
    eng = ServingEngine(model, params, batch=2, max_len=16,
                        prefill_chunk=4)
    rid = eng.submit(toks, 3)
    eng.run()
    # P=9, C=4: chunks of 4 and 4; the lone remaining prompt token is just
    # a decode feed, so the scheduler hands it to the fused decode path
    assert eng.prefill_steps == 2
    assert eng.prompt_tokens == len(toks)
    assert rid in eng.ttft and eng.ttft[rid] > 0


def test_sampling_invariant_to_chunk_schedule():
    """Sampled streams must not depend on the step schedule: subkeys are
    fold_in(admission key, position), so chunked ingestion (fewer steps to
    reach a position) draws the same tokens as token-by-token."""
    cfg, model, params = _model_params("qwen2.5-3b")
    reqs = [([3, 5, 7, 2, 9, 4], 5), ([11, 2, 8], 5), ([4, 4, 4, 4, 1], 5)]
    runs = {}
    for pc in (1, 4):
        _, runs[pc] = _serve(model, params, reqs, prefill_chunk=pc,
                             temperature=1.0, top_k=8, seed=42)
    assert runs[1] == runs[4]


def test_encdec_prefill_chunk_matches_decode():
    """encdec keeps signature parity: chunked ingestion reproduces the
    step-by-step decode logits and pos advance, including per-row widths."""
    cfg = dataclasses.replace(get_arch("seamless-m4t-medium").reduced(),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 6), 0,
                              cfg.vocab_size).astype(jnp.int32)
    s = model.init_decode_state(2, 12, per_row_pos=True)
    logits = {}
    for j in range(5):
        l, s = model.decode_step(params, s, toks[:, j])
        logits[j + 1] = l
    # uniform width 5
    s2 = model.init_decode_state(2, 12, per_row_pos=True)
    l2, s2 = model.prefill_chunk(params, s2, toks[:, :5],
                                 jnp.asarray([5, 5], jnp.int32))
    assert s2["pos"].tolist() == [5, 5]
    np.testing.assert_allclose(np.asarray(l2), np.asarray(logits[5]),
                               rtol=1e-5, atol=1e-5)
    # heterogeneous widths: row 1 ingests only 3 tokens
    s3 = model.init_decode_state(2, 12, per_row_pos=True)
    l3, s3 = model.prefill_chunk(params, s3, toks[:, :5],
                                 jnp.asarray([5, 3], jnp.int32))
    assert s3["pos"].tolist() == [5, 3]
    np.testing.assert_allclose(np.asarray(l3[1]), np.asarray(logits[3][1]),
                               rtol=1e-5, atol=1e-5)
