"""Serving-engine tests — continuous batching vs isolated decode, and the
decode-vs-teacher-forced parity check (previously buried behind
``serve.py --check``), both under the REFERENCE and PALLAS(interpret)
backends (the paper's single-source dual-target discipline applied to the
serving path)."""
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Backend, use_backend
from repro.core.policy import current_backend, set_default_backend
from repro.configs.registry import get_arch
from repro.models.model import build_model
from repro.serving import RequestQueue, ServingEngine
from repro.serving.checks import assert_decode_matches_teacher_forced

BACKENDS = ["reference", "pallas"]
# one attention-free (ssm) and one KV-cache (dense) family: the engine's
# per-row positions exercise rope + masked cache writes + per-row lengths
ARCHS = ["mamba2-2.7b", "qwen2.5-3b"]


def _cfg(arch):
    cfg = get_arch(arch).reduced()
    return dataclasses.replace(cfg, dtype="float32")


def _model_params(arch):
    cfg = _cfg(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _isolated_decode(model, params, toks, gen, max_len):
    """Single-request greedy decode — the per-request ground truth."""
    state = model.init_decode_state(1, max_len)
    t = jnp.asarray(toks, jnp.int32)[None]
    logits = None
    for j in range(len(toks)):
        logits, state = model.decode_step(params, state, t[:, j])
    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out.append(int(tok[0]))
    for _ in range(gen - 1):
        logits, state = model.decode_step(params, state, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(tok[0]))
    return np.asarray(out, np.int32)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("arch", ARCHS)
def test_continuous_batching_matches_isolated_decode(arch, backend):
    """Batched, refilled, out-of-phase rows must produce the same tokens as
    each request decoded alone — slot reuse may not leak state."""
    cfg, model, params = _model_params(arch)
    rng = np.random.default_rng(7)
    gen = 4
    reqs = [
        (rng.integers(0, cfg.vocab_size, size=int(rng.integers(3, 8))).tolist(),
         gen)
        for _ in range(5)
    ]
    max_len = 16
    with use_backend(backend):
        eng = ServingEngine(model, params, batch=2, max_len=max_len,
                            steps_per_sync=3)
        rids = [eng.submit(t, g) for t, g in reqs]
        outs = eng.run()
        for (toks, g), rid in zip(reqs, rids):
            want = _isolated_decode(model, params, toks, g, max_len)
            np.testing.assert_array_equal(outs[rid], want)
    # 5 heterogeneous requests through 2 slots: refill must not retrace
    assert eng._step_n._cache_size() == 1
    assert eng._admit._cache_size() == 1


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_teacher_forced(arch, backend):
    """The old ``serve.py --check``, as a real test: incremental decode
    through the cache reproduces the teacher-forced forward logits."""
    cfg, model, params = _model_params(arch)
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (2, 9), 0, cfg.vocab_size
    )
    with use_backend(backend):
        assert_decode_matches_teacher_forced(model, params, prompt, 16)


def test_request_queue_validation():
    q = RequestQueue(max_len=8)
    with pytest.raises(ValueError):
        q.submit([], 4)
    with pytest.raises(ValueError):
        q.submit([1, 2], 0)
    with pytest.raises(ValueError):
        q.submit([1, 2, 3, 4, 5], 4)   # 5 + 4 > max_len
    a = q.submit([1, 2, 3], 4)
    b = q.submit([4], 2)
    assert (a, b) == (0, 1) and len(q) == 2
    assert q.pop().req_id == 0


def test_engine_rejects_unsupported_family():
    cfg, model, params = (None, None, None)
    cfg = _cfg("seamless-m4t-medium")     # encdec: no per-row decode state
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError):
        ServingEngine(model, params, batch=2, max_len=16)


def test_default_backend_visible_across_threads():
    """set_default_backend must reach serving worker threads (the default
    is process-wide; only the use_backend stack is thread-local)."""
    set_default_backend("pallas")
    try:
        seen = []
        t = threading.Thread(target=lambda: seen.append(current_backend()))
        t.start()
        t.join()
        assert seen == [Backend.PALLAS]
        # the scoped stack stays thread-local: an override here must not
        # bleed into a concurrently-started thread
        seen2 = []
        with use_backend("reference"):
            t2 = threading.Thread(target=lambda: seen2.append(current_backend()))
            t2.start()
            t2.join()
        assert seen2 == [Backend.PALLAS]
    finally:
        set_default_backend(None)
