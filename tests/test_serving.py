"""Serving-engine tests — continuous batching vs isolated decode, and the
decode-vs-teacher-forced parity check (previously buried behind
``serve.py --check``), both under the REFERENCE and PALLAS(interpret)
backends (the paper's single-source dual-target discipline applied to the
serving path)."""
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.audit import no_transfer_audit
from repro.core import Backend, use_backend
from repro.core.policy import current_backend, set_default_backend
from repro.configs.registry import get_arch
from repro.models.model import build_model
from repro.serving import RequestQueue, ServingEngine
from repro.serving.checks import assert_decode_matches_teacher_forced

BACKENDS = ["reference", "pallas"]
# one attention-free (ssm) and one KV-cache (dense) family: the engine's
# per-row positions exercise rope + masked cache writes + per-row lengths
ARCHS = ["mamba2-2.7b", "qwen2.5-3b"]


def _cfg(arch):
    cfg = get_arch(arch).reduced()
    return dataclasses.replace(cfg, dtype="float32")


def _model_params(arch):
    cfg = _cfg(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _isolated_decode(model, params, toks, gen, max_len):
    """Single-request greedy decode — the per-request ground truth."""
    state = model.init_decode_state(1, max_len)
    t = jnp.asarray(toks, jnp.int32)[None]
    logits = None
    for j in range(len(toks)):
        logits, state = model.decode_step(params, state, t[:, j])
    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out.append(int(tok[0]))
    for _ in range(gen - 1):
        logits, state = model.decode_step(params, state, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(tok[0]))
    return np.asarray(out, np.int32)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("arch", ARCHS)
def test_continuous_batching_matches_isolated_decode(arch, backend):
    """Batched, refilled, out-of-phase rows must produce the same tokens as
    each request decoded alone — slot reuse may not leak state."""
    cfg, model, params = _model_params(arch)
    rng = np.random.default_rng(7)
    gen = 4
    reqs = [
        (rng.integers(0, cfg.vocab_size, size=int(rng.integers(3, 8))).tolist(),
         gen)
        for _ in range(5)
    ]
    max_len = 16
    with use_backend(backend):
        eng = ServingEngine(model, params, batch=2, max_len=max_len,
                            steps_per_sync=3)
        rids = [eng.submit(t, g) for t, g in reqs]
        # the step loop must not sync device->host outside the sanctioned
        # steps_per_sync harvest — R002's claim, asserted at runtime
        with no_transfer_audit():
            outs = eng.run()
        for (toks, g), rid in zip(reqs, rids):
            want = _isolated_decode(model, params, toks, g, max_len)
            np.testing.assert_array_equal(outs[rid], want)
    # 5 heterogeneous requests through 2 slots: refill must not retrace
    assert eng._step_n._cache_size() == 1
    assert eng._admit._cache_size() == 1


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_teacher_forced(arch, backend):
    """The old ``serve.py --check``, as a real test: incremental decode
    through the cache reproduces the teacher-forced forward logits."""
    cfg, model, params = _model_params(arch)
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (2, 9), 0, cfg.vocab_size
    )
    with use_backend(backend):
        assert_decode_matches_teacher_forced(model, params, prompt, 16)


PAGED_ARCHS = ["qwen2.5-3b", "qwen3-moe-235b-a22b", "zamba2-2.7b"]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_paged_decode_matches_contiguous(arch, backend):
    """The KVCacheLayout contract: swapping the cache representation may
    not change a single token — same prompts, same seeds, dense/moe/hybrid,
    including rows admitted mid-stream at different depths (4 requests
    through 2 slots with heterogeneous prompt lengths)."""
    cfg, model, params = _model_params(arch)
    rng = np.random.default_rng(11)
    reqs = [
        (rng.integers(0, cfg.vocab_size, size=int(rng.integers(2, 9))).tolist(),
         3)
        for _ in range(4)
    ]
    outs = {}
    with use_backend(backend):
        for layout in ("contiguous", "paged"):
            kw = {"page_size": 4} if layout == "paged" else {}
            eng = ServingEngine(model, params, batch=2, max_len=16,
                                steps_per_sync=3, layout=layout, **kw)
            rids = [eng.submit(t, g) for t, g in reqs]
            with no_transfer_audit():
                got = eng.run()
            outs[layout] = [got[r].tolist() for r in rids]
            assert eng._step_n._cache_size() == 1
            assert eng._admit._cache_size() == 1
    assert outs["paged"] == outs["contiguous"]


@pytest.mark.parametrize("backend", BACKENDS)
def test_windowed_arch_through_engine_both_layouts(backend):
    """Sliding-window attention through the engine: the contiguous layout
    ring-indexes a window-sized cache, the paged layout keeps absolute
    positions and masks in attention — both must equal each other *and*
    the isolated single-request decode once the window binds (prompts
    longer than window=5).  capacity_factor is lifted to n_experts so the
    MoE rows are batch-composition-independent (see engine docstring)."""
    cfg = _cfg("mixtral-8x7b")
    cfg = dataclasses.replace(cfg, window=5,
                              capacity_factor=float(cfg.n_experts))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(13)
    reqs = [
        (rng.integers(0, cfg.vocab_size, size=int(rng.integers(6, 11))).tolist(),
         4)
        for _ in range(4)
    ]
    max_len = 16
    outs = {}
    with use_backend(backend):
        for layout in ("contiguous", "paged"):
            kw = {"page_size": 4} if layout == "paged" else {}
            eng = ServingEngine(model, params, batch=2, max_len=max_len,
                                steps_per_sync=3, layout=layout, **kw)
            rids = [eng.submit(t, g) for t, g in reqs]
            got = eng.run()
            outs[layout] = [got[r].tolist() for r in rids]
        assert outs["paged"] == outs["contiguous"]
        for (toks, g), got in zip(reqs, outs["contiguous"]):
            want = _isolated_decode(model, params, toks, g, max_len)
            np.testing.assert_array_equal(np.asarray(got, np.int32), want)


def test_paged_pool_overflows_dense_budget():
    """Serve a mix whose prompt lengths vary 8x through a page pool *half*
    the ``B x max_len`` slab: reservation admission + free-on-completion
    must recycle pages (total demand 16 pages > pool 12), outputs must
    stay token-identical, and every page must be back on the free list at
    drain (conservation across the whole serve)."""
    cfg, model, params = _model_params("qwen2.5-3b")
    batch, max_len, page = 4, 48, 8
    n_pages = 12                                    # 96 token-slots
    assert n_pages * page < batch * max_len         # would overflow the slab
    rng = np.random.default_rng(5)
    reqs = []
    for i in range(8):
        plen = 2 if i % 2 == 0 else 16              # 8x spread
        gen = 6 if i % 2 == 0 else 8
        reqs.append(
            (rng.integers(0, cfg.vocab_size, size=plen).tolist(), gen)
        )
    from repro.serving.pager import pages_needed
    total_demand = sum(pages_needed(len(t) + g, page) for t, g in reqs)
    assert total_demand > n_pages                   # reuse is mandatory
    outs = {}
    for layout, kw in (
        ("contiguous", {}),
        ("paged", {"page_size": page, "n_pages": n_pages}),
    ):
        eng = ServingEngine(model, params, batch=batch, max_len=max_len,
                            steps_per_sync=4, layout=layout, **kw)
        rids = [eng.submit(t, g) for t, g in reqs]
        got = eng.run()
        outs[layout] = [got[r].tolist() for r in rids]
    assert outs["paged"] == outs["contiguous"]
    assert 0 < eng.peak_pages_in_use <= n_pages
    # free-on-completion: after drain the pool is whole again
    assert int(eng._mstate["page_top"]) == n_pages
    assert (np.asarray(eng._mstate["block_table"]) == -1).all()
    # a request larger than the whole pool is rejected up front (it could
    # never reserve; admitting it would starve the FIFO forever) — even
    # when it fits max_len
    tiny = ServingEngine(model, params, batch=2, max_len=16,
                         layout="paged", page_size=4, n_pages=2)
    with pytest.raises(ValueError):
        tiny.submit([1, 2, 3, 4], 8)        # 3 pages > pool of 2


def test_sampling_reproducible_per_seed():
    """temperature/top-k sampling: per-request keys split on admission
    make outputs a function of the engine seed alone; greedy stays the
    default (parity tests above run the argmax path untouched)."""
    cfg, model, params = _model_params("qwen2.5-3b")
    reqs = [([3, 5, 7], 5), ([11, 2], 5), ([4, 4, 4, 4], 5)]

    def run(**kw):
        eng = ServingEngine(model, params, batch=2, max_len=12,
                            steps_per_sync=2, **kw)
        rids = [eng.submit(t, g) for t, g in reqs]
        got = eng.run()
        return eng, [got[r].tolist() for r in rids]

    _, greedy = run()
    eng, a = run(temperature=1.0, top_k=8, seed=42)
    _, b = run(temperature=1.0, top_k=8, seed=42)
    _, c = run(temperature=1.0, top_k=8, seed=7)
    assert a == b                       # same seed -> same tokens
    assert a != greedy or c != greedy   # sampling actually samples
    assert eng._step_n._cache_size() == 1
    assert eng._admit._cache_size() == 1


def test_encdec_per_row_pos_state():
    """`encdec.init_decode_state` accepts per_row_pos like the LM family:
    (B,) positions decode to the same logits as the scalar-pos path when
    rows are in lockstep (the slot-refill contract's precondition)."""
    cfg = _cfg("seamless-m4t-medium")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 5), 0,
                              cfg.vocab_size)
    s_sc = model.init_decode_state(2, 8)
    s_pr = model.init_decode_state(2, 8, per_row_pos=True)
    assert s_pr["pos"].shape == (2,)
    for j in range(toks.shape[1]):
        l_sc, s_sc = model.decode_step(params, s_sc, toks[:, j])
        l_pr, s_pr = model.decode_step(params, s_pr, toks[:, j])
    np.testing.assert_allclose(
        np.asarray(l_pr, np.float32), np.asarray(l_sc, np.float32),
        rtol=1e-5, atol=1e-5,
    )


def test_request_queue_validation():
    q = RequestQueue(max_len=8)
    with pytest.raises(ValueError):
        q.submit([], 4)
    with pytest.raises(ValueError):
        q.submit([1, 2], 0)
    with pytest.raises(ValueError):
        q.submit([1, 2, 3, 4, 5], 4)   # 5 + 4 > max_len
    a = q.submit([1, 2, 3], 4)
    b = q.submit([4], 2)
    assert (a, b) == (0, 1) and len(q) == 2
    assert q.peek().req_id == 0 and len(q) == 2   # peek must not consume
    assert q.pop().req_id == 0
    q.pop()
    assert q.peek() is None


def test_engine_rejects_unsupported_family():
    cfg, model, params = (None, None, None)
    cfg = _cfg("seamless-m4t-medium")     # encdec: no per-row decode state
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError):
        ServingEngine(model, params, batch=2, max_len=16)


def test_default_backend_visible_across_threads():
    """set_default_backend must reach serving worker threads (the default
    is process-wide; only the use_backend stack is thread-local)."""
    set_default_backend("pallas")
    try:
        seen = []
        t = threading.Thread(target=lambda: seen.append(current_backend()))
        t.start()
        t.join()
        assert seen == [Backend.PALLAS]
        # the scoped stack stays thread-local: an override here must not
        # bleed into a concurrently-started thread
        seen2 = []
        with use_backend("reference"):
            t2 = threading.Thread(target=lambda: seen2.append(current_backend()))
            t2.start()
            t2.join()
        assert seen2 == [Backend.PALLAS]
    finally:
        set_default_backend(None)
