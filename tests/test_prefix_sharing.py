"""Prefix-sharing tests — page-level prompt sharing with copy-on-write
must leave every generated token identical to the no-sharing engine
across KV layouts and backends (the KVCacheLayout contract extended to
*aliased* pages), while actually engaging: shared pages mapped at
admission, prefill resumed at the first unshared token, CoW on the one
write that can land in a shared page, refcounted release through
donor-death and slot-readmission cycles, and a real resident-memory win
on the shared-system-prompt workload.  The recurrent families (ssm /
hybrid) share through page-boundary state snapshots — the donor's
SSM/conv state is restored at the last shared boundary, never skipped —
and must meet the same token-identity, engagement and conservation bars
(no CoW, snapshot slots partition with their pages)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import use_backend
from repro.configs.registry import get_arch
from repro.models.model import build_model
from repro.serving import ServingEngine

BACKENDS = ["reference", "pallas"]
# dense and moe share through aliased KV pages; ssm and hybrid share
# through page-boundary recurrent-state snapshots (the donor's SSM/conv
# state is *restored* at the last shared boundary, never skipped)
SHARE_ARCHS = ["qwen2.5-3b", "qwen3-moe-235b-a22b"]
RECURRENT_ARCHS = ["mamba2-2.7b", "zamba2-2.7b"]


def _cfg(arch):
    cfg = dataclasses.replace(get_arch(arch).reduced(), dtype="float32")
    if cfg.n_experts:
        # sharing changes which tokens batch into a routing step; only the
        # no-drop regime is batch-composition-independent (engine docstring)
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    return cfg


def _model_params(arch):
    cfg = _cfg(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _serve_staged(model, params, donor, rest, *, warm_steps=5, **kw):
    """Admit the donor alone, run a few cycles so its prompt pages are
    written, then submit the rest — the schedule under which sharing can
    actually engage (a prompt only matches *resident, already-written*
    pages)."""
    eng = ServingEngine(model, params, batch=4, max_len=26,
                        steps_per_sync=2, **kw)
    rid0 = eng.submit(*donor)
    for _ in range(warm_steps):
        eng.step()
    rids = [rid0] + [eng.submit(t, g) for t, g in rest]
    outs = eng.run()
    return eng, [outs[r].tolist() for r in rids]


def _shared_requests(cfg, seed=5):
    """A long-lived donor plus sharers: divergent tail, fully shared
    prompt (the CoW case), and a longer divergent tail."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, size=8).tolist()
    donor = (prefix + [7, 9], 14)
    rest = [(prefix + [3], 3), (list(prefix), 3), (prefix + [5, 1, 2], 4)]
    return donor, rest


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("arch", SHARE_ARCHS)
def test_sharing_is_token_identical(arch, backend):
    """Sharing on vs off vs the contiguous layout: same tokens everywhere,
    with sharing demonstrably engaged (skipped prompt tokens, a CoW copy
    for the fully shared prompt) and no page leaked at drain."""
    cfg, model, params = _model_params(arch)
    donor, rest = _shared_requests(cfg)
    kw = dict(layout="paged", page_size=4, prefill_chunk=4)
    with use_backend(backend):
        _, contig = _serve_staged(model, params, donor, rest)
        _, base = _serve_staged(model, params, donor, rest, **kw)
        eng, got = _serve_staged(model, params, donor, rest,
                                 prefix_sharing=True, **kw)
    assert got == base == contig
    assert eng.shared_prompt_tokens > 0, "sharing never engaged"
    assert eng.cow_pages >= 1, "the fully shared prompt must CoW"
    assert eng._step_n._cache_size() == 1
    assert eng._admit._cache_size() == 1
    assert eng._prefill._cache_size() == 1
    # drain returns every page: refcounted release leaked nothing
    assert int(eng._mstate["page_top"]) == eng.n_pages
    assert (np.asarray(eng._mstate["page_rc"]) == 0).all()
    assert (np.asarray(eng._mstate["block_table"]) == -1).all()


def test_sharing_token_identical_without_chunked_prefill():
    """prefill_chunk=1: the re-fed tokens go through the fused *decode*
    path, whose write must CoW exactly like the chunked one."""
    cfg, model, params = _model_params("qwen2.5-3b")
    donor, rest = _shared_requests(cfg)
    kw = dict(layout="paged", page_size=4)
    _, base = _serve_staged(model, params, donor, rest, **kw)
    eng, got = _serve_staged(model, params, donor, rest,
                             prefix_sharing=True, **kw)
    assert got == base
    assert eng.shared_prompt_tokens > 0 and eng.cow_pages >= 1
    assert int(eng._mstate["page_top"]) == eng.n_pages


def test_cow_divergence_after_shared_pages():
    """Two requests share full pages then diverge mid-page: the sharer's
    divergent tokens must never bleed into the donor's stream (the donor
    keeps decoding from its own pages after the sharer's CoW)."""
    cfg, model, params = _model_params("qwen2.5-3b")
    rng = np.random.default_rng(11)
    prefix = rng.integers(0, cfg.vocab_size, size=8).tolist()
    donor = (list(prefix), 14)                 # page-aligned, long-lived
    # every sharer forces CoW of the donor's final page: fully shared
    # prompt, or a divergent token at the first position past the pages
    rest = [(list(prefix), 4), (prefix + [1], 4), (prefix + [2, 3], 4)]
    kw = dict(layout="paged", page_size=4, prefill_chunk=4)
    _, base = _serve_staged(model, params, donor, rest, **kw)
    eng, got = _serve_staged(model, params, donor, rest,
                             prefix_sharing=True, **kw)
    assert got == base
    assert eng.cow_pages >= 1
    # donor (greedy, same prompt) and its fully-shared twin must agree
    assert got[0][:4] == got[1]


def test_release_readmit_cycles_conserve_and_match():
    """More requests than slots with a mix of sharable and unrelated
    prompts: donors die, slots readmit, later prompts match later donors
    (epoch-invalidated index) — tokens identical, pool whole at drain."""
    cfg, model, params = _model_params("qwen2.5-3b")
    rng = np.random.default_rng(13)
    prefix = rng.integers(0, cfg.vocab_size, size=8).tolist()
    donor = (prefix + [6], 14)
    rest = []
    for i in range(7):
        if i % 3 == 2:       # unrelated prompt: must never match
            rest.append((rng.integers(0, cfg.vocab_size, size=6).tolist(), 3))
        else:
            tail = rng.integers(0, cfg.vocab_size, size=i % 3).tolist()
            rest.append((prefix + tail, 3))
    kw = dict(layout="paged", page_size=4, prefill_chunk=4)
    _, base = _serve_staged(model, params, donor, rest, **kw)
    eng, got = _serve_staged(model, params, donor, rest,
                             prefix_sharing=True, **kw)
    assert got == base
    assert eng.shared_prompt_tokens > 0
    assert int(eng._mstate["page_top"]) == eng.n_pages
    assert (np.asarray(eng._mstate["page_rc"]) == 0).all()


def test_serial_sharers_keep_matching_resident_donor():
    """A sharer must not steal the donor's index entries and take them to
    its grave: with a long-lived donor, *serial* same-prefix requests
    (each finishing before the next arrives) must all match — the
    shared-system-prompt workload is exactly this pattern."""
    cfg, model, params = _model_params("qwen2.5-3b")
    rng = np.random.default_rng(19)
    prefix = rng.integers(0, cfg.vocab_size, size=8).tolist()
    eng = ServingEngine(model, params, batch=2, max_len=50,
                        steps_per_sync=2, layout="paged", page_size=4,
                        prefill_chunk=4, prefix_sharing=True)
    eng.submit(prefix + [1], 40)                 # long-lived donor
    for _ in range(5):
        eng.step()
    shared = []
    for i in range(3):
        rid = eng.submit(prefix + [2 + i], 2)
        for _ in range(50):
            if rid in eng.outputs:
                break
            eng.step()
        assert rid in eng.outputs
        shared.append(eng.shared_prompt_tokens)
    eng.run()
    # every serial sharer matched the donor's two full prefix pages
    assert shared == [8, 16, 24]


def test_sharing_survives_donor_completion():
    """When the original donor finishes, a surviving sharer inherits its
    index entries: the prefix stays matchable as long as *any* holder of
    the (refcount-kept-resident) pages lives."""
    cfg, model, params = _model_params("qwen2.5-3b")
    rng = np.random.default_rng(23)
    prefix = rng.integers(0, cfg.vocab_size, size=8).tolist()
    eng = ServingEngine(model, params, batch=2, max_len=50,
                        steps_per_sync=2, layout="paged", page_size=4,
                        prefill_chunk=4, prefix_sharing=True)
    rid_a = eng.submit(prefix + [1], 4)              # short-lived donor
    eng.step()                                       # prefix written
    rid_b = eng.submit(prefix + [2], 40)             # long-lived sharer
    for _ in range(50):                              # donor finishes
        if rid_a in eng.outputs:
            break
        eng.step()
    assert rid_a in eng.outputs
    assert eng.shared_prompt_tokens == 8             # B matched A
    rid_c = eng.submit(prefix + [3], 2)              # arrives after A died
    for _ in range(50):
        if rid_c in eng.outputs:
            break
        eng.step()
    assert rid_c in eng.outputs
    # C matched B's inherited pages — the prefix never went unmatchable
    assert eng.shared_prompt_tokens == 16
    eng.run()
    assert int(eng._mstate["page_top"]) == eng.n_pages


def test_sampled_streams_invariant_under_sharing():
    """Sampling keys are fold_in(admission key, position), so skipping
    prefill positions must not perturb sampled tokens."""
    cfg, model, params = _model_params("qwen2.5-3b")
    donor, rest = _shared_requests(cfg)
    kw = dict(layout="paged", page_size=4, prefill_chunk=4,
              temperature=1.0, top_k=8, seed=42)
    _, a = _serve_staged(model, params, donor, rest, **kw)
    eng, b = _serve_staged(model, params, donor, rest,
                           prefix_sharing=True, **kw)
    assert a == b
    assert eng.shared_prompt_tokens > 0


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("arch", RECURRENT_ARCHS)
def test_recurrent_sharing_is_token_identical(arch, backend):
    """ssm/hybrid sharing via restored state snapshots: same tokens as the
    no-sharing and contiguous engines, sharing demonstrably engaged, no
    CoW ever (the resume point is always an unshared boundary), and both
    the snapshot-slot pool and — for hybrid — the page pool whole at
    drain."""
    cfg, model, params = _model_params(arch)
    donor, rest = _shared_requests(cfg)
    kw = dict(layout="paged", page_size=4, prefill_chunk=4)
    with use_backend(backend):
        _, contig = _serve_staged(model, params, donor, rest)
        _, base = _serve_staged(model, params, donor, rest, **kw)
        eng, got = _serve_staged(model, params, donor, rest,
                                 prefix_sharing=True, **kw)
    assert got == base == contig
    assert eng.shared_prompt_tokens > 0, "sharing never engaged"
    assert eng.cow_pages == 0, "recurrent sharing must never CoW"
    assert eng._step_n._cache_size() == 1
    assert eng._admit._cache_size() == 1
    assert eng._prefill._cache_size() == 1
    # drain returns every snapshot slot (and page): nothing leaked
    assert int(eng._mstate["snap_top"]) == eng.n_snap_slots
    assert (np.asarray(eng._mstate["snap_rc"]) == 0).all()
    assert (np.asarray(eng._mstate["snap_table"]) == -1).all()
    if "block_table" in eng._mstate:
        assert int(eng._mstate["page_top"]) == eng.n_pages


def test_recurrent_sharing_without_chunked_prefill():
    """prefill_chunk=1: boundaries are crossed one decode step at a time,
    so every boundary state is still captured and restorable."""
    cfg, model, params = _model_params("mamba2-2.7b")
    donor, rest = _shared_requests(cfg)
    kw = dict(layout="paged", page_size=4)
    _, base = _serve_staged(model, params, donor, rest, **kw)
    eng, got = _serve_staged(model, params, donor, rest,
                             prefix_sharing=True, **kw)
    assert got == base
    assert eng.shared_prompt_tokens > 0
    assert int(eng._mstate["snap_top"]) == eng.n_snap_slots


def test_snapshot_capture_restore_roundtrip():
    """The model-level snapshot contract, no engine in the loop: decode
    steps that end at page boundaries capture the post-step state; a
    sharer admitted with ``restore_snapshots`` holds bitwise the donor's
    state at the shared boundary (restore is a load, not a recompute)."""
    from repro.models import lm as LM

    cfg, model, params = _model_params("mamba2-2.7b")
    P = 4
    state = LM.init_decode_state(cfg, 2, 16, per_row_pos=True,
                                 layout="paged", page_size=P,
                                 snapshots=True)
    toks = np.arange(1, 11, dtype=np.int32)    # 10 tokens: boundaries 4, 8
    active = jnp.asarray([True, False])
    snap_at = {}
    for t in toks:
        _, state = LM.decode_step(
            cfg, params, state, jnp.asarray([t, 0], jnp.int32),
            active=active, snap_every=P,
        )
        if int(state["pos"][0]) % P == 0:
            snap_at[int(state["pos"][0])] = np.asarray(state["ssm"][:, 0])
    assert sorted(snap_at) == [4, 8]
    tbl = np.asarray(state["snap_table"])
    assert (tbl[0, :2] >= 0).all() and (tbl[0, 2:] == -1).all()
    assert (tbl[1] == -1).all()
    # restore row 1 from row 0's first two boundaries (8 shared tokens)
    state = LM.restore_snapshots(
        state, jnp.asarray([False, True]), jnp.zeros((2,), jnp.int32),
        jnp.asarray([0, 2], jnp.int32),
    )
    np.testing.assert_array_equal(np.asarray(state["ssm"][:, 1]), snap_at[8])
    # the shared slots are refcounted like pages: donor release keeps
    # them resident, sharer release frees them
    tbl = np.asarray(state["snap_table"])
    np.testing.assert_array_equal(tbl[1, :2], tbl[0, :2])
    assert (np.asarray(state["snap_rc"])[tbl[0, :2]] == 2).all()
    state = LM.reset_decode_rows(cfg, state, jnp.asarray([True, False]))
    assert (np.asarray(state["snap_rc"])[tbl[1, :2]] == 1).all()
    state = LM.reset_decode_rows(cfg, state, jnp.asarray([False, True]))
    assert (np.asarray(state["snap_rc"]) == 0).all()
    assert int(state["snap_top"]) == state["snap_free"].shape[0]


def test_sharing_requires_paged_layout():
    cfg, model, params = _model_params("qwen2.5-3b")
    with pytest.raises(ValueError):
        ServingEngine(model, params, batch=2, max_len=16,
                      prefix_sharing=True)


def test_resident_kv_drops_with_shared_system_prompt():
    """The acceptance workload: 8 rows sharing a 256-token prompt prefix.
    Peak resident KV bytes must drop >= 3x vs the no-sharing engine while
    every output token stays identical."""
    cfg, model, params = _model_params("qwen2.5-3b")
    n, plen, gen, page = 8, 256, 6, 8
    rng = np.random.default_rng(17)
    prefix = rng.integers(0, cfg.vocab_size, size=plen).tolist()
    tails = [rng.integers(0, cfg.vocab_size, size=3).tolist()
             for _ in range(n)]
    tails[-1] = []                       # one fully shared prompt (CoW)
    donor_gen = gen + 4
    max_len = plen + 3 + donor_gen + 1

    def run(sharing):
        eng = ServingEngine(model, params, batch=n, max_len=max_len,
                            steps_per_sync=2, layout="paged",
                            page_size=page, prefill_chunk=64,
                            prefix_sharing=sharing)
        rid0 = eng.submit(prefix + tails[0], donor_gen)
        eng.step()                       # donor's prefix pages are written
        rids = [rid0] + [eng.submit(prefix + t, gen) for t in tails[1:]]
        outs = eng.run()
        return eng, [outs[r].tolist() for r in rids]

    e_off, base = run(False)
    e_on, got = run(True)
    assert got == base
    assert e_on.shared_prompt_tokens >= (n - 1) * (plen - 1)
    drop = (e_off.kv_resident_bytes(peak=True)
            / max(e_on.kv_resident_bytes(peak=True), 1))
    assert drop >= 3.0, f"resident-KV drop {drop:.2f}x < 3x"


def test_hybrid_resident_kv_drops_with_shared_system_prompt():
    """The acceptance workload for the recurrent families: 8 hybrid rows
    sharing a 256-token prompt prefix.  Snapshot-restore sharing must
    leave every token identical while peak resident KV collapses (the
    shared attention pages are resident once) and nearly the whole
    prefix is served from shared pages + restored state."""
    cfg, model, params = _model_params("zamba2-2.7b")
    n, plen, gen, page = 8, 256, 6, 8
    rng = np.random.default_rng(29)
    prefix = rng.integers(0, cfg.vocab_size, size=plen).tolist()
    tails = [rng.integers(0, cfg.vocab_size, size=3).tolist()
             for _ in range(n)]
    tails[-1] = []           # fully shared prompt (resume one page early)
    donor_gen = gen + 4
    max_len = plen + 3 + donor_gen + 1

    def run(sharing):
        eng = ServingEngine(model, params, batch=n, max_len=max_len,
                            steps_per_sync=2, layout="paged",
                            page_size=page, prefill_chunk=64,
                            prefix_sharing=sharing)
        rid0 = eng.submit(prefix + tails[0], donor_gen)
        eng.step()                       # donor's prefix pages are written
        rids = [rid0] + [eng.submit(prefix + t, gen) for t in tails[1:]]
        outs = eng.run()
        return eng, [outs[r].tolist() for r in rids]

    e_off, base = run(False)
    e_on, got = run(True)
    assert got == base
    # every sharer skips at least the page-aligned bulk of the prefix
    # (the fully shared prompt resumes one boundary short of its end)
    assert e_on.shared_prompt_tokens >= (n - 1) * (plen - page)
    assert e_on.cow_pages == 0
    drop = (e_off.kv_resident_bytes(peak=True)
            / max(e_on.kv_resident_bytes(peak=True), 1))
    assert drop >= 3.0, f"hybrid resident-KV drop {drop:.2f}x < 3x"
    assert int(e_on._mstate["snap_top"]) == e_on.n_snap_slots
    assert (np.asarray(e_on._mstate["snap_rc"]) == 0).all()
