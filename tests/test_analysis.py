"""Tests for ``repro.analysis``: every rule fires on its known-bad fixture
exactly where expected (and nowhere on the known-good twin), suppression
comments work, the clean tree reports zero findings, the coverage lint
catches half-wired ops, and the runtime auditors hold over a real mixed
prefill/decode/admission workload."""
import dataclasses
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    JitCacheRetrace,
    coverage_findings,
    jit_cache_audit,
    lint_paths,
    lint_source,
    no_transfer_audit,
)

FIXTURES = Path(__file__).parent / "fixtures" / "lint"
REPO = Path(__file__).resolve().parents[1]

# fixture stem -> (virtual path it is linted under, expected (rule, line))
CASES = {
    "r001": (
        "src/repro/kernels/flash_attention.py",
        [("R001", 7)],
    ),
    "r002": (
        "src/repro/serving/engine.py",
        [("R002", 8), ("R002", 12), ("R002", 13)],
    ),
    "r003": (
        "src/repro/serving/engine.py",
        [("R003", 7), ("R003", 10)],
    ),
    "r004": (
        "src/repro/serving/worker.py",
        [("R004", 7)],
    ),
    "r005": (
        "src/repro/kernels/mamba_scan.py",
        [("R005", 7), ("R005", 11)],
    ),
    "r006": (
        "src/repro/serving/engine.py",
        [("R006", 6), ("R006", 10)],
    ),
    "r007": (
        "src/repro/serving/pager.py",
        [("R007", 7), ("R007", 11), ("R007", 15)],
    ),
}


def _lint_fixture(name: str, vpath: str):
    src = (FIXTURES / f"{name}.py").read_text()
    return src, lint_source(src, vpath)


@pytest.mark.parametrize("stem", sorted(CASES))
def test_rule_fires_on_bad_fixture(stem):
    """Each bad fixture produces exactly the expected (rule, line) set —
    all rules run, so cross-rule false positives fail the test too."""
    vpath, want = CASES[stem]
    _, findings = _lint_fixture(f"{stem}_bad", vpath)
    assert [(f.rule, f.line) for f in findings] == want
    for f in findings:
        assert f.path == vpath and f.hint  # every rule ships a fix-hint


@pytest.mark.parametrize("stem", sorted(CASES))
def test_good_fixture_is_clean(stem):
    vpath, _ = CASES[stem]
    _, findings = _lint_fixture(f"{stem}_good", vpath)
    assert findings == []


@pytest.mark.parametrize("stem", sorted(CASES))
def test_suppression_comment_silences_rule(stem):
    """Appending `# repro-lint: disable=RXXX` to each flagged line makes
    the bad fixture lint clean."""
    vpath, want = CASES[stem]
    src, findings = _lint_fixture(f"{stem}_bad", vpath)
    assert findings  # precondition
    lines = src.splitlines()
    for rule, line in want:
        lines[line - 1] += f"  # repro-lint: disable={rule}"
    assert lint_source("\n".join(lines), vpath) == []


def test_suppression_on_preceding_comment_line():
    src = (
        "# repro-lint: disable=R001\n"
        "from jax.experimental.pallas import tpu as pltpu\n"
    )
    assert lint_source(src, "src/repro/kernels/foo.py") == []
    # disabling a different rule does not silence R001
    src2 = src.replace("R001", "R003")
    assert [f.rule for f in lint_source(src2, "src/repro/kernels/foo.py")] == [
        "R001"
    ]


def test_seeded_violation_is_fixed_in_tree():
    """The day-one R001 violation: the fixture reproducing the pre-fix
    flash_attention header is caught; the in-tree file is clean."""
    _, findings = _lint_fixture("r001_bad", "src/repro/kernels/flash_attention.py")
    assert [f.rule for f in findings] == ["R001"]
    real = REPO / "src/repro/kernels/flash_attention.py"
    assert "pallas.tpu" not in real.read_text().replace("\n", "")
    assert (
        lint_source(real.read_text(), "src/repro/kernels/flash_attention.py")
        == []
    )


def test_clean_tree_has_zero_findings():
    findings = lint_paths([REPO / "src" / "repro"], root=REPO)
    assert findings == [], "\n".join(f.format() for f in findings)


def test_cli_main_clean_tree(capsys):
    from repro.analysis.lint import main

    assert main(["--no-coverage"]) == 0
    assert "0 findings" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# Coverage lint (C101-C103)
# ---------------------------------------------------------------------------


def test_coverage_lint_clean_registry():
    assert coverage_findings() == []


def test_coverage_lint_catches_half_wired_ops():
    from repro.core import registry

    def fake(*args):  # pragma: no cover - never called
        raise NotImplementedError

    names = ("_lint_nopallas", "_lint_untuned", "_lint_stale")
    registry.register_op(names[0], reference=fake)
    registry.register_op(names[1], reference=fake, pallas=fake, tuning=None)
    registry.register_op(
        names[2], reference=fake, pallas=fake, tuning="no_such_tuning_key"
    )
    try:
        got = {
            f.rule for f in coverage_findings() if "_lint_" in f.message
        }
        assert got == {"C101", "C102", "C103"}
    finally:
        for n in names:
            registry._OPS.pop(n, None)


def test_table_lint_flags_unknown_key():
    """C104: a table entry under a tuning key no registered op declares."""
    from repro.analysis.coverage import table_findings
    from repro.tuning import table as tt

    doc = tt.empty_doc()
    doc["entries"] = {"no_such_key": {"m8": {"params": {"bm": 32}}}}
    got = table_findings(doc)
    assert [f.rule for f in got] == ["C104"]
    assert "no_such_key" in got[0].message


def test_table_lint_flags_key_that_lost_its_lowering():
    """C104: the op exists but is no longer Pallas-lowered — the persisted
    entry is dead weight that would silently stop applying."""
    from repro.analysis.coverage import table_findings
    from repro.core import registry
    from repro.tuning import table as tt

    registry.register_op(
        "_lint_tbl", reference=lambda: None, tuning="_lint_tbl_key",
        reference_only=True,
    )
    try:
        doc = tt.empty_doc()
        doc["entries"] = {"_lint_tbl_key": {"m8": {"params": {"bm": 32}}}}
        got = table_findings(doc)
        assert [f.rule for f in got] == ["C104"]
        assert "lowering" in got[0].message
    finally:
        registry._OPS.pop("_lint_tbl", None)


def test_table_lint_flags_param_no_call_site_resolves():
    """C105: the key is live but the stored knob name matches no
    get_tuning call-site default — a typo or a renamed knob."""
    from repro.analysis.coverage import table_findings
    from repro.tuning import table as tt

    doc = tt.empty_doc()
    doc["entries"] = {"gemm": {"m8": {"params": {"block_mm": 32}}}}
    got = table_findings(doc)
    assert [f.rule for f in got] == ["C105"]
    assert "block_mm" in got[0].message


def test_table_lint_reports_malformed_table_as_c104():
    from repro.analysis.coverage import table_findings

    got = table_findings({"schema": 99})
    assert got and all(f.rule == "C104" for f in got)


def test_register_op_rejects_contradictory_declaration():
    from repro.core import registry

    with pytest.raises(ValueError):
        registry.register_op(
            "_lint_bogus", reference=lambda: None, pallas=lambda: None,
            reference_only=True,
        )
    assert "_lint_bogus" not in registry._OPS


# ---------------------------------------------------------------------------
# Runtime auditors
# ---------------------------------------------------------------------------


def _engine(**kw):
    from repro.configs.registry import get_arch
    from repro.models.model import build_model
    from repro.serving import ServingEngine

    cfg = dataclasses.replace(get_arch("qwen2.5-3b").reduced(), dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, ServingEngine(
        model, params, batch=2, max_len=16, steps_per_sync=3, **kw
    )


def test_jit_cache_audit_mixed_workload():
    """5 heterogeneous requests through 2 slots (chunked prefill, decode,
    mid-stream admission, paged release): every jitted entry point must
    hold at cache size 1, with no implicit sync between harvests."""
    cfg, eng = _engine(layout="paged", page_size=4, prefill_chunk=4)
    rng = np.random.default_rng(3)
    for _ in range(5):
        toks = rng.integers(
            0, cfg.vocab_size, size=int(rng.integers(2, 8))
        ).tolist()
        eng.submit(toks, 3)
    with jit_cache_audit(eng) as report, no_transfer_audit():
        eng.run()
    assert report.calls["_step_n"] > 0 and report.calls["_prefill"] > 0
    for name in ("_step_n", "_admit", "_prefill"):
        # cache size stays 1: exactly one compilation, never a retrace
        assert report.max_sizes[name] == 1, report
        assert report.growth(name) == 1, report
    # wrappers restored on exit
    assert hasattr(eng._step_n, "_cache_size")


def test_jit_cache_audit_catches_retrace():
    class Holder:
        pass

    h = Holder()
    h._step_n = jax.jit(lambda x: x * 2)
    orig = h._step_n
    with pytest.raises(JitCacheRetrace, match="_step_n retraced"):
        with jit_cache_audit(h, fn_names=("_step_n",)):
            h._step_n(jnp.ones((2,)))
            h._step_n(jnp.ones((3,)))  # shape change -> second trace
    assert h._step_n is orig  # restored even on failure


def test_no_transfer_audit_blocks_implicit_sync():
    x = jnp.arange(4)
    with no_transfer_audit():
        got = jax.device_get(x)  # explicit harvest: allowed
        assert got.tolist() == [0, 1, 2, 3]
        with pytest.raises(Exception, match="[Dd]isallow"):
            int(x[0])  # implicit device->host sync: blocked
