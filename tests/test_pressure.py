"""Pressure tests — serving survives preemption, cancellation, deadlines
and injected faults.

The scheduler contract under test (engine class docstring): when the
head of the queue cannot reserve pages, strictly-lower-priority resident
rows are preempted — their pages (and, for recurrent families, their
page-boundary snapshot slots) *spill* to a private host-tier copy and
later *restore* — and every doomed request (cancelled, past-deadline,
poisoned) drains through the same jitted release path whether it is
queued, mid-prefill, device-active, spilled, or donating a shared
prefix.  The bars, everywhere: survivors' token streams are
bit-identical to an unpressured run of the same requests, no page or
snapshot slot leaks in either tier post-drain, and the jitted entry
points (``_spill``/``_restore`` included) never retrace.

Token-identity across schedules leans on two engine guarantees worth
naming because these tests would catch their regression first: a
request's chunked-prefill partitioning is schedule-invariant (a budget
or preemption stop always leaves progress chunk-aligned, and frozen
rows skip the fused decode call), and sampling keys are a pure function
of (engine seed, req_id), so admission reshuffling cannot perturb any
row's stream.
"""
import dataclasses
import functools

import jax
import numpy as np
import pytest

from repro.analysis.audit import jit_cache_audit, no_transfer_audit
from repro.configs.registry import get_arch
from repro.core import use_backend
from repro.models.model import build_model
from repro.serving import (
    FaultEvent,
    FaultPlan,
    QueueEmpty,
    QueueFullError,
    RequestQueue,
    ServingEngine,
)

BACKENDS = ["reference", "pallas"]
#: paged KV pages to spill — dense and hybrid (hybrid also spills
#: snapshot slots when sharing is on); pure ssm has no KV pool
SPILL_ARCHS = ["qwen2.5-3b", "zamba2-2.7b"]
ALL_ARCHS = ["qwen2.5-3b", "mamba2-2.7b", "zamba2-2.7b"]
LAYOUTS = ["contiguous", "paged"]


def _cfg(arch):
    return dataclasses.replace(get_arch(arch).reduced(), dtype="float32")


@functools.lru_cache(maxsize=None)
def _model_params(arch):
    cfg = _cfg(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _mk(model, params, *, batch=2, max_len=32, layout="paged", n_pages=16,
        **kw):
    kwargs = dict(batch=batch, max_len=max_len, steps_per_sync=2,
                  prefill_chunk=4, layout=layout)
    if layout == "paged":
        kwargs.update(page_size=4, n_pages=n_pages)
    kwargs.update(kw)
    return ServingEngine(model, params, **kwargs)


def _assert_conserved(eng):
    """Zero leaked pages / snapshot slots in any tier after a drain."""
    st = eng._mstate
    for top, free, table in (
        ("page_top", "page_free", "block_table"),
        ("host_top", "host_free", "host_table"),
        ("snap_top", "snap_free", "snap_table"),
        ("hsnap_top", "hsnap_free", "hsnap_table"),
    ):
        if top not in st:
            continue
        assert int(st[top]) == st[free].shape[0], f"{top}: slots leaked"
        assert (np.asarray(st[table]) == -1).all(), f"{table}: stale maps"


# -- preemption: host spill + restore ---------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("arch", SPILL_ARCHS)
def test_preemption_restore_token_identity(arch, backend):
    """A high-priority arrival that cannot reserve pages must spill the
    resident low-priority row to the host tier and restore it after —
    with every stream bit-identical to the uncontended run, both tiers
    conserved, and no jitted entry point (spill/restore included)
    retracing; the whole pressured run holds under the transfer guard."""
    cfg, model, params = _model_params(arch)
    sharing = dict(prefix_sharing=True)

    base = _mk(model, params, n_pages=16, **sharing)
    base.submit(list(range(1, 9)), 10, priority=0)
    base.submit(list(range(21, 27)), 8, priority=1)
    with use_backend(backend):
        bouts = base.run()

    # pool of 6: the resident request reserves 5 pages, the high-priority
    # one needs 4 — impossible without preemption
    pres = _mk(model, params, n_pages=6, **sharing)
    with use_backend(backend):
        with jit_cache_audit(pres) as report:
            pres.submit(list(range(1, 9)), 10, priority=0)
            pres.step()
            pres.submit(list(range(21, 27)), 8, priority=1)
            with no_transfer_audit():
                pouts = pres.run()
    assert pres.preemptions >= 1 and pres.restores >= 1
    for rid in bouts:
        np.testing.assert_array_equal(bouts[rid], pouts[rid])
    _assert_conserved(pres)
    if pres._spillable:
        assert report.growth("_spill") <= 1
        assert report.growth("_restore") <= 1


@pytest.mark.parametrize("arch", SPILL_ARCHS)
def test_mid_prefill_preemption_token_identity(arch):
    """Preempting a row that is still *ingesting its prompt* must not
    perturb its tokens: the spilled row's progress stays chunk-aligned
    and it resumes the exact chunk schedule after restore (chunked
    prefill logits depend on the chunk partitioning, not just on
    positions — the engine freezes mid-prompt rows rather than advancing
    them token-by-token)."""
    cfg, model, params = _model_params(arch)

    def mk(n_pages, budget=0):
        return _mk(model, params, max_len=40, n_pages=n_pages,
                   steps_per_sync=1, prefill_budget=budget)

    prompt = list(range(1, 25))
    base = mk(20)
    base.submit(prompt, 8, priority=0)
    base.submit(list(range(31, 37)), 6, priority=1)
    bouts = base.run()

    pres = mk(8, budget=1)
    pres.submit(prompt, 8, priority=0)
    pres.step()                   # one chunk in: mid-prefill
    pres.submit(list(range(31, 37)), 6, priority=1)
    pouts = pres.run()
    assert pres.preemptions >= 1 and pres.restores >= 1
    for rid in bouts:
        np.testing.assert_array_equal(bouts[rid], pouts[rid])
    _assert_conserved(pres)


# -- cancellation through the release path ----------------------------------

@pytest.mark.parametrize("layout", LAYOUTS)
def test_cancel_queued_request(layout):
    """Cancel of a still-queued request removes it before it ever touches
    a device slot; the resident survivor's stream is untouched."""
    cfg, model, params = _model_params("qwen2.5-3b")
    solo = _mk(model, params, batch=1, layout=layout)
    ra = solo.submit(list(range(1, 7)), 8)
    souts = solo.run()

    eng = _mk(model, params, batch=1, layout=layout)
    ra = eng.submit(list(range(1, 7)), 8)
    rb = eng.submit(list(range(11, 17)), 8)   # queued behind ra (batch=1)
    eng.step()
    assert eng.cancel(rb) is True
    assert eng.cancel(rb) is False            # already gone
    assert eng.cancel(10**6) is False         # unknown id
    outs = eng.run()
    assert sorted(outs) == [ra]
    assert rb in eng.cancelled
    np.testing.assert_array_equal(outs[ra], souts[ra])
    _assert_conserved(eng)


@pytest.mark.parametrize("arch", ALL_ARCHS)
@pytest.mark.parametrize("layout", LAYOUTS)
def test_cancel_mid_prefill_row(arch, layout):
    """Cancel of a row still ingesting its prompt drains it at the next
    harvest — recurrent lanes (ssm/hybrid) included — and the other
    row's stream survives bit-identically."""
    cfg, model, params = _model_params(arch)

    def mk():
        return _mk(model, params, max_len=40, layout=layout, n_pages=20,
                   prefill_budget=1)

    solo = mk()
    rs = solo.submit(list(range(31, 37)), 6)
    souts = solo.run()

    eng = mk()
    ra = eng.submit(list(range(1, 25)), 8)    # long prompt: several chunks
    eng.step()                                # mid-prefill under budget=1
    rb = eng.submit(list(range(31, 37)), 6)
    assert eng.cancel(ra) is True
    outs = eng.run()
    assert sorted(outs) == [rb]
    assert ra in eng.cancelled and ra not in outs
    np.testing.assert_array_equal(outs[rb], souts[rs])
    _assert_conserved(eng)


@pytest.mark.parametrize("arch", SPILL_ARCHS)
def test_cancel_spilled_row(arch):
    """Cancel of a row parked on the host tier: it is never restored —
    the harvest drains its host-tier pages/slots directly — and the
    preemptor's stream is bit-identical to an uncontended run."""
    cfg, model, params = _model_params(arch)

    base = _mk(model, params, n_pages=16)
    base.submit(list(range(1, 9)), 10, priority=0)
    rb = base.submit(list(range(21, 27)), 8, priority=1)
    bouts = base.run()

    eng = _mk(model, params, n_pages=6)
    ra = eng.submit(list(range(1, 9)), 10, priority=0)
    eng.step()
    rb = eng.submit(list(range(21, 27)), 8, priority=1)
    eng.step()                                # ra spilled, rb admitted
    assert eng.preemptions == 1
    assert eng.cancel(ra) is True             # cancel *while spilled*
    outs = eng.run()
    assert sorted(outs) == [rb]
    assert ra in eng.cancelled
    assert eng.restores == 0                  # doomed rows never restore
    np.testing.assert_array_equal(outs[rb], bouts[rb])
    _assert_conserved(eng)


@pytest.mark.parametrize("arch", SPILL_ARCHS)
def test_cancel_prefix_donor_with_live_sharers(arch):
    """Cancel of a prefix donor whose pages (or snapshot slots) live
    sharers still reference: refcounts keep the shared data resident, the
    sharers finish with the same tokens as an unshared run, and the last
    release returns every page in every tier."""
    cfg, model, params = _model_params(arch)
    rng = np.random.default_rng(5)
    prefix = rng.integers(0, cfg.vocab_size, size=8).tolist()

    def serve(sharing, cancel_donor):
        eng = ServingEngine(model, params, batch=4, max_len=26,
                            steps_per_sync=2, prefill_chunk=4,
                            layout="paged", page_size=4, n_pages=24,
                            prefix_sharing=sharing)
        rd = eng.submit(prefix + [7, 9], 14)
        for _ in range(3):
            eng.step()                        # donor's prefix is resident
        rids = [eng.submit(prefix + [3], 5), eng.submit(list(prefix), 5)]
        if cancel_donor:
            assert eng.cancel(rd) is True
        outs = eng.run()
        return eng, rd, rids, outs

    ref, rd, rids, router = serve(sharing=False, cancel_donor=True)
    eng, rd2, rids2, outs = serve(sharing=True, cancel_donor=True)
    if eng.prefix_sharing:
        assert eng.shared_prompt_tokens > 0, "sharing never engaged"
    assert rd2 in eng.cancelled and rd2 not in outs
    for a, b in zip(rids, rids2):
        np.testing.assert_array_equal(outs[b], router[a])
    _assert_conserved(eng)


# -- deadlines and scripted faults ------------------------------------------

def test_deadline_expires_queued_and_resident():
    """Per-request deadlines drain both a queued and a resident request
    through the release path (recorded as expired, no output), leaving
    the survivor's stream bit-identical."""
    cfg, model, params = _model_params("qwen2.5-3b")

    solo = _mk(model, params, batch=2)
    rs = solo.submit(list(range(1, 7)), 10)
    souts = solo.run()

    eng = _mk(model, params, batch=2)
    rs = eng.submit(list(range(1, 7)), 10)
    ra = eng.submit(list(range(11, 17)), 10)                # resident victim
    rq = eng.submit(list(range(21, 27)), 10, priority=-1)   # queued victim
    plan = FaultPlan(events=(
        FaultEvent(cycle=2, kind="deadline", req_id=ra, deadline_ms=0.0),
        FaultEvent(cycle=2, kind="deadline", req_id=rq, deadline_ms=0.0),
    ))
    eng.set_fault_plan(plan)
    outs = eng.run()
    assert sorted(outs) == [rs]
    assert {ra, rq} <= eng.expired
    np.testing.assert_array_equal(outs[rs], souts[rs])
    _assert_conserved(eng)


def test_fault_exhaust_window_and_poison():
    """A pool-exhaustion window stalls admission until released, and a
    poisoned resident row drains with no output — the survivor stream
    rides through both untouched."""
    cfg, model, params = _model_params("qwen2.5-3b")

    solo = _mk(model, params, batch=2, n_pages=8)
    rs = solo.submit(list(range(1, 7)), 8)
    souts = solo.run()

    eng = _mk(model, params, batch=2, n_pages=8)
    rs = eng.submit(list(range(1, 7)), 8)      # needs 4 pages
    rp = eng.submit(list(range(11, 17)), 8)    # needs 4 pages
    plan = FaultPlan(events=(
        # hold 5 of 8 pages: rs (already resident after cycle 0) keeps its
        # 4, rp cannot reserve until the window closes
        FaultEvent(cycle=1, kind="exhaust_pool", pages=5),
        FaultEvent(cycle=3, kind="poison", req_id=rp),
        FaultEvent(cycle=5, kind="release_pool"),
    ))
    eng.set_fault_plan(plan)
    outs = eng.run()
    assert sorted(outs) == [rs]
    assert rp in eng.poisoned and rp not in outs
    np.testing.assert_array_equal(outs[rs], souts[rs])
    _assert_conserved(eng)


def test_ssm_engine_is_not_spillable_but_cancels_cleanly():
    """Pure-ssm has no KV pool to spill (``_spillable`` is False, no
    ``_spill``/``_restore`` closures) yet cancellation and deadlines must
    still drain recurrent lanes through the release path."""
    cfg, model, params = _model_params("mamba2-2.7b")
    eng = _mk(model, params, batch=2, layout="contiguous")
    assert not eng._spillable
    assert eng._spill is None and eng._restore is None
    rs = eng.submit(list(range(1, 7)), 8)
    rc = eng.submit(list(range(11, 17)), 8)
    eng.step()
    assert eng.cancel(rc) is True
    outs = eng.run()
    assert sorted(outs) == [rs] and rc in eng.cancelled
    assert "preemptions" not in eng.stats()
    _assert_conserved(eng)


# -- queue semantics ---------------------------------------------------------

def test_request_queue_orders_and_cancels():
    """(priority desc, deadline budget asc, arrival asc) ordering; typed
    empty-pop; locked cancel; queue-full backpressure naming the id."""
    q = RequestQueue(max_len=64, max_pending=4)
    r0 = q.submit([1, 2], 4)                              # prio 0, no SLO
    r1 = q.submit([1, 2], 4, priority=1)                  # highest
    r2 = q.submit([1, 2], 4, deadline_ms=50.0)            # tight budget
    r3 = q.submit([1, 2], 4, deadline_ms=500.0)
    assert len(q) == 4 and bool(q)
    with pytest.raises(QueueFullError, match="request 4"):
        q.submit([1, 2], 4)
    assert q.peek().req_id == r1
    assert q.cancel(r2).req_id == r2
    assert q.cancel(r2) is None                           # already gone
    assert [q.pop().req_id for _ in range(3)] == [r1, r3, r0]
    assert not q and len(q) == 0
    with pytest.raises(QueueEmpty):
        q.pop()
    # rejections never consume ids: the full-queue rejection above did not
    # advance the counter, so this names the same would-be id
    with pytest.raises(ValueError, match="request 4"):
        q.submit([1] * 100, 4)                            # over max_len


def test_engine_submit_rejections_name_request():
    """Engine-level rejections carry the request id: over-length against
    max_len and pool-impossible against the page pool."""
    cfg, model, params = _model_params("qwen2.5-3b")
    eng = _mk(model, params, batch=1, max_len=16, n_pages=4)
    with pytest.raises(ValueError, match="request 0"):
        eng.submit(list(range(40)), 8)         # pool-impossible
    with pytest.raises(ValueError, match="request 0"):
        eng.submit(list(range(10)), 10)        # over max_len
    rid = eng.submit([1, 2, 3], 4)             # still admits fine after
    outs = eng.run()
    assert rid in outs
    _assert_conserved(eng)
