"""Property tests for the device-side page allocator (`repro.serving.pager`).

The layout contract's conservation law: at every moment the free-list
prefix and the mapped block-table entries *partition* the page set — no
page is simultaneously free and mapped, mapped by two rows, or lost.
Interleaved alloc-on-write / release sequences exercise it: hypothesis
generates them when installed; a seeded fallback sweep always runs, so
the invariant is covered even where dev deps are absent.  A separate
case checks the allocator state round-trips through jit unchanged (the
no-retrace requirement of the serving engine).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import pager

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property sweep falls back to seeded cases
    HAVE_HYPOTHESIS = False


def _check_partition(ps: pager.PagerState, bt) -> None:
    free, top = np.asarray(ps.free), int(ps.top)
    table = np.asarray(bt)
    n_pages = free.shape[0]
    assert 0 <= top <= n_pages
    free_ids = free[:top].tolist()
    mapped = table[table >= 0].tolist()
    assert len(set(free_ids)) == len(free_ids), "free list holds a dup"
    assert len(set(mapped)) == len(mapped), "page mapped twice"
    assert sorted(free_ids + mapped) == list(range(n_pages)), (
        "free + mapped must partition the page set"
    )


def _run_sequence(n_pages, batch, max_blocks, page_size, ops):
    """ops: [(is_release, row_bits)]: release returns the masked rows'
    pages; otherwise the masked rows advance one position (alloc)."""
    ps = pager.init_pager(n_pages)
    bt = pager.init_block_table(batch, max_blocks)
    pos = np.zeros((batch,), np.int32)
    for is_release, bits in ops:
        mask = np.array([(bits >> b) & 1 == 1 for b in range(batch)])
        if is_release:
            ps, bt = pager.release_rows(ps, bt, jnp.asarray(mask))
            pos[mask] = 0
        else:
            ps, bt = pager.alloc_on_write(
                ps, bt, jnp.asarray(pos), jnp.asarray(mask),
                page_size=page_size,
            )
            pos[mask] += 1
        _check_partition(ps, bt)


@pytest.mark.parametrize("seed", range(8))
def test_alloc_release_conserves_pages_seeded(seed):
    rng = np.random.default_rng(seed)
    n_pages = int(rng.integers(1, 11))
    batch = int(rng.integers(1, 5))
    max_blocks = int(rng.integers(1, 4))
    page_size = int(rng.integers(1, 5))
    ops = [
        (bool(rng.random() < 0.3), int(rng.integers(0, 2 ** batch)))
        for _ in range(int(rng.integers(4, 25)))
    ]
    _run_sequence(n_pages, batch, max_blocks, page_size, ops)


if HAVE_HYPOTHESIS:
    _ops = st.lists(
        st.tuples(st.booleans(), st.integers(min_value=0, max_value=15)),
        min_size=1, max_size=24,
    )

    @settings(max_examples=30, deadline=None)
    @given(
        n_pages=st.integers(min_value=1, max_value=10),
        batch=st.integers(min_value=1, max_value=4),
        max_blocks=st.integers(min_value=1, max_value=3),
        page_size=st.integers(min_value=1, max_value=4),
        ops=_ops,
    )
    def test_alloc_release_conserves_pages_hypothesis(
        n_pages, batch, max_blocks, page_size, ops
    ):
        _run_sequence(n_pages, batch, max_blocks, page_size, ops)


def test_alloc_denial_when_pool_dry():
    """More simultaneous writers than pages: the overflow rows stay
    unmapped (their writes drop) and the invariant still holds."""
    ps = pager.init_pager(2)
    bt = pager.init_block_table(4, 1)
    ps, bt = pager.alloc_on_write(
        ps, bt, jnp.zeros((4,), jnp.int32), page_size=4
    )
    _check_partition(ps, bt)
    assert int(ps.top) == 0
    assert int((np.asarray(bt) >= 0).sum()) == 2


def test_out_of_range_block_never_allocates():
    """Positions beyond the block table's coverage must not consume pages
    (a zombie row advancing past max_len would otherwise drain the pool)."""
    ps = pager.init_pager(4)
    bt = pager.init_block_table(2, 2)
    idx = jnp.asarray([0, 2 * 3], jnp.int32)          # row 1 out of range
    ps, bt = pager.alloc_on_write(ps, bt, idx, page_size=3)
    _check_partition(ps, bt)
    assert int(ps.top) == 3
    assert np.asarray(bt)[1].tolist() == [-1, -1]


def test_state_round_trips_through_jit():
    """The jitted allocator must be bit-identical to the eager one (the
    engine runs it inside `_step_n`; divergence would desync the host
    reservation ledger from device state)."""
    jalloc = jax.jit(pager.alloc_on_write, static_argnames=("page_size",))
    jfree = jax.jit(pager.release_rows)
    rng = np.random.default_rng(0)
    for _ in range(3):
        ps_e = ps_j = pager.init_pager(6)
        bt_e = bt_j = pager.init_block_table(3, 2)
        pos = np.zeros((3,), np.int32)
        for _ in range(10):
            if rng.random() < 0.3:
                mask = jnp.asarray(rng.random(3) < 0.5)
                ps_e, bt_e = pager.release_rows(ps_e, bt_e, mask)
                ps_j, bt_j = jfree(ps_j, bt_j, mask)
                pos[np.asarray(mask)] = 0
            else:
                act = jnp.asarray(rng.random(3) < 0.8)
                ps_e, bt_e = pager.alloc_on_write(
                    ps_e, bt_e, jnp.asarray(pos), act, page_size=2
                )
                ps_j, bt_j = jalloc(ps_j, bt_j, jnp.asarray(pos), act,
                                    page_size=2)
                pos[np.asarray(act)] += 1
            np.testing.assert_array_equal(np.asarray(bt_e), np.asarray(bt_j))
            np.testing.assert_array_equal(
                np.asarray(ps_e.free)[: int(ps_e.top)],
                np.asarray(ps_j.free)[: int(ps_j.top)],
            )
            assert int(ps_e.top) == int(ps_j.top)
            _check_partition(ps_j, bt_j)
    assert jalloc._cache_size() == 1
    assert jfree._cache_size() == 1


def test_pages_needed_matches_write_pattern():
    """Admission reserves exactly the blocks the decode loop touches: a
    request of total_len T writes positions 0..T-2."""
    for page_size in (1, 2, 8):
        for total in (1, 2, 7, 8, 9, 17):
            touched = {p // page_size for p in range(max(total - 1, 1))}
            assert pager.pages_needed(total, page_size) == len(touched), (
                total, page_size
            )
