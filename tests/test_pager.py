"""Property tests for the device-side page allocator (`repro.serving.pager`).

The layout contract's conservation law, refcount form, generalized to
the two-tier (device + host) pager: at every moment each tier's
free-list prefix and the pages its block tables reference *partition*
that tier's pool — free + device-resident + host-resident account for
every page and slot — each referenced page's refcount equals the number
of block-table entries pointing at it, and no (row, block) is mapped in
both tiers at once.  Interleaved alloc-on-write / release /
share-prefix / copy-on-write / spill / restore sequences exercise it
(the share step replays the engine's admission order: release the
admitted rows, map the donor's leading blocks, resume one position
before the shared frontier so the next write lands in a shared page and
CoWs; the spill/restore steps replay preemption: victims move to
private host copies and later back, gated — like the engine's
reservation ledger — on the device pool having room): hypothesis
generates them when installed; a seeded fallback sweep always runs, so
the invariant is covered even where dev deps are absent.  The recurrent-state
snapshot store reuses these primitives over boundary space (page_size 1),
so the same walk pinned to page_size 1 is its conservation property:
snapshots partition with their pages, release frees slots only at rc==0.
A separate case checks the allocator state round-trips through jit
unchanged (the no-retrace requirement of the serving engine).
"""
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import pager

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property sweep falls back to seeded cases
    HAVE_HYPOTHESIS = False


def _check_partition(ps: pager.PagerState, bt) -> None:
    free, top = np.asarray(ps.free), int(ps.top)
    rc = np.asarray(ps.rc)
    table = np.asarray(bt)
    n_pages = free.shape[0]
    assert 0 <= top <= n_pages
    free_ids = free[:top].tolist()
    assert len(set(free_ids)) == len(free_ids), "free list holds a dup"
    counts = Counter(table[table >= 0].tolist())
    free_set = set(free_ids)
    for p in range(n_pages):
        if p in free_set:
            assert counts[p] == 0, f"page {p} free and mapped"
            assert rc[p] == 0, f"free page {p} has rc {rc[p]}"
        else:
            assert rc[p] == counts[p] >= 1, (
                f"resident page {p}: rc {rc[p]} != {counts[p]} refs"
            )


def _run_sequence(n_pages, batch, max_blocks, page_size, ops):
    """ops: [(kind, row_bits, src)] — kind 0: the masked rows CoW-then-
    alloc at their position and advance (the decode-step write path);
    kind 1: release the masked rows in *both* tiers (the engine's drain
    path frees a cancelled spilled row's host slots too); kind 2: admit
    the masked rows as sharers of row ``src % batch``'s leading blocks
    (release first, the engine's reset-then-share admission), resuming
    one position short of the shared frontier so the next write
    exercises CoW; kind 3: spill the masked rows to private host copies
    (preemption — spilled rows stop writing, donating, and sharing until
    restored, as the engine's freeze/prefix-eviction guarantees); kind
    4: restore the masked spilled rows, gated on the device pool having
    room for every host-mapped block (the engine's reservation ledger).

    After every op, each tier's partition law must hold and no
    (row, block) may be mapped on the device and the host at once."""
    ps = pager.init_pager(n_pages)
    bt = pager.init_block_table(batch, max_blocks)
    # host tier worst-case sized, like the engine: spill can never go dry
    hs = pager.init_pager(batch * max_blocks)
    ht = pager.init_block_table(batch, max_blocks)
    pos = np.zeros((batch,), np.int32)
    spilled = np.zeros((batch,), bool)
    for kind, bits, src in ops:
        mask = np.array([(bits >> b) & 1 == 1 for b in range(batch)])
        if kind == 1:
            ps, bt = pager.release_rows(ps, bt, jnp.asarray(mask))
            hs, ht = pager.release_rows(hs, ht, jnp.asarray(mask))
            pos[mask] = 0
            spilled[mask] = False
        elif kind == 2:
            src = src % batch
            mask[src] = False            # the engine never self-donates
            mask &= ~spilled             # spilled rows neither join...
            if spilled[src]:             # ...nor donate (prefix-evicted)
                mask[:] = False
            if mask.any():
                ps, bt = pager.release_rows(ps, bt, jnp.asarray(mask))
                row = np.asarray(bt)[src]
                nblk = 0
                while nblk < max_blocks and row[nblk] >= 0:
                    nblk += 1
                ps, bt = pager.share_prefix(
                    ps, bt, jnp.full((batch,), src, jnp.int32),
                    jnp.full((batch,), nblk, jnp.int32), jnp.asarray(mask),
                )
                pos[mask] = max(nblk * page_size - 1, 0)
        elif kind == 3:
            mask &= ~spilled
            if mask.any():
                ps, bt, hs, ht, _, _ = pager.spill_rows(
                    ps, bt, hs, ht, jnp.asarray(mask)
                )
                spilled[mask] = True
        elif kind == 4:
            mask &= spilled
            need = int((np.asarray(ht)[mask] >= 0).sum())
            if mask.any() and need <= int(ps.top):
                ps, bt, hs, ht, _, _ = pager.restore_rows(
                    ps, bt, hs, ht, jnp.asarray(mask)
                )
                spilled[mask] = False
        else:
            mask &= ~spilled
            ps, bt, cow_src, cow_dst, _, moved = pager.cow_on_write(
                ps, bt, jnp.asarray(pos), jnp.asarray(mask),
                page_size=page_size,
            )
            # a moved row's fresh page must be exclusively owned
            assert (np.asarray(ps.rc)[np.asarray(cow_dst)[np.asarray(moved)]]
                    == 1).all()
            ps, bt = pager.alloc_on_write(
                ps, bt, jnp.asarray(pos), jnp.asarray(mask),
                page_size=page_size,
            )
            pos[mask] += 1
        _check_partition(ps, bt)
        _check_partition(hs, ht)
        both = (np.asarray(bt) >= 0) & (np.asarray(ht) >= 0)
        assert not both.any(), "a block is mapped in both tiers"


@pytest.mark.parametrize("seed", range(8))
def test_alloc_release_conserves_pages_seeded(seed):
    rng = np.random.default_rng(seed)
    n_pages = int(rng.integers(1, 11))
    batch = int(rng.integers(1, 5))
    max_blocks = int(rng.integers(1, 4))
    page_size = int(rng.integers(1, 5))
    ops = [
        (int(rng.choice([0, 0, 0, 1, 2, 3, 3, 4])),
         int(rng.integers(0, 2 ** batch)), int(rng.integers(0, batch)))
        for _ in range(int(rng.integers(4, 25)))
    ]
    _run_sequence(n_pages, batch, max_blocks, page_size, ops)


if HAVE_HYPOTHESIS:
    _ops = st.lists(
        st.tuples(st.integers(min_value=0, max_value=4),
                  st.integers(min_value=0, max_value=15),
                  st.integers(min_value=0, max_value=3)),
        min_size=1, max_size=24,
    )

    @settings(max_examples=30, deadline=None)
    @given(
        n_pages=st.integers(min_value=1, max_value=10),
        batch=st.integers(min_value=1, max_value=4),
        max_blocks=st.integers(min_value=1, max_value=3),
        page_size=st.integers(min_value=1, max_value=4),
        ops=_ops,
    )
    def test_alloc_release_conserves_pages_hypothesis(
        n_pages, batch, max_blocks, page_size, ops
    ):
        _run_sequence(n_pages, batch, max_blocks, page_size, ops)


# -- snapshot store: boundary space is block space with page_size == 1 ------

@pytest.mark.parametrize("seed", range(6))
def test_snapshot_slots_conserve_seeded(seed):
    """The recurrent-state snapshot store (ssm/hybrid prefix sharing) runs
    these exact allocator primitives over *boundary* space — block space
    with page_size pinned to 1 (one slot per page boundary: capture
    allocates at the boundary index, admission ``share_prefix``-maps the
    donor's leading slots, release drops refs and frees only at rc==0 —
    so snapshots partition with their pages).  Same walk, page_size 1:
    the free-list prefix and the mapped slots must partition the pool and
    every slot's rc must equal its reference multiplicity."""
    rng = np.random.default_rng(1000 + seed)
    n_slots = int(rng.integers(2, 12))
    batch = int(rng.integers(1, 5))
    n_bound = int(rng.integers(1, 5))
    ops = [
        (int(rng.choice([0, 0, 0, 1, 2, 3, 3, 4])),
         int(rng.integers(0, 2 ** batch)), int(rng.integers(0, batch)))
        for _ in range(int(rng.integers(4, 25)))
    ]
    _run_sequence(n_slots, batch, n_bound, 1, ops)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(
        n_slots=st.integers(min_value=1, max_value=12),
        batch=st.integers(min_value=1, max_value=4),
        n_bound=st.integers(min_value=1, max_value=4),
        ops=_ops,
    )
    def test_snapshot_slots_conserve_hypothesis(n_slots, batch, n_bound,
                                                ops):
        """Hypothesis form of the snapshot-store conservation property
        (see the seeded variant): boundary space = page_size 1."""
        _run_sequence(n_slots, batch, n_bound, 1, ops)


def test_alloc_denial_when_pool_dry():
    """More simultaneous writers than pages: the overflow rows stay
    unmapped (their writes drop) and the invariant still holds."""
    ps = pager.init_pager(2)
    bt = pager.init_block_table(4, 1)
    ps, bt = pager.alloc_on_write(
        ps, bt, jnp.zeros((4,), jnp.int32), page_size=4
    )
    _check_partition(ps, bt)
    assert int(ps.top) == 0
    assert int((np.asarray(bt) >= 0).sum()) == 2


def test_out_of_range_block_never_allocates():
    """Positions beyond the block table's coverage must not consume pages
    (a zombie row advancing past max_len would otherwise drain the pool)."""
    ps = pager.init_pager(4)
    bt = pager.init_block_table(2, 2)
    idx = jnp.asarray([0, 2 * 3], jnp.int32)          # row 1 out of range
    ps, bt = pager.alloc_on_write(ps, bt, idx, page_size=3)
    _check_partition(ps, bt)
    assert int(ps.top) == 3
    assert np.asarray(bt)[1].tolist() == [-1, -1]


def test_state_round_trips_through_jit():
    """The jitted allocator must be bit-identical to the eager one (the
    engine runs it inside `_step_n`; divergence would desync the host
    reservation ledger from device state)."""
    jalloc = jax.jit(pager.alloc_on_write, static_argnames=("page_size",))
    jfree = jax.jit(pager.release_rows)
    rng = np.random.default_rng(0)
    for _ in range(3):
        ps_e = ps_j = pager.init_pager(6)
        bt_e = bt_j = pager.init_block_table(3, 2)
        pos = np.zeros((3,), np.int32)
        for _ in range(10):
            if rng.random() < 0.3:
                mask = jnp.asarray(rng.random(3) < 0.5)
                ps_e, bt_e = pager.release_rows(ps_e, bt_e, mask)
                ps_j, bt_j = jfree(ps_j, bt_j, mask)
                pos[np.asarray(mask)] = 0
            else:
                act = jnp.asarray(rng.random(3) < 0.8)
                ps_e, bt_e = pager.alloc_on_write(
                    ps_e, bt_e, jnp.asarray(pos), act, page_size=2
                )
                ps_j, bt_j = jalloc(ps_j, bt_j, jnp.asarray(pos), act,
                                    page_size=2)
                pos[np.asarray(act)] += 1
            np.testing.assert_array_equal(np.asarray(bt_e), np.asarray(bt_j))
            np.testing.assert_array_equal(
                np.asarray(ps_e.free)[: int(ps_e.top)],
                np.asarray(ps_j.free)[: int(ps_j.top)],
            )
            np.testing.assert_array_equal(
                np.asarray(ps_e.rc), np.asarray(ps_j.rc)
            )
            assert int(ps_e.top) == int(ps_j.top)
            _check_partition(ps_j, bt_j)
    assert jalloc._cache_size() == 1
    assert jfree._cache_size() == 1


def test_share_bumps_refcounts_and_release_keeps_shared_pages():
    """The prefix-sharing lifecycle: sharing bumps refcounts, a donor's
    release keeps shared pages resident (they outlive the row that wrote
    them), and the final holder's release returns every page."""
    ps = pager.init_pager(6)
    bt = pager.init_block_table(3, 4)
    donor_only = jnp.asarray([True, False, False])
    for p in range(8):          # donor writes blocks 0, 1 (page_size 4)
        ps, bt = pager.alloc_on_write(
            ps, bt, jnp.asarray([p, 0, 0], jnp.int32), donor_only,
            page_size=4,
        )
    ps, bt = pager.share_prefix(
        ps, bt, jnp.zeros((3,), jnp.int32), jnp.full((3,), 2, jnp.int32),
        jnp.asarray([False, True, True]),
    )
    _check_partition(ps, bt)
    pages = np.asarray(bt)[0, :2]
    assert (np.asarray(ps.rc)[pages] == 3).all()
    assert int(ps.top) == 4                 # sharing allocates nothing
    ps, bt = pager.release_rows(ps, bt, donor_only)
    _check_partition(ps, bt)
    assert int(ps.top) == 4                 # shared pages stay resident
    assert (np.asarray(ps.rc)[pages] == 2).all()
    ps, bt = pager.release_rows(ps, bt, jnp.asarray([False, True, True]))
    _check_partition(ps, bt)
    assert int(ps.top) == 6                 # last refs gone -> pool whole
    assert (np.asarray(ps.rc) == 0).all()


def test_cow_moves_writer_and_preserves_partition():
    """A write into a shared page must move the writer to a private copy:
    fresh page popped, block-table entry swapped, refcounts transferred —
    and the masked copy must carry exactly the slots below the write."""
    ps = pager.init_pager(6)
    bt = pager.init_block_table(2, 2)
    donor_only = jnp.asarray([True, False])
    for p in range(4):
        ps, bt = pager.alloc_on_write(
            ps, bt, jnp.asarray([p, 0], jnp.int32), donor_only, page_size=4,
        )
    ps, bt = pager.share_prefix(
        ps, bt, jnp.zeros((2,), jnp.int32), jnp.ones((2,), jnp.int32),
        jnp.asarray([False, True]),
    )
    shared_page = int(np.asarray(bt)[0, 0])
    ps, bt, src, dst, lim, moved = pager.cow_on_write(
        ps, bt, jnp.asarray([0, 3], jnp.int32), jnp.asarray([False, True]),
        page_size=4,
    )
    _check_partition(ps, bt)
    assert bool(np.asarray(moved)[1]) and not bool(np.asarray(moved)[0])
    new_page = int(np.asarray(bt)[1, 0])
    assert new_page != shared_page
    assert int(np.asarray(ps.rc)[shared_page]) == 1   # donor's ref remains
    assert int(np.asarray(ps.rc)[new_page]) == 1
    assert np.asarray(lim)[1] == 3                    # copy slots 0..2
    # the masked copy: slots below the write come over, the rest zero
    pool = jnp.arange(6 * 4 * 1 * 2, dtype=jnp.float32).reshape(1, 6, 4, 1, 2)
    out = np.asarray(pager.copy_page_prefix(pool, src, dst, lim))
    np.testing.assert_array_equal(
        out[0, new_page, :3], np.asarray(pool)[0, shared_page, :3]
    )
    assert (out[0, new_page, 3:] == 0).all()
    # donor's page content untouched
    np.testing.assert_array_equal(
        out[0, shared_page], np.asarray(pool)[0, shared_page]
    )


def test_simultaneous_cow_frees_orphaned_page():
    """Two sharers CoW-ing the same page in one step after the donor is
    gone: both drop their refs, the page hits rc 0 mid-step and must land
    back on the free list — not leak."""
    ps = pager.init_pager(5)
    bt = pager.init_block_table(3, 1)
    donor_only = jnp.asarray([True, False, False])
    for p in range(2):
        ps, bt = pager.alloc_on_write(
            ps, bt, jnp.asarray([p, 0, 0], jnp.int32), donor_only,
            page_size=2,
        )
    ps, bt = pager.share_prefix(
        ps, bt, jnp.zeros((3,), jnp.int32), jnp.ones((3,), jnp.int32),
        jnp.asarray([False, True, True]),
    )
    ps, bt = pager.release_rows(ps, bt, donor_only)
    shared_page = int(np.asarray(bt)[1, 0])
    assert int(np.asarray(ps.rc)[shared_page]) == 2
    ps, bt, _, _, _, moved = pager.cow_on_write(
        ps, bt, jnp.asarray([0, 1, 1], jnp.int32),
        jnp.asarray([False, True, True]), page_size=2,
    )
    _check_partition(ps, bt)
    assert np.asarray(moved)[1:].all()
    assert int(np.asarray(ps.rc)[shared_page]) == 0
    assert shared_page in np.asarray(ps.free)[: int(ps.top)].tolist()


def test_cow_noop_without_sharing():
    """With every refcount <= 1 (no sharing anywhere) the CoW pass must
    not move anything — the no-sharing engine runs the same trace as a
    plain allocator."""
    ps = pager.init_pager(4)
    bt = pager.init_block_table(2, 2)
    for p in range(3):
        ps, bt = pager.alloc_on_write(
            ps, bt, jnp.asarray(p, jnp.int32), page_size=2
        )
    before = (np.asarray(ps.free).copy(), int(ps.top),
              np.asarray(ps.rc).copy(), np.asarray(bt).copy())
    ps, bt, _, _, _, moved = pager.cow_on_write(
        ps, bt, jnp.asarray([2, 2], jnp.int32), page_size=2
    )
    assert not np.asarray(moved).any()
    np.testing.assert_array_equal(np.asarray(ps.free), before[0])
    assert int(ps.top) == before[1]
    np.testing.assert_array_equal(np.asarray(ps.rc), before[2])
    np.testing.assert_array_equal(np.asarray(bt), before[3])


def test_spill_restore_round_trips_pages_and_content():
    """Spill then restore: the row's mapping moves to private host slots
    and back to (fresh) private device pages, page *content* survives the
    round trip bit-exactly through ``copy_pages``, and both pools end
    whole."""
    ps = pager.init_pager(4)
    bt = pager.init_block_table(2, 2)
    hs = pager.init_pager(4)
    ht = pager.init_block_table(2, 2)
    for p in range(4):            # both rows write two blocks each
        ps, bt = pager.alloc_on_write(
            ps, bt, jnp.full((2,), p, jnp.int32), page_size=2
        )
    pool = jnp.arange(1 * 4 * 2 * 1 * 2, dtype=jnp.float32)
    pool = pool.reshape(1, 4, 2, 1, 2)
    hpool = jnp.zeros_like(pool)
    victim = jnp.asarray([True, False])
    row0 = np.asarray(bt)[0].copy()
    want = np.asarray(pool)[0, row0]

    ps, bt, hs, ht, src, dst = pager.spill_rows(ps, bt, hs, ht, victim)
    hpool = pager.copy_pages(hpool, pool, src, dst)
    _check_partition(ps, bt)
    _check_partition(hs, ht)
    assert (np.asarray(bt)[0] == -1).all()          # off-device
    hrow = np.asarray(ht)[0]
    assert (hrow >= 0).all()
    assert (np.asarray(hs.rc)[hrow] == 1).all()     # host copy is private
    assert int(ps.top) == 2                         # victim's pages freed
    np.testing.assert_array_equal(np.asarray(hpool)[0, hrow], want)

    ps, bt, hs, ht, src, dst = pager.restore_rows(ps, bt, hs, ht, victim)
    pool = pager.copy_pages(pool, hpool, src, dst)
    _check_partition(ps, bt)
    _check_partition(hs, ht)
    drow = np.asarray(bt)[0]
    assert (drow >= 0).all() and (np.asarray(ht)[0] == -1).all()
    assert (np.asarray(ps.rc)[drow] == 1).all()     # restored rows private
    assert int(hs.top) == 4                         # host slots returned
    np.testing.assert_array_equal(np.asarray(pool)[0, drow], want)


def test_spill_of_shared_row_keeps_peer_pages_resident():
    """Spilling a donor whose pages a sharer still references: the victim
    gets a *private* host copy, the shared device pages stay resident for
    the peer (rc drops by one, no free), and restoring re-allocates
    private pages — restore never depends on the peer outliving the
    spill."""
    ps = pager.init_pager(4)
    bt = pager.init_block_table(2, 2)
    hs = pager.init_pager(4)
    ht = pager.init_block_table(2, 2)
    donor_only = jnp.asarray([True, False])
    for p in range(4):
        ps, bt = pager.alloc_on_write(
            ps, bt, jnp.asarray([p, 0], jnp.int32), donor_only, page_size=2,
        )
    ps, bt = pager.share_prefix(
        ps, bt, jnp.zeros((2,), jnp.int32), jnp.full((2,), 2, jnp.int32),
        jnp.asarray([False, True]),
    )
    shared = np.asarray(bt)[0].copy()
    ps, bt, hs, ht, _, _ = pager.spill_rows(ps, bt, hs, ht, donor_only)
    _check_partition(ps, bt)
    _check_partition(hs, ht)
    assert int(ps.top) == 2                          # nothing freed: peer holds
    assert (np.asarray(ps.rc)[shared] == 1).all()    # donor's refs dropped
    np.testing.assert_array_equal(np.asarray(bt)[1], shared)
    assert (np.asarray(ht)[0] >= 0).all()            # private host copy
    ps, bt, hs, ht, _, _ = pager.restore_rows(ps, bt, hs, ht, donor_only)
    _check_partition(ps, bt)
    _check_partition(hs, ht)
    restored = np.asarray(bt)[0]
    assert (restored >= 0).all()
    assert not set(restored.tolist()) & set(shared.tolist())  # fresh pages
    assert (np.asarray(ps.rc)[restored] == 1).all()


def test_copy_pages_snapshot_axis_round_trip():
    """``copy_pages`` with ``axis=0`` (slot-major snapshot pools) moves
    whole slots and drops out-of-range sentinels — the hsnap spill path."""
    pool = jnp.arange(4 * 3, dtype=jnp.float32).reshape(4, 3)
    hpool = jnp.zeros((5, 3), jnp.float32)
    src = jnp.asarray([2, 0, 4], jnp.int32)     # 4 = sentinel (n_src == 4)
    dst = jnp.asarray([1, 3, 5], jnp.int32)     # 5 = sentinel (drop)
    out = np.asarray(pager.copy_pages(hpool, pool, src, dst, axis=0))
    np.testing.assert_array_equal(out[1], np.asarray(pool)[2])
    np.testing.assert_array_equal(out[3], np.asarray(pool)[0])
    assert (out[[0, 2, 4]] == 0).all()


def test_pages_needed_matches_write_pattern():
    """Admission reserves exactly the blocks the decode loop touches: a
    request of total_len T writes positions 0..T-2."""
    for page_size in (1, 2, 8):
        for total in (1, 2, 7, 8, 9, 17):
            touched = {p // page_size for p in range(max(total - 1, 1))}
            assert pager.pages_needed(total, page_size) == len(touched), (
                total, page_size
            )


# ---------------------------------------------------------------------------
# Quantized pools (kv_dtype="int8"): the scale pool partitions with the
# pages — every write/CoW/spill move that touches a page's payload moves
# its per-(page, head) scale in the same masked operation, so dequantized
# content survives every allocator move bit-exactly.
# ---------------------------------------------------------------------------


def _dq(pool, scale):
    """Dequantize a per-layer pool: (n_pages, S, Hkv, hd) x (n_pages, Hkv)."""
    return np.asarray(pool, np.float32) * np.asarray(scale)[:, None, :, None]


def test_write_page_quant_bound_scale_and_masking():
    """One-shot quantized token writes: dequantized error stays within
    half a quantization step of the page scale, the scale is exactly
    amax/127, and a masked row moves neither payload nor scale."""
    rng = np.random.default_rng(0)
    pool = jnp.zeros((4, 2, 2, 3), jnp.int8)
    scale = jnp.zeros((4, 2), jnp.float32)
    bt = jnp.asarray([[0, -1], [2, -1]], jnp.int32)
    toks = [jnp.asarray(rng.normal(size=(2, 2, 3)) * 3.0, jnp.float32)
            for _ in range(2)]
    active = jnp.asarray([True, False])
    for idx, new in enumerate(toks):
        pool, scale = pager.write_page_quant(
            pool, scale, new, bt, jnp.asarray(idx, jnp.int32), active
        )
    sc = np.asarray(scale)
    assert (sc[2] == 0).all() and (np.asarray(pool)[2] == 0).all(), (
        "masked row leaked a write into its page"
    )
    want_amax = np.maximum(
        np.abs(np.asarray(toks[0][0])).max(-1),
        np.abs(np.asarray(toks[1][0])).max(-1),
    )
    np.testing.assert_allclose(sc[0], want_amax / 127.0, rtol=1e-6)
    got = _dq(pool, scale)[0]                      # (S, Hkv, hd)
    for slot in range(2):
        err = np.abs(got[slot] - np.asarray(toks[slot][0]))
        assert (err <= 0.5 * sc[0][:, None] + 1e-7).all(), (
            f"slot {slot}: error above half a quantization step"
        )


def test_write_page_quant_slot0_resets_stale_scale():
    """A freed page carries a stale scale; the next row's slot-0 write
    must reset it to the fresh token's amax, not max-merge with it —
    otherwise one loud former tenant coarsens every later tenant."""
    pool = jnp.zeros((2, 2, 1, 2), jnp.int8)
    scale = jnp.full((2, 1), 100.0, jnp.float32)   # stale from a past row
    bt = jnp.asarray([[0]], jnp.int32)
    new = jnp.asarray([[[0.5, -0.25]]], jnp.float32)
    pool, scale = pager.write_page_quant(
        pool, scale, new, bt, jnp.asarray(0, jnp.int32)
    )
    np.testing.assert_allclose(np.asarray(scale)[0, 0], 0.5 / 127.0,
                               rtol=1e-6)
    np.testing.assert_allclose(_dq(pool, scale)[0, 0, 0],
                               np.asarray(new)[0, 0], atol=0.5 * 0.5 / 127.0)


def test_write_page_chunk_quant_page_grain_scales():
    """A chunk spanning several pages quantizes each page's rung against
    that page's own amax (page-grain scales, not chunk-grain), and the
    error bound holds across every written slot."""
    rng = np.random.default_rng(1)
    page, c = 2, 5
    pool = jnp.zeros((4, page, 1, 2), jnp.int8)
    scale = jnp.zeros((4, 1), jnp.float32)
    bt = jnp.asarray([[0, 1, 2]], jnp.int32)
    new = jnp.asarray(rng.normal(size=(1, c, 1, 2)) * 2.0, jnp.float32)
    pool, scale = pager.write_page_chunk_quant(
        pool, scale, new, bt, jnp.asarray(0, jnp.int32),
        jnp.asarray(c, jnp.int32),
    )
    sc = np.asarray(scale)
    nf = np.asarray(new)[0]                        # (C, 1, 2)
    for blk in range(3):
        lo, hi = blk * page, min((blk + 1) * page, c)
        want = np.abs(nf[lo:hi]).max(axis=(0, 2)) / 127.0
        np.testing.assert_allclose(sc[blk], want, rtol=1e-6)
    got = _dq(pool, scale)
    for t in range(c):
        blk, slot = t // page, t % page
        err = np.abs(got[blk, slot] - nf[t])
        assert (err <= 0.5 * sc[blk][:, None] + 1e-7).all()


def test_cow_moves_scale_with_prefix():
    """CoW on a quantized pool: ``copy_page_scale`` rides the same
    (src, dst) plan as ``copy_page_prefix``, so the moved prefix
    dequantizes bit-identically on the fresh page and unmoved rows drop
    through the sentinel."""
    ps = pager.init_pager(6)
    bt = pager.init_block_table(2, 2)
    donor_only = jnp.asarray([True, False])
    for p in range(4):
        ps, bt = pager.alloc_on_write(
            ps, bt, jnp.asarray([p, 0], jnp.int32), donor_only, page_size=4,
        )
    ps, bt = pager.share_prefix(
        ps, bt, jnp.zeros((2,), jnp.int32), jnp.ones((2,), jnp.int32),
        jnp.asarray([False, True]),
    )
    shared_page = int(np.asarray(bt)[0, 0])
    ps, bt, src, dst, lim, moved = pager.cow_on_write(
        ps, bt, jnp.asarray([0, 3], jnp.int32), jnp.asarray([False, True]),
        page_size=4,
    )
    new_page = int(np.asarray(bt)[1, 0])
    rng = np.random.default_rng(2)
    pool = jnp.asarray(rng.integers(-127, 128, size=(1, 6, 4, 1, 2)),
                       jnp.int8)
    scales = jnp.asarray(rng.uniform(0.01, 0.1, size=(1, 6, 1)), jnp.float32)
    before = np.asarray(scales).copy()
    out_pool = pager.copy_page_prefix(pool, src, dst, lim)
    out_sc = pager.copy_page_scale(scales, src, dst)
    got = (np.asarray(out_pool[0], np.float32)
           * np.asarray(out_sc)[0, :, None, :, None])
    want = (np.asarray(pool[0], np.float32)
            * before[0, :, None, :, None])
    np.testing.assert_array_equal(got[new_page, :3], want[shared_page, :3])
    # only the moved row's dst page changed; every other scale is intact
    keep = np.ones(6, bool)
    keep[new_page] = False
    np.testing.assert_array_equal(np.asarray(out_sc)[0, keep],
                                  before[0, keep])


def test_spill_restore_quant_round_trip():
    """Spill and restore move the int8 payload and the scale pool through
    the same (src, dst) page plans, so the victim's dequantized content
    survives the host round trip bit-exactly."""
    ps = pager.init_pager(4)
    bt = pager.init_block_table(2, 2)
    hs = pager.init_pager(4)
    ht = pager.init_block_table(2, 2)
    for p in range(4):
        ps, bt = pager.alloc_on_write(
            ps, bt, jnp.full((2,), p, jnp.int32), page_size=2
        )
    rng = np.random.default_rng(3)
    pool = jnp.asarray(rng.integers(-127, 128, size=(1, 4, 2, 1, 2)),
                       jnp.int8)
    sc = jnp.asarray(rng.uniform(0.01, 0.1, size=(1, 4, 1)), jnp.float32)
    hpool = jnp.zeros_like(pool)
    hsc = jnp.zeros_like(sc)
    victim = jnp.asarray([True, False])
    row0 = np.asarray(bt)[0].copy()
    want = (np.asarray(pool[0], np.float32)
            * np.asarray(sc)[0, :, None, :, None])[row0]

    ps, bt, hs, ht, src, dst = pager.spill_rows(ps, bt, hs, ht, victim)
    hpool = pager.copy_pages(hpool, pool, src, dst)
    hsc = pager.copy_pages(hsc, sc, src, dst)
    hrow = np.asarray(ht)[0]
    got_host = (np.asarray(hpool[0], np.float32)
                * np.asarray(hsc)[0, :, None, :, None])[hrow]
    np.testing.assert_array_equal(got_host, want)

    ps, bt, hs, ht, src, dst = pager.restore_rows(ps, bt, hs, ht, victim)
    pool = pager.copy_pages(pool, hpool, src, dst)
    sc = pager.copy_pages(sc, hsc, src, dst)
    drow = np.asarray(bt)[0]
    got = (np.asarray(pool[0], np.float32)
           * np.asarray(sc)[0, :, None, :, None])[drow]
    np.testing.assert_array_equal(got, want)
