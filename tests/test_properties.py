"""Hypothesis property-based tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)",
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import Backend, use_backend
from repro.core.container import Blob, MajorOrder, as_layout
from repro.kernels import ops, ref
from repro.optim import compress as GC

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@st.composite
def conv_case(draw):
    n = draw(st.integers(1, 3))
    c = draw(st.integers(1, 4))
    k = draw(st.integers(1, 4))
    stride = draw(st.integers(1, 3))
    pad = draw(st.integers(0, 2))
    h = draw(st.integers(k, 12))
    w = draw(st.integers(k, 12))
    return n, c, h, w, k, stride, pad


@given(conv_case(), st.integers(0, 2**31 - 1))
def test_im2col_col2im_adjoint(case, seed):
    """<im2col(x), y> == <x, col2im(y)> — exact adjointness, any geometry."""
    n, c, h, w, k, stride, pad = case
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (n, c, h, w))
    cols = ref.im2col(x, k, k, stride, pad)
    y = jax.random.normal(ky, cols.shape)
    lhs = jnp.vdot(cols, y)
    rhs = jnp.vdot(x, ref.col2im(y, x.shape, k, k, stride, pad))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


@given(st.integers(1, 8), st.integers(2, 32), st.integers(0, 2**31 - 1),
       st.floats(-50.0, 50.0))
def test_softmax_shift_invariance(b, v, seed, shift):
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, v)) * 5
    p1 = ref.softmax(x)
    p2 = ref.softmax(x + shift)
    np.testing.assert_allclose(p1, p2, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(p1.sum(-1), np.ones(b), rtol=1e-5)


@given(st.integers(1, 4), st.integers(2, 24), st.integers(1, 4),
       st.integers(0, 2**31 - 1))
def test_attention_causality(b, s, h, seed):
    """Perturbing token t must not change outputs at positions < t."""
    d = 8
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (b, s, h, d))
    k = jax.random.normal(k2, (b, s, h, d))
    v = jax.random.normal(k3, (b, s, h, d))
    o1 = ref.mha_attention(q, k, v, causal=True)
    t = s - 1
    k_p = k.at[:, t].add(3.0)
    v_p = v.at[:, t].add(3.0)
    o2 = ref.mha_attention(q, k_p, v_p, causal=True)
    np.testing.assert_allclose(o1[:, :t], o2[:, :t], rtol=1e-4, atol=1e-5)


@given(st.integers(1, 3), st.integers(4, 24), st.integers(1, 3),
       st.integers(0, 2**31 - 1))
def test_ssd_scan_chunk_invariance(b, s, h, seed):
    """Chunk size is an implementation detail: results must not depend on it."""
    p, n = 4, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, s, 1, n))
    cm = jax.random.normal(jax.random.fold_in(ks[3], 1), (b, s, 1, n))
    y1, f1 = ref.ssd_scan(x, dt, a, bm, cm, chunk=2)
    y2, f2 = ref.ssd_scan(x, dt, a, bm, cm, chunk=max(s, 3))
    np.testing.assert_allclose(y1, y2, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(f1, f2, rtol=2e-3, atol=2e-3)


@given(st.integers(1, 6), st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_ssd_linearity_in_x(heads, s, seed):
    """The SSD map is linear in x for fixed (dt, A, B, C)."""
    b, p, n = 1, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x1 = jax.random.normal(ks[0], (b, s, heads, p))
    x2 = jax.random.normal(ks[1], (b, s, heads, p))
    dt = jax.nn.softplus(jax.random.normal(ks[2], (b, s, heads)))
    a = -jnp.exp(jax.random.normal(ks[3], (heads,)))
    bm = jax.random.normal(ks[4], (b, s, 1, n))
    cm = jax.random.normal(jax.random.fold_in(ks[4], 1), (b, s, 1, n))
    y1, _ = ref.ssd_scan(x1, dt, a, bm, cm, chunk=8)
    y2, _ = ref.ssd_scan(x2, dt, a, bm, cm, chunk=8)
    y12, _ = ref.ssd_scan(x1 + 2.0 * x2, dt, a, bm, cm, chunk=8)
    np.testing.assert_allclose(y12, y1 + 2.0 * y2, rtol=3e-3, atol=3e-3)


@given(st.sampled_from(["bf16", "int8"]), st.integers(0, 2**31 - 1),
       st.floats(0.001, 10.0))
def test_compression_error_feedback_invariant(codec, seed, scale):
    """decode(encode(g + ef)) + new_ef == g + ef exactly (EF bookkeeping)."""
    g = {"x": jax.random.normal(jax.random.PRNGKey(seed), (16,)) * scale}
    ef = {"x": jax.random.normal(jax.random.PRNGKey(seed + 1), (16,)) * 0.01}
    q, s, ef2 = GC.compress(g, ef, codec)
    deq = GC.decompress(q, s, codec)
    np.testing.assert_allclose(
        np.asarray(deq["x"] + ef2["x"]),
        np.asarray(g["x"] + ef["x"]),
        rtol=1e-5, atol=1e-6,
    )


@given(st.integers(1, 4), st.integers(1, 6), st.integers(0, 2**31 - 1))
def test_layout_roundtrip_identity(r, c, seed):
    """as_layout row->col->row is the identity (the paper's boundary
    transpose is a pure relayout, not a value change)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (r, c))
    y = as_layout(x, MajorOrder.ROW, MajorOrder.COLUMN)
    z = as_layout(y, MajorOrder.COLUMN, MajorOrder.ROW)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(z))


@given(st.integers(2, 64), st.integers(2, 32), st.integers(0, 2**31 - 1))
def test_backend_equivalence_matmul_chain(m, n, seed):
    """Single-source dual-backend equivalence on a random op chain."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (m, n))
    w = jax.random.normal(k2, (n, 8))
    b = jax.random.normal(k3, (8,))
    outs = []
    for be in ("reference", "pallas"):
        with use_backend(be):
            outs.append(ops.relu(ops.bias_add_rows(ops.matmul(x, w), b), 0.1))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-4)


@given(st.lists(st.integers(1, 5), min_size=1, max_size=3),
       st.integers(0, 2**31 - 1))
def test_blob_reshape_preserves_count(dims, seed):
    shape = tuple(dims)
    b = Blob(jax.random.normal(jax.random.PRNGKey(seed), shape))
    flat = b.reshape((b.count,))
    assert flat.count == b.count
    np.testing.assert_array_equal(
        np.asarray(flat.data), np.asarray(b.data).reshape(-1)
    )
