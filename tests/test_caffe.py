"""The Caffe-port test suite — our analogue of the paper's Table 1 (per-
block Caffe unit tests) plus end-to-end LeNet training (their §4.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.caffe import (
    Net, Solver, lenet_cifar10, lenet_cifar10_solver, lenet_mnist,
    lenet_mnist_solver,
)
from repro.caffe.layers import build_layer
from repro.caffe.spec import LayerSpec
from repro.core import Backend, use_backend
from repro.data.synthetic import cifar10_like, mnist_like


def L(name, type_, bottoms, tops, **kw):
    return LayerSpec(name=name, type=type_, bottoms=tuple(bottoms),
                     tops=tuple(tops), **kw)


def _fd_check(layer, params, bottoms, argnum=0, eps=1e-3):
    """Finite-difference check of the layer's explicit backward."""
    tops, cache = layer.forward(params, bottoms, train=True)
    dy = [jnp.ones_like(t) for t in tops]
    bdiffs, _ = layer.backward(params, cache, dy)
    x = bottoms[argnum]
    # random probe direction
    probe = jax.random.normal(jax.random.PRNGKey(9), x.shape)

    def f(xi):
        bs = list(bottoms)
        bs[argnum] = xi
        t, _ = layer.forward(params, bs, train=True)
        return sum(ti.sum() for ti in t)

    got = (bdiffs[argnum] * probe).sum()
    want = (f(x + eps * probe) - f(x - eps * probe)) / (2 * eps)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


# -- per-block tests (Table 1 analogue) --------------------------------------

class TestConvolution:
    def _mk(self, **kw):
        spec = dict(num_output=4, kernel_size=3, stride=1, pad=1)
        spec.update(kw)
        layer = build_layer(L("c", "Convolution", ["data"], ["out"], **spec))
        params, _ = layer.init(jax.random.PRNGKey(0), [(2, 3, 8, 8)])
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 8, 8))
        return layer, params, x

    @pytest.mark.parametrize("stride,pad", [(1, 0), (1, 1), (2, 1), (2, 2)])
    def test_forward_matches_lax(self, stride, pad):
        layer, params, x = self._mk(stride=stride, pad=pad)
        (y,), _ = layer.forward(params, [x], True)
        want = jax.lax.conv_general_dilated(
            x, params["w"], (stride, stride), [(pad, pad)] * 2,
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        ) + params["b"][None, :, None, None]
        np.testing.assert_allclose(y, want, rtol=2e-5, atol=2e-5)

    def test_backward(self):
        layer, params, x = self._mk()
        _fd_check(layer, params, [x])

    def test_no_bias(self):
        layer, params, x = self._mk(bias_term=False)
        assert "b" not in params
        (y,), _ = layer.forward(params, [x], True)
        assert y.shape == (2, 4, 8, 8)


class TestInnerProduct:
    def test_forward_backward(self):
        layer = build_layer(
            L("ip", "InnerProduct", ["data"], ["out"], num_output=7)
        )
        params, _ = layer.init(jax.random.PRNGKey(0), [(4, 3, 5, 5)])
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 3, 5, 5))
        (y,), cache = layer.forward(params, [x], True)
        want = x.reshape(4, -1) @ params["w"] + params["b"]
        np.testing.assert_allclose(y, want, rtol=1e-5, atol=1e-5)
        _fd_check(layer, params, [x])

    def test_paper_listing_functor(self):
        # Listing 1.2: dot_product + matrixPlusVectorRows over rows
        from repro.core import matrix_plus_vector_rows

        m = jnp.arange(12.0).reshape(3, 4)
        v = jnp.array([1.0, 2.0, 3.0, 4.0])
        np.testing.assert_array_equal(
            matrix_plus_vector_rows(m, v), m + v[None, :]
        )


class TestPooling:
    @pytest.mark.parametrize("pool", ["max", "ave"])
    @pytest.mark.parametrize("k,s", [(2, 2), (3, 2)])
    def test_forward_backward(self, pool, k, s):
        layer = build_layer(
            L("p", "Pooling", ["data"], ["out"], kernel_size=k, stride=s,
              pool=pool)
        )
        params, _ = layer.init(jax.random.PRNGKey(0), [(2, 3, 9, 9)])
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 9, 9))
        (y,), cache = layer.forward(params, [x], True)
        if pool == "max":
            want = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 1, k, k), (1, 1, s, s), "VALID"
            )
            np.testing.assert_allclose(y, want)
        _fd_check(layer, params, [x])


class TestReLU:
    @pytest.mark.parametrize("slope", [0.0, 0.1])
    def test_leaky(self, slope):
        layer = build_layer(
            L("r", "ReLU", ["x"], ["y"], negative_slope=slope)
        )
        x = jnp.array([-2.0, -0.5, 0.0, 0.5, 2.0])
        (y,), cache = layer.forward({}, [x], True)
        np.testing.assert_allclose(y, jnp.where(x > 0, x, slope * x))
        (dx,), _ = layer.backward({}, cache, [jnp.ones_like(x)])
        np.testing.assert_allclose(dx, jnp.where(x > 0, 1.0, slope))


class TestSoftmax:
    def test_forward_probabilities(self):
        layer = build_layer(L("s", "Softmax", ["x"], ["p"]))
        x = jax.random.normal(jax.random.PRNGKey(0), (6, 10)) * 5
        (p,), _ = layer.forward({}, [x], True)
        np.testing.assert_allclose(p.sum(-1), np.ones(6), rtol=1e-6)
        assert (p >= 0).all()

    def test_backward_vs_autodiff(self):
        layer = build_layer(L("s", "Softmax", ["x"], ["p"]))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 7))
        dy = jax.random.normal(jax.random.PRNGKey(1), (4, 7))
        (_, ), cache = layer.forward({}, [x], True)
        (dx,), _ = layer.backward({}, cache, [dy])
        want = jax.grad(
            lambda x: (jax.nn.softmax(x, -1) * dy).sum()
        )(x)
        np.testing.assert_allclose(dx, want, rtol=1e-4, atol=1e-6)


class TestSoftmaxWithLoss:
    def test_loss_and_gradient(self):
        layer = build_layer(L("l", "SoftmaxWithLoss", ["x", "label"], ["loss"]))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 10))
        lab = jax.random.randint(jax.random.PRNGKey(1), (8,), 0, 10)
        (loss,), cache = layer.forward({}, [x, lab], True)
        want = -jax.nn.log_softmax(x)[jnp.arange(8), lab].mean()
        np.testing.assert_allclose(loss, want, rtol=1e-6)
        (dx, _), _ = layer.backward({}, cache, [jnp.ones(())])
        gwant = jax.grad(
            lambda x: -jax.nn.log_softmax(x)[jnp.arange(8), lab].mean()
        )(x)
        np.testing.assert_allclose(dx, gwant, rtol=1e-5, atol=1e-7)


class TestAccuracy:
    def test_top1(self):
        layer = build_layer(L("a", "Accuracy", ["x", "label"], ["acc"]))
        x = jnp.eye(10)[:8] * 3.0
        lab = jnp.arange(8)
        (acc,), _ = layer.forward({}, [x, lab], False)
        assert float(acc) == 1.0
        lab_wrong = (lab + 5) % 10
        (acc2,), _ = layer.forward({}, [x, lab_wrong], False)
        assert float(acc2) == 0.0

    def test_top5(self):
        layer = build_layer(
            L("a", "Accuracy", ["x", "label"], ["acc"], top_k=5)
        )
        # unambiguous ranking: logits strictly increasing in class id
        x = jnp.tile(jnp.arange(10.0)[None, :], (4, 1))
        in_top5 = jnp.array([9, 7, 5, 6])      # ranks 0,2,4,3
        (acc,), _ = layer.forward({}, [x, in_top5], False)
        assert float(acc) == 1.0
        out_top5 = jnp.array([0, 1, 2, 3])     # ranks 9,8,7,6
        (acc2,), _ = layer.forward({}, [x, out_top5], False)
        assert float(acc2) == 0.0


# -- net-level ----------------------------------------------------------------

@pytest.mark.parametrize("mk,stream", [
    (lenet_mnist, mnist_like), (lenet_cifar10, cifar10_like)
])
def test_manual_backward_matches_autodiff(mk, stream):
    """Caffe's explicit backprop chain == jax.grad through the same net."""
    net = Net(mk())
    params = net.init(jax.random.PRNGKey(1), 4)
    d, l = stream(4, seed=3).batch(0)
    g_auto = jax.grad(net.forward_loss)(params, d, l)
    g_manual = net.backward_manual(params, d, l)
    fa = dict(jax.tree_util.tree_leaves_with_path(g_auto))
    fm = dict(jax.tree_util.tree_leaves_with_path(g_manual))
    assert set(map(str, fa)) == set(map(str, fm))
    for k in fa:
        np.testing.assert_allclose(
            fa[k], fm[str(k) and k], rtol=2e-3, atol=3e-5, err_msg=str(k)
        )


def test_lenet_mnist_trains():
    net = Net(lenet_mnist())
    solver = Solver(net, lenet_mnist_solver(
        max_iter=30, batch_size=16, test_interval=30, test_batches=2))
    stream = mnist_like(16)
    state, hist = solver.solve(
        jax.random.PRNGKey(0), iter(stream), test_iter=lambda: stream.eval_iter()
    )
    assert hist["loss"][-1] < hist["loss"][0] * 0.5
    assert hist["test_acc"][-1][1] > 0.8


def test_lenet_cifar10_trains():
    # Caffe's faithful gaussian(1e-4) conv1 filler is near-dead at this tiny
    # iteration budget; xavier makes the convergence check meaningful.
    import dataclasses

    spec = lenet_cifar10()
    spec = dataclasses.replace(
        spec,
        layers=tuple(l.replace(weight_filler="xavier") for l in spec.layers),
    )
    net = Net(spec)
    solver = Solver(net, lenet_cifar10_solver(
        max_iter=60, batch_size=16, base_lr=0.01))
    stream = cifar10_like(16)
    state, hist = solver.solve(jax.random.PRNGKey(0), iter(stream))
    first = sum(hist["loss"][:5]) / 5
    last = sum(hist["loss"][-5:]) / 5
    assert last < first * 0.9, (first, last)


def test_dual_backend_lenet_equivalence():
    """The paper's core claim: one source, two targets, same results."""
    net = Net(lenet_mnist())
    params = net.init(jax.random.PRNGKey(0), 4)
    d, l = mnist_like(4).batch(0)
    outs = {}
    for be in ("reference", "pallas"):
        with use_backend(be):
            loss = net.forward_loss(params, d, l)
            grads = jax.grad(net.forward_loss)(params, d, l)
            outs[be] = (loss, grads)
    np.testing.assert_allclose(outs["reference"][0], outs["pallas"][0],
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(outs["reference"][1]),
                    jax.tree.leaves(outs["pallas"][1])):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-4)


def test_partial_port_boundary_modes_equal_results():
    """§4.3: the boundary transfers hurt performance but must not change
    results — verify all three modes agree."""
    losses = []
    for boundary in (None, "transfer", "transfer+transpose"):
        net = Net(lenet_mnist(), boundary=boundary)
        params = net.init(jax.random.PRNGKey(0), 4)
        d, l = mnist_like(4).batch(0)
        losses.append(float(net.forward_loss(params, d, l)))
    assert losses[0] == pytest.approx(losses[1], rel=1e-6)
    assert losses[0] == pytest.approx(losses[2], rel=1e-6)
