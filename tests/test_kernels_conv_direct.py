"""Fused direct-conv kernel: correctness vs oracle + the HBM-traffic claim
(never materializes the im2col matrix) checked via the HLO cost model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.conv_direct import conv2d_direct_pallas


@pytest.mark.parametrize(
    "n,c,h,w,f,k,s,p",
    [(2, 3, 12, 12, 4, 3, 1, 1), (1, 1, 28, 28, 20, 5, 1, 0),
     (2, 4, 10, 10, 8, 3, 2, 1), (1, 2, 8, 8, 3, 2, 2, 0),
     (2, 3, 9, 9, 5, 3, 3, 0), (1, 3, 16, 16, 160, 5, 1, 2)],
)
def test_conv_direct_matches_oracle(n, c, h, w, f, k, s, p):
    x = jax.random.normal(jax.random.PRNGKey(0), (n, c, h, w), jnp.float32)
    wt = jax.random.normal(jax.random.PRNGKey(1), (f, c, k, k)) * 0.2
    b = jax.random.normal(jax.random.PRNGKey(2), (f,)) * 0.1
    got = conv2d_direct_pallas(x, wt, b, stride=s, pad=p)
    want = ref.conv2d(x, wt, b, stride=s, pad=p)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_conv_direct_no_bias():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 8, 8))
    wt = jax.random.normal(jax.random.PRNGKey(1), (4, 3, 3, 3)) * 0.2
    got = conv2d_direct_pallas(x, wt, None, stride=1, pad=1)
    want = ref.conv2d(x, wt, None, stride=1, pad=1)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_conv_direct_saves_hbm_traffic_vs_im2col():
    """The fusion claim, measured: reference im2col+GEMM moves strictly
    more bytes than the fused direct conv for the same problem."""
    from repro.roofline.hlo_cost import cost_from_hlo_text

    n, c, h, w, f, k = 4, 8, 28, 28, 32, 5
    x = jax.ShapeDtypeStruct((n, c, h, w), jnp.float32)
    wt = jax.ShapeDtypeStruct((f, c, k, k), jnp.float32)
    b = jax.ShapeDtypeStruct((f,), jnp.float32)

    ref_comp = jax.jit(
        lambda x, w, b: ref.conv2d(x, w, b, stride=1, pad=2)
    ).lower(x, wt, b).compile()
    ref_cost = cost_from_hlo_text(ref_comp.as_text())
    # fused kernel in interpret mode lowers to many ops; compare against
    # the *analytic* floor instead: one input read + one output write
    analytic_floor = (n * c * (h + 4) * (w + 4) + f * c * k * k
                      + n * f * h * w) * 4
    im2col_bytes = n * c * k * k * h * w * 4  # the materialized col matrix
    # reference path must carry at least the column matrix once
    assert ref_cost.bytes > im2col_bytes
    # and the floor the fused kernel targets is far below it
    assert analytic_floor < 0.25 * ref_cost.bytes
