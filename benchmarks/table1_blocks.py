"""Table 1 analogue — per-block correctness sweeps for the ported blocks.

The paper reports Caffe unit-test pass rates for its PHAST port
(Convolution 3/15, Pooling 11/11, InnerProduct 9/9, SoftMax 4/4,
SoftMaxLoss 4/4, Accuracy 9/12).  We run the same *kind* of table against
our port: every block's Pallas lowering vs the reference oracle across a
case sweep, reporting passed/total per block.
"""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import use_backend
from repro.kernels import ops, ref


def _agree(fn_case: Callable[[], Tuple[np.ndarray, np.ndarray]],
           rtol=2e-3, atol=2e-3) -> bool:
    try:
        got, want = fn_case()
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=rtol, atol=atol,
        )
        return True
    except AssertionError:
        return False


def _conv_cases() -> List[Callable]:
    cases = []
    for i, (shape, f, k, s, p) in enumerate([
        ((1, 1, 8, 8), 2, 3, 1, 0), ((2, 3, 12, 12), 4, 5, 1, 2),
        ((2, 3, 12, 12), 4, 3, 2, 1), ((1, 4, 28, 28), 8, 5, 1, 0),
        ((2, 2, 9, 9), 3, 2, 2, 0), ((1, 1, 6, 6), 1, 1, 1, 0),
        ((2, 8, 16, 16), 16, 3, 1, 1), ((1, 3, 32, 32), 32, 5, 1, 2),
        # gradient cases
        ((2, 3, 10, 10), 4, 3, 1, 1), ((1, 2, 8, 8), 2, 5, 1, 2),
        ((2, 4, 12, 12), 6, 3, 2, 1), ((1, 1, 28, 28), 20, 5, 1, 0),
        ((2, 2, 14, 14), 4, 7, 1, 3), ((1, 3, 8, 8), 5, 3, 3, 0),
        ((2, 1, 16, 16), 2, 4, 4, 0),
    ]):
        grad = i >= 8

        def case(shape=shape, f=f, k=k, s=s, p=p, grad=grad, i=i):
            key = jax.random.PRNGKey(i)
            x = jax.random.normal(key, shape)
            w = jax.random.normal(jax.random.fold_in(key, 1),
                                  (f, shape[1], k, k)) * 0.2
            b = jax.random.normal(jax.random.fold_in(key, 2), (f,)) * 0.1

            def loss(x, w, b, be):
                with use_backend(be):
                    return (ops.conv2d(x, w, b, stride=s, pad=p) ** 2).sum()

            if grad:
                ga = jax.grad(loss, (0, 1, 2))(x, w, b, "pallas")
                gb = jax.grad(loss, (0, 1, 2))(x, w, b, "reference")
                return (jnp.concatenate([g.reshape(-1) for g in ga]),
                        jnp.concatenate([g.reshape(-1) for g in gb]))
            with use_backend("pallas"):
                got = ops.conv2d(x, w, b, stride=s, pad=p)
            with use_backend("reference"):
                want = ops.conv2d(x, w, b, stride=s, pad=p)
            return got, want

        cases.append(case)
    return cases


def _pool_cases() -> List[Callable]:
    cases = []
    for i, (shape, k, s, p) in enumerate([
        ((2, 3, 8, 8), 2, 2, 0), ((1, 4, 9, 9), 3, 3, 0),
        ((2, 2, 12, 12), 2, 2, 0), ((1, 1, 28, 28), 2, 2, 0),
        ((2, 3, 8, 8), 2, 2, 1), ((1, 2, 16, 16), 4, 4, 0),
        # bwd cases
        ((2, 3, 8, 8), 2, 2, 0), ((1, 4, 9, 9), 3, 3, 0),
        ((2, 2, 16, 16), 4, 4, 0), ((1, 3, 12, 12), 2, 3, 0),
        ((1, 1, 10, 10), 5, 5, 0),
    ]):
        grad = i >= 6

        def case(shape=shape, k=k, s=s, p=p, grad=grad, i=i):
            x = jax.random.normal(jax.random.PRNGKey(i), shape)

            def loss(x, be):
                with use_backend(be):
                    return (ops.maxpool(x, k, s, p) ** 2).sum()

            if grad:
                return (jax.grad(loss)(x, "pallas"),
                        jax.grad(loss)(x, "reference"))
            with use_backend("pallas"):
                got = ops.maxpool(x, k, s, p)
            with use_backend("reference"):
                want = ops.maxpool(x, k, s, p)
            return got, want

        cases.append(case)
    return cases


def _ip_cases() -> List[Callable]:
    cases = []
    for i, (m, kk, n, grad) in enumerate([
        (4, 8, 16, False), (128, 256, 64, False), (1, 32, 10, False),
        (64, 500, 10, False), (32, 800, 500, False),
        (4, 8, 16, True), (64, 128, 32, True), (16, 500, 10, True),
        (2, 3, 5, True),
    ]):
        def case(m=m, kk=kk, n=n, grad=grad, i=i):
            key = jax.random.PRNGKey(i)
            x = jax.random.normal(key, (m, kk))
            w = jax.random.normal(jax.random.fold_in(key, 1), (kk, n)) * 0.1
            b = jax.random.normal(jax.random.fold_in(key, 2), (n,)) * 0.1

            def loss(x, w, b, be):
                with use_backend(be):
                    return (ops.bias_add_rows(ops.matmul(x, w), b) ** 2).sum()

            if grad:
                ga = jax.grad(loss, (0, 1, 2))(x, w, b, "pallas")
                gb = jax.grad(loss, (0, 1, 2))(x, w, b, "reference")
                return (jnp.concatenate([g.reshape(-1) for g in ga]),
                        jnp.concatenate([g.reshape(-1) for g in gb]))
            with use_backend("pallas"):
                got = ops.bias_add_rows(ops.matmul(x, w), b)
            with use_backend("reference"):
                want = ops.bias_add_rows(ops.matmul(x, w), b)
            return got, want

        cases.append(case)
    return cases


def _softmax_cases(loss_variant: bool) -> List[Callable]:
    cases = []
    for i, (b, v) in enumerate([(4, 10), (64, 10), (128, 1000), (3, 2)]):
        def case(b=b, v=v, i=i):
            key = jax.random.PRNGKey(i)
            x = jax.random.normal(key, (b, v)) * 4
            y = jax.random.randint(jax.random.fold_in(key, 1), (b,), 0, v)
            if loss_variant:
                with use_backend("pallas"):
                    g1 = jax.grad(
                        lambda x: ops.softmax_xent_loss(x, y))(x)
                with use_backend("reference"):
                    g2 = jax.grad(
                        lambda x: ops.softmax_xent_loss(x, y))(x)
                return g1, g2
            with use_backend("pallas"):
                got = ops.softmax(x)
            with use_backend("reference"):
                want = ops.softmax(x)
            return got, want

        cases.append(case)
    return cases


def _accuracy_cases() -> List[Callable]:
    cases = []
    for i, (b, v, k) in enumerate([
        (8, 10, 1), (64, 10, 1), (128, 100, 1), (8, 10, 5), (64, 100, 5),
        (16, 1000, 5), (4, 10, 1), (32, 50, 1), (8, 10, 1), (16, 10, 5),
        (128, 10, 1), (256, 10, 5),
    ]):
        def case(b=b, v=v, k=k, i=i):
            key = jax.random.PRNGKey(i)
            x = jax.random.normal(key, (b, v))
            y = jax.random.randint(jax.random.fold_in(key, 1), (b,), 0, v)
            got = ops.accuracy(x, y, k)
            _, idx = jax.lax.top_k(x, k)
            want = (idx == y[:, None]).any(-1).mean()
            return got, want

        cases.append(case)
    return cases


def run() -> List[Tuple[str, int, int]]:
    table = []
    for name, cases in [
        ("Convolution", _conv_cases()),
        ("Pooling", _pool_cases()),
        ("InnerProduct", _ip_cases()),
        ("SoftMax", _softmax_cases(False)),
        ("SoftMaxLoss", _softmax_cases(True)),
        ("Accuracy", _accuracy_cases()),
    ]:
        passed = sum(_agree(c) for c in cases)
        table.append((name, passed, len(cases)))
    return table


def main():
    print("block,passed,total,pct  (paper's PHAST port: conv 20%, pool 100%,"
          " ip 100%, softmax 100%, loss 100%, accuracy 75%)")
    for name, passed, total in run():
        print(f"{name},{passed},{total},{100*passed//total}")


if __name__ == "__main__":
    main()
