"""Benchmark harness — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

  table1  per-block correctness pass rates (paper Table 1)
  table2  LeNet fwd-bwd ms + partial-port boundary ablation (paper Table 2,
          §4.3 transfer/layout analysis)
  kernels microbenchmark of Pallas kernels (interpret) vs reference oracle
          wall time — NOT a TPU perf claim, a correctness-per-cost sweep
  roofline summary of the dry-run roofline table (if experiments/dryrun
          exists; the full table lives in EXPERIMENTS.md)
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    quick = "--quick" in sys.argv
    print("== table1: per-block pass rates (paper Table 1 analogue) ==")
    from benchmarks import table1_blocks
    table1_blocks.main()

    print()
    print("== table2: LeNet fwd-bwd + partial-port ablation (Table 2) ==")
    from benchmarks import table2_fwbw
    table2_fwbw.main()

    print()
    print("== serving: host-loop vs engine + paged-vs-contiguous KV ==")
    from benchmarks import serve_engine
    serve_engine.main(["--quick"] if quick else [])

    print()
    print("== roofline: dry-run summary (see EXPERIMENTS.md for analysis) ==")
    import pathlib
    if pathlib.Path("experiments/dryrun").exists():
        from benchmarks import roofline_table
        roofline_table.main()
    else:
        print("experiments/dryrun missing - run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all")


if __name__ == "__main__":
    main()
