"""Table 2 analogue — average forward-backward wall time for the two LeNet
networks, plus the paper's §4.3 partial-port ablation.

The paper measures (ms per fwd+bwd iteration):
                   MNIST            CIFAR-10
    Caffe          71.42 (CPU)      399.50 (CPU)
    Caffe (PHAST)  198.60 (CPU)     1113.71 (CPU)   -> ~2.8x slower

and attributes most of the PHAST gap to (a) domain-crossing transfers
between ported and unported layers and (b) a row/column-major layout
conversion per crossing.  We reproduce the *mechanism*: the same net run

    fused          - jit end-to-end, single domain (our "full port")
    boundary       - host round-trip between every layer (partial port)
    boundary+T     - round-trip + forced layout transpose per crossing

The fused/boundary ratio is our measured analogue of their 2.8x.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp

from repro.caffe import Net, lenet_cifar10, lenet_mnist
from repro.data.synthetic import cifar10_like, mnist_like


def _time_fwbw(net: Net, params, data, label, iters: int = 10) -> float:
    """Mean ms per forward+backward."""
    if net.boundary is None:
        fn = jax.jit(jax.value_and_grad(net.forward_loss))
        fn(params, data, label)[0].block_until_ready()  # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            loss, _ = fn(params, data, label)
        loss.block_until_ready()
        return (time.perf_counter() - t0) / iters * 1e3
    # partial-port mode cannot be jitted end-to-end (that is the point):
    # each layer runs in its own domain with host crossings between.
    net.forward_loss(params, data, label)  # warm per-layer jits
    t0 = time.perf_counter()
    for _ in range(iters):
        loss = net.forward_loss(params, data, label)
        grads = net.backward_manual(params, data, label)
    jax.block_until_ready(grads)
    return (time.perf_counter() - t0) / iters * 1e3


def run(batch: int = 64, iters: int = 5) -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = {}
    for name, mk, stream_fn in [
        ("mnist", lenet_mnist, mnist_like),
        ("cifar10", lenet_cifar10, cifar10_like),
    ]:
        data, label = stream_fn(batch).batch(0)
        res = {}
        for mode, boundary in [
            ("fused", None),
            ("boundary", "transfer"),
            ("boundary+transpose", "transfer+transpose"),
        ]:
            net = Net(mk(), boundary=boundary)
            params = net.init(jax.random.PRNGKey(0), batch)
            res[mode] = _time_fwbw(net, params, data, label, iters)
        res["slowdown_boundary"] = res["boundary"] / res["fused"]
        res["slowdown_boundary_transpose"] = (
            res["boundary+transpose"] / res["fused"]
        )
        out[name] = res
    return out


def main():
    print("net,mode,ms_per_fwbw,derived")
    for name, res in run().items():
        for mode in ("fused", "boundary", "boundary+transpose"):
            print(f"{name},{mode},{res[mode]:.2f},")
        print(f"{name},slowdown_boundary,,"
              f"{res['slowdown_boundary']:.2f}x")
        print(f"{name},slowdown_boundary_transpose,,"
              f"{res['slowdown_boundary_transpose']:.2f}x "
              f"(paper's partial-port gap: 2.8x CPU / 4.0x GPU)")


if __name__ == "__main__":
    main()
