"""Roofline table assembler — reads the dry-run JSON records and emits the
EXPERIMENTS.md §Roofline table (CSV + markdown)."""
from __future__ import annotations

import json
import pathlib
import sys
from typing import List


def load(dirpath="experiments/dryrun") -> List[dict]:
    out = []
    for p in sorted(pathlib.Path(dirpath).glob("*.json")):
        out.append(json.loads(p.read_text()))
    return out


def fmt_row(d: dict) -> str:
    if d["status"] != "ok":
        return (f"{d['arch']},{d['shape']},{d['mesh']},{d['status']},,,,,,,"
                f"{d.get('reason', d.get('error', ''))[:60]}")
    return (
        f"{d['arch']},{d['shape']},{d['mesh']},ok,"
        f"{d['t_compute']:.4f},{d['t_memory']:.4f},{d['t_collective']:.4f},"
        f"{d['bottleneck']},{d['useful_flops_ratio']:.3f},"
        f"{d['roofline_fraction']:.3f},"
        f"{(d.get('bytes_per_device') or 0)/1e9:.2f}GB"
    )


def main():
    dirpath = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    rows = load(dirpath)
    print("arch,shape,mesh,status,t_compute_s,t_memory_s,t_collective_s,"
          "bottleneck,useful_flops_ratio,roofline_fraction,mem_per_dev")
    for d in rows:
        print(fmt_row(d))
    ok = [d for d in rows if d["status"] == "ok"]
    if ok:
        worst = min(ok, key=lambda d: d["roofline_fraction"])
        coll = max(ok, key=lambda d: d["t_collective"] /
                   max(d["t_compute"] + d["t_memory"], 1e-12))
        print(f"# worst roofline fraction: {worst['arch']}/{worst['shape']}"
              f"/{worst['mesh']} ({worst['roofline_fraction']:.3f})")
        print(f"# most collective-bound: {coll['arch']}/{coll['shape']}"
              f"/{coll['mesh']}")


if __name__ == "__main__":
    main()
