"""Serving-loop benchmark — host-side scheduler vs device-side engine.

The paper's §4.3 finding is that a partial port pays for every crossing
between the ported domain and the host orchestrator.  The two serving
loops here are that ablation, applied to continuous batching:

    host-loop   per-row Python scheduling: an ``int()`` host sync per row
                per decode step to pick prompt-vs-generated feeding and to
                test completion (the pre-engine ``examples/serve_batched``)
    engine      ``repro.serving.ServingEngine``: control state on-device,
                one fused jit per batch of steps, one host sync per cycle

Same model, same requests, greedy decode; reported number is generated
tokens per second.

A second ablation compares the two KV-cache layouts on an attention arch
at mixed prompt lengths (paged pool capped at half the contiguous slab):
gen tok/s and peak resident KV bytes, outputs token-identical.

A third ablation measures *prompt ingestion*: chunked prefill
(``--prefill-chunk`` tokens per step) vs token-by-token, long prompts
under both layouts — prefill tok/s and mean TTFT, outputs token-identical
across all four engines.

A fourth ablation measures *prefix sharing*: N requests carrying the same
long system prompt, with and without page-level prefix sharing/CoW —
sharer TTFT and peak resident KV bytes, outputs token-identical.

``--faults`` adds a *pressure* cell (robustness harness, not a perf
table): the same submission sequence served unpressured and under an
injected ``FaultPlan`` — a pool-exhaustion window that forces a
preemption (host spill) and delays the restore, a cancel, and a
deadline storm.  The pressured engine must drain the doomed requests
through the release path, finish the survivors with *bit-identical*
tokens, and hand back every page and snapshot slot in both tiers.

``--arrival poisson --rate R`` adds an open-loop cell: seeded
exponential inter-arrival gaps on the wall clock, mixed priorities, a
deliberately undersized paged pool — reporting p50/p99 TTFT plus the
preemption/restore counters (latency under load; correctness under
pressure is the ``--faults`` cell's job).

``--layout`` scopes the single-layout sections to one KV layout so a CI
matrix cell (backend x layout) exercises exactly its own path; the
inherently cross-layout ablation only runs under the default ``both``.

    PYTHONPATH=src python -m benchmarks.serve_engine [--quick]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.audit import jit_cache_audit
from repro.configs.registry import get_arch
from repro.models.model import build_model
from repro.serving import (
    CacheConfig,
    EngineConfig,
    FaultEvent,
    FaultPlan,
    ServingEngine,
    SpecConfig,
)


def _audit_ctx(eng, enabled):
    """Under --audit, fail loudly on any retrace instead of timing it."""
    import contextlib

    return jit_cache_audit(eng) if enabled else contextlib.nullcontext()


def _kv_dtype(args, layout):
    """Paged cells inherit ``--kv-dtype``; the contiguous slab is never
    quantized (CacheConfig enforces the same rule)."""
    return args.kv_dtype if layout == "paged" else "f32"


def _quant_note(section):
    """Cross-grain token-identity asserts are waived under int8 pools.

    Per-page scales make the quantization grain part of the write path: a
    chunked write quantizes a whole page rung against one amax, while a
    token-by-token write max-merges and requantizes — dequantized content
    can differ by a fraction of a quantization step, which is enough to
    flip greedy near-ties.  Cells that share one write grain (host vs
    engine at chunk 1, pressured vs unpressured) still assert exact
    identity; int8-vs-f32 parity is asserted at controlled horizons in
    tests/test_kv_quant.py."""
    print(f"  (token-identity assert waived under kv_dtype=int8: "
          f"{section} changes the quantization grain)")


def make_requests(seed, n, vocab_size, gen, lo=4, hi=12):
    rng = np.random.default_rng(seed)
    return [
        (rng.integers(0, vocab_size, size=int(rng.integers(lo, hi))).tolist(),
         gen)
        for _ in range(n)
    ]


def run_host_loop(model, params, reqs, batch, max_len, cache=None):
    """The pre-engine loop: per-row Python control with host syncs.

    One fix over the seed example is kept so the comparison is between two
    *correct* schedulers: admitted rows get their decode caches reset (the
    seed leaked the previous request's SSM state into its replacement).

    ``cache`` mirrors the engine's CacheConfig: under ``--kv-dtype int8``
    the engine-vs-host token assert is only exact when both loops write
    the same quantized pool token-by-token (same quantization grain)."""
    queue = [jnp.asarray(t, jnp.int32) for t, _ in reqs]
    gens = [g for _, g in reqs]
    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    reset = jax.jit(model.reset_decode_rows, donate_argnums=(0,))
    # compile outside the timed region (a server compiles once at startup)
    wstate = model.init_decode_state(batch, max_len, per_row_pos=True,
                                     cache=cache)
    wstate = reset(wstate, jnp.zeros((batch,), bool))
    logits, wstate = decode(params, wstate, jnp.zeros((batch,), jnp.int32))
    jax.block_until_ready(logits)
    state = model.init_decode_state(batch, max_len, per_row_pos=True,
                                    cache=cache)
    slots = [None] * batch
    progress = [0] * batch
    outputs = {}
    done = 0
    next_req = 0
    t0 = time.perf_counter()
    steps = 0
    while done < len(reqs):
        admit = np.zeros((batch,), bool)
        for b in range(batch):
            if slots[b] is None and next_req < len(reqs):
                slots[b] = next_req
                progress[b] = 0
                outputs[next_req] = []
                next_req += 1
                admit[b] = True
        if admit.any():
            state = reset(state, jnp.asarray(admit))
        toks = []
        for b in range(batch):
            r = slots[b]
            if r is None:
                toks.append(0)
            elif progress[b] < len(queue[r]):
                toks.append(int(queue[r][progress[b]]))   # host sync per row
            else:
                toks.append(int(outputs[r][-1]))          # host sync per row
        logits, state = decode(params, state, jnp.asarray(toks, jnp.int32))
        steps += 1
        nxt = jnp.argmax(logits, axis=-1)
        for b in range(batch):
            r = slots[b]
            if r is None:
                continue
            progress[b] += 1
            if progress[b] >= len(queue[r]):
                outputs[r].append(int(nxt[b]))            # host sync per row
                if len(outputs[r]) >= gens[r]:
                    done += 1
                    slots[b] = None
    dt = time.perf_counter() - t0
    total_gen = sum(gens)
    return {"tok_s": total_gen / dt, "steps": steps, "seconds": dt,
            "outputs": outputs}


def run_engine(model, params, reqs, batch, max_len, steps_per_sync,
               audit=False, cache=None, config=None):
    if config is None:
        config = EngineConfig(steps_per_sync=steps_per_sync)
    eng = ServingEngine(model, params, batch=batch, max_len=max_len,
                        cache=cache, config=config)
    with _audit_ctx(eng, audit):
        # compile outside the timed region (a server compiles once at
        # startup): a throwaway workload drives admit + fused-step
        # (+ prefill) traces once
        for _ in range(batch):
            eng.submit([1, 2, 3], 2)
        eng.run()
        eng.reset_stats()

        rids = [eng.submit(t, g) for t, g in reqs]
        t0 = time.perf_counter()
        outs = eng.run()
        dt = time.perf_counter() - t0
    ttft = [eng.ttft[r] for r in rids if r in eng.ttft]
    row = {"tok_s": eng.generated / dt, "steps": eng.steps, "seconds": dt,
           "prefill_steps": eng.prefill_steps,
           "prefill_tok_s": eng.prompt_tokens / dt,
           "ttft_ms": 1e3 * float(np.mean(ttft)) if ttft else float("nan"),
           "ttft_ms_p99": (1e3 * float(np.percentile(ttft, 99))
                           if ttft else float("nan")),
           "kv_bytes": eng.kv_resident_bytes(peak=True),
           "outputs": {i: outs[r].tolist() for i, r in enumerate(rids)}}
    if eng.spec is not None:
        st = eng.stats()
        row.update(
            spec_accepted=int(st["spec_accepted"]),
            spec_proposed=int(st["spec_proposed"]),
            spec_emitted=int(st["spec_emitted"]),
            spec_accept_rate=float(st["spec_accept_rate"]),
        )
    return row


def compare_layouts(args):
    """Paged vs contiguous at mixed prompt lengths (the memory ablation).

    Prompt lengths span >= 8x, so the contiguous slab (B x max_len per
    row, sized for the *longest* request) is mostly idle padding.  The
    paged engine's pool is capped at half the slab; throughput must hold
    while peak resident KV drops to roughly the live-token footprint."""
    cfg = get_arch(args.kv_arch)
    if cfg.is_attention_free:
        print("  (skipped: attention-free arch — no KV cache to page; "
              "recurrent state is O(1) per row under either layout)")
        return {}
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    lo, hi = 4, 33                            # >= 8x spread
    max_len = hi + args.gen + 1
    reqs = make_requests(1, args.requests, cfg.vocab_size, args.gen,
                         lo=lo, hi=hi)
    page = args.page_size
    # pool deliberately below the contiguous equivalent (half the slab),
    # but never below the largest single request's worst-case need — a
    # request bigger than the whole pool is rejected at submit
    from repro.serving.pager import pages_needed
    full_pool = args.batch * (-(-max_len // page))
    max_need = max(pages_needed(len(t) + g, page) for t, g in reqs)
    quant = args.kv_dtype != "f32"
    pool = max(max_need, full_pool // 2)
    cells = [
        ("contiguous", CacheConfig()),
        ("paged", CacheConfig(layout="paged", page_size=page, n_pages=pool,
                              kv_dtype=args.kv_dtype)),
    ]
    if quant:
        # the f32 twin of the quantized pool: token-identity baseline for
        # the contiguous compare and denominator of the exact-2x byte check
        cells.append(("paged_f32", CacheConfig(layout="paged",
                                               page_size=page,
                                               n_pages=pool)))
    rows = {}
    for name, cache in cells:
        rows[name] = run_engine(model, params, reqs, args.batch, max_len,
                                args.steps_per_sync, audit=args.audit,
                                cache=cache)
    ident = "paged_f32" if quant else "paged"
    for i in range(len(reqs)):
        a, b = rows["contiguous"]["outputs"][i], rows[ident]["outputs"][i]
        assert a == b, f"request {i}: contiguous {a} != {ident} {b}"
    ratio = {"f32": 1, "bf16": 2, "int8": 4}[args.kv_dtype]
    if quant:
        # the packed payload must land exactly on the itemsize ladder —
        # bf16 = 1/2 the f32 pool, int8 = 1/4 (per-page scales ride in a
        # side pool the byte counter deliberately excludes)
        assert rows["paged"]["kv_bytes"] * ratio == rows["paged_f32"]["kv_bytes"], (
            f"{args.kv_dtype} pool not exactly 1/{ratio} the f32 pool: "
            f"{rows['paged']['kv_bytes']} vs {rows['paged_f32']['kv_bytes']}"
        )
    print(f"arch={args.kv_arch} requests={args.requests} batch={args.batch} "
          f"gen={args.gen} prompt_len {lo}..{hi - 1} page_size={page} "
          f"kv_dtype={args.kv_dtype}")
    print(f"  {'layout':<12} {'gen tok/s':>10} {'peak KV bytes':>14} "
          f"{'vs slab':>8}")
    slab = rows["contiguous"]["kv_bytes"]
    for name, _ in cells:
        r = rows[name]
        print(f"  {name:<12} {r['tok_s']:>10.1f} {r['kv_bytes']:>14d} "
              f"{r['kv_bytes'] / slab:>7.0%}")
    if quant:
        print(f"  (contiguous vs paged_f32 token-identical; {args.kv_dtype} "
              f"resident KV exactly 1/{ratio} the f32 pool)")
    else:
        print("  (outputs token-identical)")
    return rows


def compare_prefix_sharing(args):
    """Prefix sharing on/off under the shared-system-prompt workload (the
    resident-memory + TTFT ablation).

    N requests share a long page-aligned prompt prefix (a system prompt)
    plus a short unique tail.  The donor is admitted alone and ingests the
    full prefix; the other N-1 arrive while it is still decoding — the
    exact schedule vLLM-style prefix caching exists for.  Sharing must
    leave every token identical while the sharers' TTFT and the peak
    resident KV bytes collapse (each shared page is resident once, not
    once per row)."""
    import dataclasses

    cfg = get_arch(args.kv_arch)
    if args.share_requests < 2:
        print("  (skipped: --share-requests < 2 — sharing needs a donor "
              "and at least one sharer)")
        return {}
    if args.prefill_vocab:
        cfg = dataclasses.replace(cfg, vocab_size=args.prefill_vocab)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    n = args.share_requests
    plen = args.share_prefix_len
    rng = np.random.default_rng(7)
    prefix = rng.integers(0, cfg.vocab_size, size=plen).tolist()
    tails = [rng.integers(0, cfg.vocab_size, size=4).tolist()
             for _ in range(n)]
    if plen % args.page_size == 0 and n > 1:
        # one fully shared prompt: its re-fed last token exercises CoW
        tails[-1] = []
    gen = args.prefill_gen
    # the donor must outlive the sharers' admission (one sync cycle later)
    donor_gen = gen + args.steps_per_sync + 1
    max_len = plen + 4 + donor_gen + 1

    def run(sharing):
        eng = ServingEngine(
            model, params, batch=n, max_len=max_len,
            cache=CacheConfig(layout="paged", page_size=args.page_size,
                              kv_dtype=args.kv_dtype),
            config=EngineConfig(
                steps_per_sync=args.steps_per_sync,
                prefill_chunk=args.prefill_chunk, prefix_sharing=sharing,
            ),
        )
        with _audit_ctx(eng, args.audit):
            for _ in range(2):                 # compile outside the clock
                eng.submit([1, 2, 3], 2)
            eng.run()
            eng.reset_stats()
            rid0 = eng.submit(prefix + tails[0], donor_gen)
            eng.step()                         # donor ingests the prefix
            rids = [rid0] + [
                eng.submit(prefix + t, gen) for t in tails[1:]
            ]
            pt0 = eng.prompt_tokens            # donor's pre-window tokens
            t0 = time.perf_counter()
            outs = eng.run()
            dt = time.perf_counter() - t0
        ttft = [eng.ttft[r] for r in rids[1:] if r in eng.ttft]
        return {
            "ttft_ms": 1e3 * float(np.mean(ttft)) if ttft else float("nan"),
            "prefill_tok_s": (eng.prompt_tokens - pt0) / dt,
            "kv_bytes": eng.kv_resident_bytes(peak=True),
            "shared": eng.shared_prompt_tokens,
            "cow": eng.cow_pages,
            "outputs": {i: outs[r].tolist() for i, r in enumerate(rids)},
        }

    rows = {name: run(s) for name, s in (("unshared", False),
                                         ("shared", True))}
    if args.kv_dtype != "int8":
        # holds for bf16 too: the rounding is element-wise, so shared and
        # unshared pools store bitwise-identical prefix pages
        assert rows["shared"]["outputs"] == rows["unshared"]["outputs"], (
            "prefix sharing changed tokens"
        )
    else:
        # a sharer resumes mid-page: its boundary page mixes donor-grain
        # prefix slots with tail rungs the unshared run quantized together
        _quant_note("prefix sharing")
    assert rows["shared"]["shared"] > 0, "sharing never engaged"
    print(f"arch={args.kv_arch} [{cfg.family}] requests={n} "
          f"prefix_len={plen} tail=4 gen={gen} page_size={args.page_size} "
          f"chunk={args.prefill_chunk}")
    print(f"  {'sharing':<10} {'sharer TTFT ms':>14} {'prefill tok/s':>13} "
          f"{'peak KV bytes':>14} {'shared toks':>11} {'CoW':>4}")
    for name in ("unshared", "shared"):
        r = rows[name]
        print(f"  {name:<10} {r['ttft_ms']:>14.1f} "
              f"{r['prefill_tok_s']:>13.1f} {r['kv_bytes']:>14d} "
              f"{r['shared']:>11d} {r['cow']:>4d}")
    ttft_x = rows["unshared"]["ttft_ms"] / rows["shared"]["ttft_ms"]
    msg = f"  TTFT {ttft_x:.1f}x"
    if rows["shared"]["kv_bytes"]:   # attention-free archs have no KV pages
        drop = rows["unshared"]["kv_bytes"] / rows["shared"]["kv_bytes"]
        msg = f"  resident-KV drop {drop:.1f}x," + msg[1:]
    ident = ("outputs token-identical" if args.kv_dtype != "int8"
             else "identity waived under int8")
    print(msg + f" ({ident})")
    return rows


def compare_prefill(args):
    """Chunked vs token-by-token prompt ingestion (the TTFT ablation).

    Long prompts, short generations: the workload the chunked-prefill path
    exists for.  Four engines — chunk 1 and chunk C under each KV layout —
    serve the same requests; outputs must be token-identical everywhere,
    and the table reports prompt-ingestion tok/s plus mean TTFT so the
    ``ceil(P/C)``-steps win is visible as wall-clock, not step counts.

    The smoke archs carry a toy 128-entry vocab, which erases the LM-head
    GEMM a real server pays on *every* token-by-token prompt step (the
    chunked path computes logits once per chunk).  ``--prefill-vocab``
    restores a serving-scale vocabulary for this ablation so the baseline
    is the workload the optimization targets."""
    import dataclasses

    cfg = get_arch(args.kv_arch)
    if args.prefill_vocab:
        cfg = dataclasses.replace(cfg, vocab_size=args.prefill_vocab)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    plen = args.prompt_len
    max_len = plen + args.prefill_gen + 1
    rng = np.random.default_rng(3)
    reqs = [
        (rng.integers(0, cfg.vocab_size, size=plen).tolist(),
         args.prefill_gen)
        for _ in range(args.prefill_requests)
    ]
    chunks = sorted({1, args.prefill_chunk})    # chunk 1 = the baseline
    layouts = (("contiguous", "paged") if args.layout == "both"
               else (args.layout,))
    rows = {}
    for layout in layouts:
        cache = CacheConfig(layout=layout, page_size=args.page_size,
                            kv_dtype=_kv_dtype(args, layout))
        for pc in chunks:
            rows[(layout, pc)] = run_engine(
                model, params, reqs, args.batch, max_len,
                args.steps_per_sync, audit=args.audit, cache=cache,
                config=EngineConfig(steps_per_sync=args.steps_per_sync,
                                    prefill_chunk=pc),
            )
    if args.kv_dtype != "int8":
        # bf16 included: chunked and token-by-token writes round the same
        # values element-wise, so every chunk width stores the same pool
        base = rows[(layouts[0], 1)]["outputs"]
        for key, r in rows.items():
            assert r["outputs"] == base, (
                f"{key}: outputs diverge from baseline"
            )
    else:
        _quant_note("chunk width")
    print(f"arch={args.kv_arch} requests={args.prefill_requests} "
          f"batch={args.batch} prompt_len={plen} gen={args.prefill_gen} "
          f"chunk={args.prefill_chunk}")
    print(f"  {'layout':<12} {'chunk':>5} {'prefill tok/s':>13} "
          f"{'mean TTFT ms':>12} {'gen tok/s':>10} {'steps':>6} {'pf':>4}")
    for (layout, pc), r in rows.items():
        print(f"  {layout:<12} {pc:>5d} {r['prefill_tok_s']:>13.1f} "
              f"{r['ttft_ms']:>12.1f} {r['tok_s']:>10.1f} "
              f"{r['steps']:>6d} {r['prefill_steps']:>4d}")
    if args.prefill_chunk > 1:
        ident = ("outputs token-identical" if args.kv_dtype != "int8"
                 else "identity waived under int8")
        for layout in layouts:
            speedup = (rows[(layout, args.prefill_chunk)]["prefill_tok_s"]
                       / rows[(layout, 1)]["prefill_tok_s"])
            print(f"  {layout}: prompt-ingestion speedup "
                  f"{speedup:.2f}x ({ident})")
    return rows


def _lookup_score(seq, plen, ngram):
    """Fraction of generated positions a prompt-lookup drafter would have
    predicted: the continuation after the most recent earlier match of the
    trailing n-gram equals the actual next token."""
    hits = total = 0
    for t in range(max(plen, ngram), len(seq)):
        key = tuple(seq[t - ngram:t])
        pred = None
        for s in range(t - ngram - 1, -1, -1):
            if tuple(seq[s:s + ngram]) == key:
                pred = seq[s + ngram]
                break
        total += 1
        hits += pred == seq[t]
    return hits / max(total, 1)


def run_spec(args):
    """Speculative decoding: drafted tokens through the chunked verifier
    vs plain decode (the accept-rate / latency ablation).

    The workload is the one prompt lookup exists for — generations that
    repeat their own context (the summarization / code-copy regime).  A
    randomly-initialised smoke model only settles into an n-gram-
    predictable greedy cycle on a fraction of prompts, so a pre-pass
    decodes 20x candidate repeated-suffix prompts once and keeps the rows
    whose continuation a lookup drafter would actually predict — selecting
    the target regime rather than hoping random weights land in it.

    Each K runs against a shared K=0 baseline per layout; outputs must be
    token-identical at every K (greedy acceptance emits only verifier-
    argmax tokens, so speculation is a pure latency move), at least one
    draft must be accepted, and at benchmark scale (gen >= 16) the best
    prompt-lookup K must clear 1.3x the baseline's gen tok/s."""
    cfg = get_arch(args.kv_arch)
    ks = [int(k) for k in str(args.spec_k).split(",") if k.strip()]
    ks = sorted({k for k in ks if k > 0})
    if not ks:
        print("  (skipped: --spec-k 0 — no draft widths requested)")
        return {}
    if args.spec_drafter == "hybrid_ssm" and cfg.family != "hybrid":
        print("  (skipped: drafter='hybrid_ssm' drafts with the hybrid "
              "family's own Mamba layers — pick --family hybrid)")
        return {}
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    gen = args.gen
    rng = np.random.default_rng(11)
    cands = []
    for _ in range(20 * args.requests):
        motif = rng.integers(0, cfg.vocab_size, size=3).tolist()
        head = rng.integers(0, cfg.vocab_size, size=2).tolist()
        cands.append((head + motif * 4, gen))    # repeated suffix
    max_len = max(len(t) for t, _ in cands) + gen + 1
    pc = max(2, args.prefill_chunk)              # spec needs the chunked path
    probe = run_engine(model, params, cands, args.batch, max_len,
                       args.steps_per_sync, audit=args.audit,
                       config=EngineConfig(steps_per_sync=args.steps_per_sync,
                                           prefill_chunk=pc))
    scores = [
        _lookup_score(cands[i][0] + probe["outputs"][i], len(cands[i][0]),
                      args.spec_ngram)
        for i in range(len(cands))
    ]
    ranked = sorted(range(len(cands)), key=lambda i: -scores[i])
    reqs = [cands[i] for i in sorted(ranked[:args.requests])]
    # a wandering model (possible at smoke scale: 6-token continuations
    # from random weights) can leave nothing predictable to accept — the
    # cell still checks token identity, but accepted>0 is only a
    # meaningful invariant when the pre-pass found predictable rows
    predictable = scores[ranked[0]] >= 0.3
    layouts = (("contiguous", "paged") if args.layout == "both"
               else (args.layout,))
    rows = {}
    for layout in layouts:
        cache = CacheConfig(layout=layout, page_size=args.page_size,
                            kv_dtype=_kv_dtype(args, layout))
        for k in [0] + ks:
            spec = (SpecConfig(k=k, drafter=args.spec_drafter,
                               ngram=args.spec_ngram) if k else None)
            rows[(layout, f"k{k}")] = run_engine(
                model, params, reqs, args.batch, max_len,
                args.steps_per_sync, audit=args.audit, cache=cache,
                config=EngineConfig(steps_per_sync=args.steps_per_sync,
                                    prefill_chunk=pc, spec=spec),
            )
    quant_paged = args.kv_dtype == "int8" and "paged" in layouts
    for (layout, kk), r in rows.items():
        if _kv_dtype(args, layout) != "int8":
            base = rows[(layout, "k0")]["outputs"]
            assert r["outputs"] == base, (
                f"{layout} {kk}: speculative outputs diverge from plain "
                f"decode"
            )
            if kk != "k0" and predictable:
                assert r["spec_accepted"] > 0, (
                    f"{layout} {kk}: no draft was ever accepted"
                )
    if quant_paged:
        # rejected drafts max-merge into page scales before the rewind, and
        # scales never shrink — the verifier reads a slightly coarser page
        # than plain decode ever wrote
        _quant_note("draft-write rewind")
    print(f"arch={args.kv_arch} [{cfg.family}] requests={args.requests} "
          f"batch={args.batch} gen={gen} drafter={args.spec_drafter} "
          f"ngram={args.spec_ngram} chunk={pc}")
    print(f"  {'layout':<12} {'K':>3} {'gen tok/s':>10} {'accept':>7} "
          f"{'emitted':>8} {'vs K=0':>7}")
    for layout in layouts:
        base = rows[(layout, "k0")]["tok_s"]
        for k in [0] + ks:
            r = rows[(layout, f"k{k}")]
            acc = f"{r['spec_accept_rate']:.0%}" if k else "-"
            print(f"  {layout:<12} {k:>3d} {r['tok_s']:>10.1f} {acc:>7} "
                  f"{r.get('spec_emitted', 0):>8d} "
                  f"{r['tok_s'] / base:>6.2f}x")
    if not predictable:
        print("  (pre-pass found no lookup-predictable continuations at "
              "this scale — accept-rate floor waived, identity still held)")
    ident = ("outputs token-identical" if not quant_paged
             else "f32 cells token-identical")
    if gen >= 16 and predictable and args.spec_drafter == "prompt_lookup":
        for layout in layouts:
            base = rows[(layout, "k0")]["tok_s"]
            best = max(rows[(layout, f"k{k}")]["tok_s"] for k in ks)
            assert best >= 1.3 * base, (
                f"{layout}: best speculative tok/s {best:.1f} < 1.3x the "
                f"plain-decode baseline {base:.1f} on the repeated-suffix "
                "cell"
            )
        print(f"  (speculation >= 1.3x plain decode per layout; {ident})")
    else:
        print(f"  ({ident} across K)")
    return rows


def _assert_conserved(eng, label):
    """Post-drain invariant: every pool the engine owns — device and host,
    KV pages and snapshot slots — fully free, every table clear.  Zero
    leaked pages/slots is the acceptance bar for the pressure cell."""
    st = eng._mstate
    for top, free, table in (
        ("page_top", "page_free", "block_table"),
        ("host_top", "host_free", "host_table"),
        ("snap_top", "snap_free", "snap_table"),
        ("hsnap_top", "hsnap_free", "hsnap_table"),
    ):
        if top not in st:
            continue
        nslots = st[free].shape[0]
        leaked = nslots - int(st[top])
        assert leaked == 0, f"{label}: {top} leaked {leaked}/{nslots} slots"
        assert bool((np.asarray(st[table]) == -1).all()), (
            f"{label}: {table} still maps freed rows"
        )


def _pressure_cell(args, layout):
    """One --faults cell: unpressured baseline vs FaultPlan-injected run.

    Paged: pool sized so the high-priority arrival *must* preempt the
    resident low-priority long request mid-prefill (host spill), and an
    exhaustion window provably delays its restore.  Contiguous (no pool
    to squeeze): the cancel/deadline half of the plan only.  Either way
    the survivors' tokens must be bit-identical to the baseline's and no
    page or snapshot slot may leak."""
    cfg = get_arch(args.kv_arch)
    spillable = layout == "paged" and not cfg.is_attention_free
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    gen_long, gen_short = 8, 6
    rng = np.random.default_rng(17)
    long_prompt = rng.integers(0, cfg.vocab_size, size=24).tolist()
    shorts = [rng.integers(0, cfg.vocab_size, size=6).tolist()
              for _ in range(3)]

    def mk(n_pages=None, budget=0):
        paged = layout == "paged"
        # survivor bit-identity survives int8: baseline and pressured runs
        # share one chunk decomposition per row, and spill/restore moves
        # quantized payload and scales byte-exactly
        cache = CacheConfig(
            layout=layout, page_size=4 if paged else 16,
            n_pages=n_pages if paged else None,
            kv_dtype=_kv_dtype(args, layout),
        )
        return ServingEngine(
            model, params, batch=2, max_len=40, cache=cache,
            config=EngineConfig(
                steps_per_sync=2, prefill_chunk=4, prefill_budget=budget,
                prefix_sharing=paged and not cfg.is_attention_free,
            ),
        )

    def drive(eng, plan=None):
        with _audit_ctx(eng, args.audit):
            rids = [eng.submit(long_prompt, gen_long, priority=0)]
            eng.step()              # low-priority long request resident
            rids.append(eng.submit(shorts[0], gen_short, priority=1))
            rids.append(eng.submit(shorts[1], gen_short, priority=0))
            rids.append(eng.submit(shorts[2], gen_short, priority=0,
                                   deadline_ms=60_000.0))
            if plan is not None:
                eng.set_fault_plan(plan(rids))
            outs = eng.run()
        return rids, outs

    base_rids, base_outs = drive(mk(n_pages=20))

    def plan(rids):
        events = [
            FaultEvent(cycle=2, kind="cancel", req_id=rids[2]),
            FaultEvent(cycle=2, kind="deadline", req_id=rids[3],
                       deadline_ms=0.0),
        ]
        if spillable:
            events += [
                FaultEvent(cycle=1, kind="exhaust_pool", pages=4),
                FaultEvent(cycle=8, kind="release_pool"),
            ]
        return FaultPlan(events=tuple(events))

    # paged: pool == the long request's worst-case need, so the
    # high-priority arrival cannot fit without spilling it
    eng = mk(n_pages=8, budget=1)
    rids, outs = drive(eng, plan)

    survivors = [rids[0], rids[1]]
    assert sorted(outs) == sorted(survivors), (
        f"{layout}: expected only survivors {survivors}, got {sorted(outs)}"
    )
    for r in survivors:
        assert np.array_equal(outs[r], base_outs[r]), (
            f"{layout}: survivor {r} diverged from unpressured run"
        )
    assert rids[2] in eng.cancelled, f"{layout}: cancel never landed"
    assert rids[3] in eng.expired, f"{layout}: deadline never landed"
    if spillable:
        assert eng.preemptions >= 1, "pressure never forced a preemption"
        assert eng.restores >= 1, "spilled row was never restored"
    _assert_conserved(eng, layout)

    row = {"preemptions": eng.preemptions, "restores": eng.restores,
           "cancelled": len(eng.cancelled), "expired": len(eng.expired),
           "survivors": len(outs)}
    print(f"  {layout:<12} {row['preemptions']:>8d} {row['restores']:>8d} "
          f"{row['cancelled']:>9d} {row['expired']:>7d} "
          f"{row['survivors']:>9d}   ok")
    return row


def run_pressure(args):
    """The --faults section: serving survives injected pressure."""
    layouts = (("contiguous", "paged") if args.layout == "both"
               else (args.layout,))
    print(f"arch={args.kv_arch} batch=2 prompt_len=24/6 gen=8/6 "
          f"prefill_chunk=4 prefill_budget=1")
    print(f"  {'layout':<12} {'preempt':>8} {'restore':>8} "
          f"{'cancelled':>9} {'expired':>7} {'survivors':>9}")
    out = {}
    for layout in layouts:
        if layout == "paged" and get_arch(args.kv_arch).is_attention_free:
            print("  (paged cell skipped: attention-free arch — no KV "
                  "pages to spill)")
            continue
        out[layout] = _pressure_cell(args, layout)
    print("  (survivor outputs bit-identical to unpressured run; all "
          "pools conserved)")
    return out


def run_open_loop(args):
    """The --arrival poisson section: open-loop latency under load.

    Arrivals land at seeded exponential gaps on the wall clock whether or
    not the engine has kept up (open loop), with mixed priorities and an
    undersized paged pool, so queueing delay — and, under the squeeze,
    preemption — shows up in the TTFT tail instead of being absorbed by a
    closed feedback loop."""
    cfg = get_arch(args.kv_arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    n, gen = args.requests, args.gen
    rng = np.random.default_rng(args.arrival_seed)
    lo, hi = 4, 17
    prompts = [
        rng.integers(0, cfg.vocab_size,
                     size=int(rng.integers(lo, hi))).tolist()
        for _ in range(n)
    ]
    prios = [int(rng.integers(0, 2)) for _ in range(n)]
    gaps = rng.exponential(1.0 / args.rate, size=n)
    gaps[0] = 0.0
    arrivals = np.cumsum(gaps)
    max_len = hi + gen + 1
    cache = CacheConfig()
    if not cfg.is_attention_free:
        from repro.serving.pager import pages_needed
        page = args.page_size
        full_pool = args.batch * (-(-max_len // page))
        max_need = max(pages_needed(len(p) + gen, page) for p in prompts)
        cache = CacheConfig(layout="paged", page_size=page,
                            n_pages=max(max_need, (2 * full_pool) // 3),
                            kv_dtype=args.kv_dtype)
    eng = ServingEngine(model, params, batch=args.batch, max_len=max_len,
                        cache=cache,
                        config=EngineConfig(
                            steps_per_sync=args.steps_per_sync))
    with _audit_ctx(eng, args.audit):
        for _ in range(args.batch):        # compile outside the clock
            eng.submit([1, 2, 3], 2)
        eng.run()
        eng.reset_stats()
        t0 = time.perf_counter()
        nxt = 0
        while nxt < n or len(eng.outputs) < n:
            now = time.perf_counter() - t0
            while nxt < n and arrivals[nxt] <= now:
                eng.submit(prompts[nxt], gen, priority=prios[nxt])
                nxt += 1
            if eng.queue or any(r is not None for r in eng._slot_req):
                eng.step()
            elif nxt < n:
                time.sleep(min(1e-3, max(0.0, arrivals[nxt] - now)))
        dt = time.perf_counter() - t0
    ttft = np.asarray(sorted(eng.ttft.values()))
    row = {
        "requests": n, "rate": args.rate, "seconds": dt,
        "tok_s": eng.generated / dt,
        "ttft_ms_p50": 1e3 * float(np.percentile(ttft, 50)),
        "ttft_ms_p99": 1e3 * float(np.percentile(ttft, 99)),
        "preemptions": eng.preemptions, "restores": eng.restores,
    }
    print(f"arch={args.kv_arch} requests={n} batch={args.batch} gen={gen} "
          f"rate={args.rate}/s seed={args.arrival_seed}"
          + (f" pool={cache.n_pages}p" if cache.layout == "paged" else ""))
    print(f"  {'gen tok/s':>10} {'TTFT p50 ms':>12} {'TTFT p99 ms':>12} "
          f"{'preempt':>8} {'restore':>8}")
    print(f"  {row['tok_s']:>10.1f} {row['ttft_ms_p50']:>12.1f} "
          f"{row['ttft_ms_p99']:>12.1f} {row['preemptions']:>8d} "
          f"{row['restores']:>8d}")
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b-smoke")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--steps-per-sync", type=int, default=8)
    ap.add_argument("--kv-arch", default="qwen2.5-3b-smoke",
                    help="attention arch for the paged-vs-contiguous ablation")
    ap.add_argument("--family", choices=["dense", "moe", "ssm", "hybrid"],
                    default=None,
                    help="pick the prefill/sharing-ablation arch by family "
                         "(overrides --kv-arch with that family's smoke "
                         "config) — the recurrent cells exercise chunked "
                         "SSD prefill and snapshot-restore sharing")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="prompt tokens per chunked-prefill step in the "
                         "prefill ablation (1 disables chunking)")
    ap.add_argument("--prompt-len", type=int, default=256,
                    help="prompt length for the prefill ablation")
    ap.add_argument("--prefill-gen", type=int, default=8)
    ap.add_argument("--prefill-requests", type=int, default=6)
    ap.add_argument("--prefill-vocab", type=int, default=8192,
                    help="vocab size for the prefill ablation (0 keeps the "
                         "arch's own; smoke archs' 128 hides the per-step "
                         "LM-head cost chunking amortizes)")
    ap.add_argument("--layout", choices=["both", "contiguous", "paged"],
                    default="both",
                    help="scope the single-layout sections to one KV "
                         "layout (a CI matrix cell); 'both' also runs the "
                         "cross-layout ablation")
    ap.add_argument("--kv-dtype", choices=["f32", "bf16", "int8"],
                    default="f32",
                    help="KV-pool storage precision for the paged cells "
                         "(bf16: half-width storage through the same "
                         "kernels at 1/2 the resident bytes; int8: "
                         "per-(page, head)-scaled payload at 1/4, "
                         "dequantized inside the "
                         "attention kernels; contiguous cells stay f32)")
    ap.add_argument("--spec-k", default="2,4",
                    help="comma list of draft widths K for the speculative-"
                         "decoding ablation (0 skips it); each K runs "
                         "against a shared K=0 baseline per layout")
    ap.add_argument("--spec-drafter", default="prompt_lookup",
                    choices=["prompt_lookup", "hybrid_ssm"],
                    help="proposal source: n-gram prompt lookup (any "
                         "family) or the hybrid family's own Mamba layers")
    ap.add_argument("--spec-ngram", type=int, default=2,
                    help="prompt-lookup n-gram match length")
    ap.add_argument("--share-requests", type=int, default=8,
                    help="rows in the prefix-sharing ablation")
    ap.add_argument("--share-prefix-len", type=int, default=256,
                    help="shared system-prompt length for the "
                         "prefix-sharing ablation")
    ap.add_argument("--faults", action="store_true",
                    help="run the pressure cell: preemption + host spill "
                         "under a scripted FaultPlan (pool exhaustion, "
                         "cancel, deadline storm) with survivor "
                         "token-identity and conservation asserts")
    ap.add_argument("--arrival", choices=["batch", "poisson"],
                    default="batch",
                    help="'poisson' adds an open-loop cell: seeded "
                         "exponential inter-arrival gaps on the wall "
                         "clock, p50/p99 TTFT + preemption counts")
    ap.add_argument("--rate", type=float, default=16.0,
                    help="open-loop arrival rate, requests/second")
    ap.add_argument("--arrival-seed", type=int, default=0,
                    help="seed for the open-loop arrival process")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal sizes: CI driver-rot check, not a benchmark")
    ap.add_argument("--audit", action="store_true",
                    help="wrap every engine run in jit_cache_audit so an "
                         "accidental retrace fails loudly instead of "
                         "reporting bogus tok/s")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the result dict as JSON (token lists "
                         "dropped, tuple keys flattened) — the machine-"
                         "readable feed for benchmarks/perf_snapshot.py")
    args = ap.parse_args(argv)
    if args.family:
        args.kv_arch = {
            "dense": "qwen2.5-3b", "moe": "qwen3-moe-235b-a22b",
            "ssm": "mamba2-2.7b", "hybrid": "zamba2-2.7b",
        }[args.family] + "-smoke"
    if args.quick:
        args.requests, args.gen = 8, 16
        args.prompt_len, args.prefill_chunk = 64, 16
        args.prefill_requests = 4
        args.share_requests, args.share_prefix_len = 4, 64
    if args.smoke:
        args.requests, args.gen, args.batch = 3, 6, 2
        args.prompt_len = 20
        # keep the chunked path live (>1) at a smoke-sized width
        args.prefill_chunk = max(2, min(args.prefill_chunk, 8))
        args.prefill_requests, args.prefill_gen = 3, 4
        args.prefill_vocab = min(args.prefill_vocab, 512)
        # prefix sharing stays live too: 3 full pages shared across 4 rows
        # (page-aligned so the fully-shared request exercises CoW)
        args.share_requests = 4
        args.share_prefix_len = 3 * args.page_size

    cfg = get_arch(args.arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    reqs = make_requests(0, args.requests, cfg.vocab_size, args.gen)
    max_len = 12 + args.gen + 1

    if args.kv_dtype != "f32" and args.layout != "paged":
        print(f"note: --kv-dtype {args.kv_dtype} only applies to paged "
              f"pools; layout={args.layout} keeps its non-paged cells f32")
    main_cache = None
    host_cache = None
    if args.layout == "paged":
        main_cache = CacheConfig(layout="paged", page_size=args.page_size,
                                 kv_dtype=args.kv_dtype)
        if args.kv_dtype != "f32":
            # the main cell feeds prompts token-by-token on both sides
            # (EngineConfig default prefill_chunk=1), so giving the host
            # loop the same sub-f32 pool keeps the storage precision and
            # write grain — and therefore the token streams — identical
            host_cache = main_cache
    host = run_host_loop(model, params, reqs, args.batch, max_len,
                         cache=host_cache)
    eng = run_engine(model, params, reqs, args.batch, max_len,
                     args.steps_per_sync, audit=args.audit, cache=main_cache)

    # both schedulers must produce identical tokens before we compare speed
    for i in range(len(reqs)):
        a = [int(t) for t in host["outputs"][i]]
        b = [int(t) for t in eng["outputs"][i]]
        assert a == b, f"request {i}: host {a} != engine {b}"

    print(f"arch={args.arch} requests={args.requests} batch={args.batch} "
          f"gen={args.gen} steps_per_sync={args.steps_per_sync}"
          + (f" kv_dtype={args.kv_dtype}" if args.layout == "paged" else ""))
    print(f"  {'loop':<10} {'gen tok/s':>10} {'steps':>7} {'seconds':>8}")
    for name, r in (("host-loop", host), ("engine", eng)):
        print(f"  {name:<10} {r['tok_s']:>10.1f} {r['steps']:>7d} "
              f"{r['seconds']:>8.2f}")
    print(f"  speedup: {eng['tok_s'] / host['tok_s']:.2f}x "
          f"(outputs token-identical)")
    out = {"host": host, "engine": eng}
    if args.layout == "both":
        print()
        print("-- KV layout: paged vs contiguous (mixed prompt lengths) --")
        out["layouts"] = compare_layouts(args)
    print()
    print(f"-- Chunked prefill: prompt ingestion + TTFT "
          f"(layout={args.layout}) --")
    out["prefill"] = compare_prefill(args)
    print()
    print(f"-- Speculative decoding: draft + verify "
          f"(layout={args.layout}) --")
    out["spec"] = run_spec(args)
    if args.layout in ("both", "paged"):
        print()
        print("-- Prefix sharing: shared system prompt, CoW (paged) --")
        out["sharing"] = compare_prefix_sharing(args)
    if args.faults:
        print()
        print(f"-- Pressure: preemption/spill + FaultPlan "
              f"(layout={args.layout}) --")
        out["pressure"] = run_pressure(args)
    if args.arrival == "poisson":
        print()
        print("-- Open loop: poisson arrivals, TTFT under load --")
        out["open_loop"] = run_open_loop(args)
    if args.json:
        import json

        with open(args.json, "w", encoding="utf-8") as f:
            json.dump(_jsonable(out), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"\nwrote {args.json}")
    return out


def _jsonable(obj):
    """Result dict -> JSON-safe: tuple keys flattened ("layout:chunk"),
    per-request token lists dropped (the parity asserts already ran)."""
    if isinstance(obj, dict):
        return {
            (":".join(map(str, k)) if isinstance(k, tuple) else str(k)):
                _jsonable(v)
            for k, v in obj.items() if k != "outputs"
        }
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    return obj


if __name__ == "__main__":
    main()
