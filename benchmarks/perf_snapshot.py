"""Perf snapshot + trajectory check — ``BENCH_<n>.json`` emission.

One command captures the serving stack's headline numbers and the per-op
tuned-vs-default picture into a committed artifact, so the perf
trajectory of the repo is a reviewable file series instead of folklore:

    PYTHONPATH=src python -m benchmarks.perf_snapshot            # emit next
    PYTHONPATH=src python -m benchmarks.perf_snapshot --check    # regress?

Each ``benchmarks/trajectory/BENCH_%04d.json`` carries:

* ``serving`` — per family cell (the same smoke workloads as
  ``ci.sh --smoke``, run via ``benchmarks.serve_engine --json`` under
  ``--audit`` so a retracing driver fails instead of reporting bogus
  numbers): generated tok/s, prefill tok/s, mean and p99 TTFT ms, peak
  resident KV bytes (the paged pool from the layout ablation when the
  arch has one), and — from the ``--faults`` pressure cell —
  ``preemptions`` / ``restores`` / ``pressure_survivors``, the
  host-spill scheduler's counters under the scripted FaultPlan (exact,
  deterministic: the cell's submission sequence and fault cycles are
  fixed, so a drift here is a scheduler behavior change, not noise), and
  — from the speculative-decoding ablation — ``spec_tok_s`` (timing
  band) plus ``spec_accepted`` / ``spec_emitted`` (exact: seeded
  workload, greedy acceptance, deterministic drafter).  Three extra
  dense-family paged cells (``kv_f32``/``kv_bf16``/``kv_int8``) pin the
  KV storage ladder: identical seeded workloads whose peak resident-KV
  bytes must land exactly on 1 : 1/2 : 1/4 — ``validate_bench`` rejects
  the snapshot otherwise, so the quantized-capacity claim is enforced,
  not just reported.
* ``ops`` — for every autotuned shape case (``repro.tuning.autotune``
  drives the same cells the sweep used): wall ms with the committed
  tuning table vs the hand-set call-site defaults, the resulting
  speedup, and the op's roofline fraction computed from the *reference*
  lowering's optimized HLO via ``repro.roofline.analysis`` (the
  interpret-mode Pallas HLO is an emulation artifact; the reference HLO
  is the stable arithmetic footprint).

``--check`` re-measures and compares against the newest committed
BENCH file with per-metric-family tolerances: timing metrics get a
generous relative band (machines differ; the default catches only
collapse-grade regressions), resident-KV bytes must match exactly and
roofline fractions almost exactly (both deterministic given the code).
``ci.sh --bench-check`` wires this into CI.

The backend is pinned with the scoped ``use_backend("pallas")`` (R004)
— never ``set_default_backend``.
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence

import jax

SCHEMA_VERSION = 1

TRAJECTORY_DIR = Path(__file__).resolve().parent / "trajectory"

#: timing metrics: relative regression band (0.5 = fail below 50% of the
#: committed throughput / above 2x the committed latency)
REL_TOL = 0.5
#: roofline fractions are deterministic given the op's HLO
ROOFLINE_ATOL = 0.05

_LOWER_IS_BETTER = ("ttft", "_ms",)


def _log(msg: str) -> None:
    print(msg, flush=True)


# ---------------------------------------------------------------------------
# Serving cells (benchmarks.serve_engine --json)
# ---------------------------------------------------------------------------

_SERVING_CELLS = {
    # default smoke arch (ssm family) — mirrors `ci.sh --smoke`
    "default": [],
    # recurrent+attention family: chunked SSD prefill, snapshot sharing
    "hybrid": ["--family", "hybrid"],
    # the kv_dtype storage ladder on the dense family's paged pool: the
    # same seeded workload at f32 / bf16 / int8 storage, so the three
    # cells' peak resident-KV bytes must land exactly on 1 : 1/2 : 1/4
    # (the quantized cell exactly half the 16-bit cell) —
    # ``validate_bench`` rejects any snapshot where the ladder is off.
    # ``--layout both`` keeps the layout-ablation section alive: kv_bytes
    # is read from its paged row (the main smoke arch is attention-free,
    # so the engine cell itself holds no KV pages), and the sub-f32 cells
    # re-assert the exact byte ratio against their in-run paged_f32 twin
    "kv_f32": ["--family", "dense", "--layout", "both"],
    "kv_bf16": ["--family", "dense", "--layout", "both",
                "--kv-dtype", "bf16"],
    "kv_int8": ["--family", "dense", "--layout", "both",
                "--kv-dtype", "int8"],
}


def _serving_metrics(doc: Dict[str, Any]) -> Dict[str, float]:
    eng = doc["engine"]
    out = {"tok_s": eng["tok_s"], "kv_bytes": eng["kv_bytes"]}
    layouts = doc.get("layouts") or {}
    if layouts.get("paged"):
        out["kv_bytes"] = layouts["paged"]["kv_bytes"]
    prefill = doc.get("prefill") or {}
    chunked = {k: v for k, v in prefill.items() if not k.endswith(":1")}
    for pick in ("paged", "contiguous"):
        row = next((v for k, v in sorted(chunked.items())
                    if k.startswith(pick + ":")), None)
        if row is not None:
            out.update(prefill_tok_s=row["prefill_tok_s"],
                       ttft_ms=row["ttft_ms"],
                       ttft_ms_p99=row["ttft_ms_p99"])
            break
    pressure = doc.get("pressure") or {}
    cell = pressure.get("paged") or pressure.get("contiguous")
    if cell:
        out.update(preemptions=cell["preemptions"],
                   restores=cell["restores"],
                   pressure_survivors=cell["survivors"])
    spec = doc.get("spec") or {}
    live = {k: v for k, v in spec.items() if not k.endswith(":k0")}
    for pick in ("paged", "contiguous"):
        row = next((v for k, v in sorted(live.items())
                    if k.startswith(pick + ":")), None)
        if row is not None:
            out.update(spec_tok_s=row["tok_s"],
                       spec_accepted=row["spec_accepted"],
                       spec_emitted=row["spec_emitted"])
            break
    return out


def run_serving(log=_log) -> Dict[str, Dict[str, float]]:
    from benchmarks import serve_engine
    from repro.core.policy import use_backend

    cells: Dict[str, Dict[str, float]] = {}
    for name, extra in _SERVING_CELLS.items():
        log(f"  serving cell {name!r} ...")
        with tempfile.NamedTemporaryFile(suffix=".json") as tmp:
            argv = ["--smoke", "--prefill-chunk", "8", "--audit",
                    "--faults", "--spec-k", "4", "--json", tmp.name] + extra
            with use_backend("pallas"):
                serve_engine.main(argv)
            doc = json.loads(Path(tmp.name).read_text())
        cells[name] = _serving_metrics(doc)
    return cells


# ---------------------------------------------------------------------------
# Per-op cells (tuned vs hand-set defaults + roofline fraction)
# ---------------------------------------------------------------------------

def _roofline_fraction(ref_fn, ref_args, key: str, cls: str) -> float:
    from repro.roofline.analysis import analyze

    # arrays go in as jit *arguments*: closed-over constants would let
    # XLA fold the whole op away and report zero flops
    hlo = jax.jit(ref_fn).lower(*ref_args).compile().as_text()
    r = analyze(key, cls, "host", 1, {}, hlo, model_flops=0.0)
    return round(r.roofline_fraction, 4)


def run_ops(
    table_doc: Dict[str, Any],
    *,
    repeats: int = 3,
    only: Optional[Sequence[str]] = None,
    log=_log,
) -> Dict[str, Dict[str, Any]]:
    """Time every sweep shape case under table vs call-site defaults."""
    from repro.analysis.coverage import collect_tuning_sites
    from repro.core.policy import use_backend
    from repro.core.registry import tuning_table
    from repro.tuning.autotune import measure, shape_cases
    from repro.tuning.shapes import shape_class

    keys = sorted(collect_tuning_sites())
    if only is not None:
        keys = [k for k in keys if k in only]
    out: Dict[str, Dict[str, Any]] = {}
    for key in keys:
        for case_name, dims, build in shape_cases(key, smoke=False):
            cls = shape_class(**dims)
            pallas_thunk, ref_fn, ref_args = build()
            with use_backend("pallas"):
                with tuning_table(None):
                    default_ms = measure(pallas_thunk, repeats)
                with tuning_table(table_doc):
                    tuned_ms = measure(pallas_thunk, repeats)
            cell = {
                "case": case_name,
                "shape_class": cls,
                "default_ms": round(default_ms, 4),
                "tuned_ms": round(tuned_ms, 4),
                "speedup": round(default_ms / tuned_ms, 3),
                "roofline_fraction": _roofline_fraction(
                    ref_fn, ref_args, key, cls),
            }
            out[f"{key}[{cls}]"] = cell
            log(f"  {key}[{cls}]: {default_ms:.2f} -> {tuned_ms:.2f} ms "
                f"(x{cell['speedup']:.2f}, roofline "
                f"{cell['roofline_fraction']:.3f})")
    return out


# ---------------------------------------------------------------------------
# Snapshot document + trajectory
# ---------------------------------------------------------------------------

def snapshot(
    *, repeats: int = 3, only: Optional[Sequence[str]] = None,
    serving: bool = True, log=_log,
) -> Dict[str, Any]:
    from repro.tuning import table as tt

    table_doc = tt.load(tt.resolved_path())
    doc: Dict[str, Any] = {
        "schema": SCHEMA_VERSION,
        "environment": {
            "jax": jax.__version__,
            "device": jax.devices()[0].platform,
            "repeats": repeats,
        },
        "tuning_entries": sum(
            len(v) for v in table_doc.get("entries", {}).values()
        ),
        "serving": {},
        "ops": {},
    }
    if serving:
        log("serving cells:")
        doc["serving"] = run_serving(log)
    log("op cells (tuned vs defaults):")
    doc["ops"] = run_ops(table_doc, repeats=repeats, only=only, log=log)
    improved = [k for k, v in doc["ops"].items() if v["speedup"] > 1.05]
    doc["improved_ops"] = sorted(improved)
    return doc


def bench_files(out_dir: Path = TRAJECTORY_DIR) -> List[Path]:
    return sorted(out_dir.glob("BENCH_[0-9][0-9][0-9][0-9].json"))


def next_path(out_dir: Path = TRAJECTORY_DIR) -> Path:
    files = bench_files(out_dir)
    n = int(files[-1].stem.split("_")[1]) + 1 if files else 1
    return out_dir / f"BENCH_{n:04d}.json"


def validate_bench(doc: Any) -> List[str]:
    """Schema check for a BENCH document; returns errors (empty = ok)."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return ["document is not an object"]
    if doc.get("schema") != SCHEMA_VERSION:
        errs.append(f"schema must be {SCHEMA_VERSION}")
    serving = doc.get("serving")
    if not isinstance(serving, dict):
        errs.append("'serving' must be an object")
    else:
        for cell, metrics in serving.items():
            if not isinstance(metrics, dict):
                errs.append(f"serving[{cell!r}] must be an object")
                continue
            for fld in ("tok_s", "prefill_tok_s", "ttft_ms",
                        "ttft_ms_p99", "kv_bytes"):
                if not isinstance(metrics.get(fld), (int, float)):
                    errs.append(f"serving[{cell!r}].{fld} must be a number")
        # the kv_dtype ladder, when present, must be *exact*: same seeded
        # workload, storage itemsize is the only degree of freedom
        ladder = {
            k: serving[k].get("kv_bytes")
            for k in ("kv_f32", "kv_bf16", "kv_int8")
            if isinstance(serving.get(k), dict)
        }
        if len(ladder) == 3 and all(
            isinstance(v, (int, float)) for v in ladder.values()
        ):
            f32b, bf, q8 = (ladder["kv_f32"], ladder["kv_bf16"],
                            ladder["kv_int8"])
            if bf * 2 != f32b:
                errs.append(
                    f"kv ladder: bf16 kv_bytes {bf} is not exactly half "
                    f"the f32 cell {f32b}"
                )
            if q8 * 2 != bf:
                errs.append(
                    f"kv ladder: int8 kv_bytes {q8} is not exactly half "
                    f"the bf16 cell {bf}"
                )
            if q8 * 4 != f32b:
                errs.append(
                    f"kv ladder: int8 kv_bytes {q8} is not exactly a "
                    f"quarter of the f32 cell {f32b}"
                )
    ops = doc.get("ops")
    if not isinstance(ops, dict):
        errs.append("'ops' must be an object")
    else:
        for cell, m in ops.items():
            if not isinstance(m, dict):
                errs.append(f"ops[{cell!r}] must be an object")
                continue
            for fld in ("default_ms", "tuned_ms", "speedup",
                        "roofline_fraction"):
                if not isinstance(m.get(fld), (int, float)):
                    errs.append(f"ops[{cell!r}].{fld} must be a number")
    if not isinstance(doc.get("improved_ops"), list):
        errs.append("'improved_ops' must be a list")
    return errs


# ---------------------------------------------------------------------------
# Trajectory check
# ---------------------------------------------------------------------------

def _is_lower_better(metric: str) -> bool:
    return any(t in metric for t in _LOWER_IS_BETTER)


def compare(
    old: Dict[str, Any], new: Dict[str, Any], *, rel_tol: float = REL_TOL,
) -> List[str]:
    """Regressions of ``new`` vs ``old``; empty list = trajectory holds.

    Only cells present in both snapshots are compared (ops come and go as
    kernels land); deterministic metrics are tight, timing metrics wide.
    """
    regressions: List[str] = []

    def timing(where: str, metric: str, o: float, n: float) -> None:
        if not (o > 0 and n > 0):     # NaN / zero: nothing to compare
            return
        if _is_lower_better(metric):
            if n > o * (1.0 + rel_tol) / (1.0 - rel_tol):
                regressions.append(
                    f"{where}.{metric}: {n:.2f} vs committed {o:.2f} "
                    f"(latency regression beyond rel_tol={rel_tol})"
                )
        elif n < o * (1.0 - rel_tol):
            regressions.append(
                f"{where}.{metric}: {n:.2f} vs committed {o:.2f} "
                f"(throughput regression beyond rel_tol={rel_tol})"
            )

    for cell in sorted(set(old.get("serving", {})) & set(new.get("serving", {}))):
        o, n = old["serving"][cell], new["serving"][cell]
        for metric in ("tok_s", "prefill_tok_s", "ttft_ms", "ttft_ms_p99",
                       "spec_tok_s"):
            if metric in o and metric in n:
                timing(f"serving.{cell}", metric, o[metric], n[metric])
        if o.get("kv_bytes") != n.get("kv_bytes"):
            regressions.append(
                f"serving.{cell}.kv_bytes: {n.get('kv_bytes')} vs committed "
                f"{o.get('kv_bytes')} (resident KV is deterministic — this "
                "is a real change, not noise)"
            )
        for metric in ("preemptions", "restores", "pressure_survivors"):
            if metric in o and metric in n and o[metric] != n[metric]:
                regressions.append(
                    f"serving.{cell}.{metric}: {n[metric]} vs committed "
                    f"{o[metric]} (the pressure cell is deterministic — "
                    "the scheduler's behavior under faults changed)"
                )
        for metric in ("spec_accepted", "spec_emitted"):
            if metric in o and metric in n and o[metric] != n[metric]:
                regressions.append(
                    f"serving.{cell}.{metric}: {n[metric]} vs committed "
                    f"{o[metric]} (seeded workload + greedy acceptance are "
                    "deterministic — the drafter or verifier changed)"
                )

    for cell in sorted(set(old.get("ops", {})) & set(new.get("ops", {}))):
        o, n = old["ops"][cell], new["ops"][cell]
        for metric in ("default_ms", "tuned_ms"):
            # sub-0.1ms cells are timer-noise-dominated either way; a real
            # collapse still trips because the *new* value leaves the floor
            if o[metric] < 0.1 and n[metric] < 0.1:
                continue
            timing(f"ops.{cell}", metric, o[metric], n[metric])
        if abs(o["roofline_fraction"] - n["roofline_fraction"]) \
                > ROOFLINE_ATOL:
            regressions.append(
                f"ops.{cell}.roofline_fraction: {n['roofline_fraction']} vs "
                f"committed {o['roofline_fraction']} (beyond "
                f"{ROOFLINE_ATOL} — the op's arithmetic footprint changed)"
            )
        # where the committed snapshot shows the table helping, the tuned
        # path must not lose to the hand-set defaults outright now.  Cells
        # the sweep left at defaults hover around 1.0 by construction and
        # are exempt — their "speedup" is two timings of identical code.
        if o["speedup"] >= 1.0 and n["speedup"] < 1.0 - rel_tol:
            regressions.append(
                f"ops.{cell}.speedup: {n['speedup']} — the committed table "
                "now slows this op down; re-run python -m "
                "repro.tuning.autotune"
            )
    return regressions


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.perf_snapshot",
        description="emit BENCH_<n>.json or check the perf trajectory",
    )
    ap.add_argument("--check", action="store_true",
                    help="re-measure and compare against the newest "
                         "committed BENCH file instead of emitting")
    ap.add_argument("--out-dir", default=None,
                    help="trajectory directory (default benchmarks/"
                         "trajectory)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--keys", default=None,
                    help="comma-separated tuning keys for the op section "
                         "(default all)")
    ap.add_argument("--no-serving", action="store_true",
                    help="skip the serving cells (op-only snapshot)")
    ap.add_argument("--rel-tol", type=float, default=REL_TOL,
                    help="relative band for timing metrics in --check")
    args = ap.parse_args(argv)

    out_dir = Path(args.out_dir) if args.out_dir else TRAJECTORY_DIR
    only = args.keys.split(",") if args.keys else None

    doc = snapshot(repeats=args.repeats, only=only,
                   serving=not args.no_serving)
    errs = validate_bench(doc)
    if errs:
        _log("snapshot failed schema validation: " + "; ".join(errs))
        return 1

    if args.check:
        files = bench_files(out_dir)
        if not files:
            _log(f"no committed BENCH files under {out_dir}; emit one first")
            return 1
        committed = json.loads(files[-1].read_text())
        errs = validate_bench(committed)
        if errs:
            _log(f"{files[-1].name} is invalid: " + "; ".join(errs))
            return 1
        regressions = compare(committed, doc, rel_tol=args.rel_tol)
        if regressions:
            _log(f"perf trajectory check FAILED vs {files[-1].name}:")
            for r in regressions:
                _log(f"  - {r}")
            return 1
        _log(f"perf trajectory holds vs {files[-1].name} "
             f"({len(doc['ops'])} op cells, "
             f"{len(doc['serving'])} serving cells)")
        return 0

    out_dir.mkdir(parents=True, exist_ok=True)
    path = next_path(out_dir)
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n",
                    encoding="utf-8")
    _log(f"wrote {path} (improved ops: {len(doc['improved_ops'])})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
