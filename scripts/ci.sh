#!/usr/bin/env bash
# CI entry points — the same commands the GitHub workflow runs, callable
# locally so a green laptop means a green matrix.
#
#   ./scripts/ci.sh                   tier-1 test suite (ROADMAP.md verbatim)
#   ./scripts/ci.sh --smoke [layout]  benchmark-driver smoke: a few
#                                     serving-engine steps under
#                                     $REPRO_BACKEND (default pallas,
#                                     interpret off-TPU) — chunked prefill
#                                     and, under the paged layout, the
#                                     prefix-sharing/CoW path — so the
#                                     benchmark entry points can't silently
#                                     rot.  layout: contiguous | paged |
#                                     both (default)
#   ./scripts/ci.sh --matrix          the full smoke matrix locally:
#                                     {reference,pallas} x {contiguous,paged}
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt ||
    echo "warning: dev-dep install failed (offline?); property tests will skip"

# --smoke shrinks every section but keeps prefill chunking > 1 and a
# page-aligned shared prefix, so the chunked path (kernel + pager
# alloc_range + scheduler) and the sharing path (prefix index +
# share_prefix + CoW) really run
smoke() {
    REPRO_BACKEND="${REPRO_BACKEND:-pallas}" \
        PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m benchmarks.serve_engine --smoke --prefill-chunk 8 \
            --layout "$1"
}

case "${1:-}" in
--smoke)
    smoke "${2:-both}"
    ;;
--matrix)
    for backend in reference pallas; do
        for layout in contiguous paged; do
            echo "== smoke: backend=$backend layout=$layout =="
            REPRO_BACKEND=$backend smoke "$layout"
        done
    done
    ;;
"")
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q
    ;;
*)
    echo "usage: $0 [--smoke [contiguous|paged|both] | --matrix]" >&2
    exit 2
    ;;
esac
