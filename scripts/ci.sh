#!/usr/bin/env bash
# CI entry points — the same commands the GitHub workflow runs, callable
# locally so a green laptop means a green matrix.
#
#   ./scripts/ci.sh                   tier-1 test suite (ROADMAP.md verbatim)
#   ./scripts/ci.sh --smoke [layout]  benchmark-driver smoke: a few
#                                     serving-engine steps under
#                                     $REPRO_BACKEND (default pallas,
#                                     interpret off-TPU) — chunked prefill
#                                     and, under the paged layout, the
#                                     prefix-sharing/CoW path — so the
#                                     benchmark entry points can't silently
#                                     rot.  layout: contiguous | paged |
#                                     both (default)
#   ./scripts/ci.sh --matrix          the full smoke matrix locally:
#                                     {reference,pallas} x {contiguous,paged}
#   ./scripts/ci.sh --lint            invariant linter (R001-R007) + op
#                                     coverage lint (repro.analysis,
#                                     incl. C104/C105 tuning-table
#                                     staleness); fails on any finding
#   ./scripts/ci.sh --bench-check     perf-trajectory check: re-measure
#                                     the BENCH metrics (smoke-scale,
#                                     audited engine runs) and compare
#                                     against the newest committed
#                                     benchmarks/trajectory/BENCH_*.json;
#                                     fails on a regression beyond the
#                                     per-metric-family tolerances
set -euo pipefail
cd "$(dirname "$0")/.."

# repo cleanliness: bytecode artifacts must never be *tracked* (the
# .gitignore hardening of PR 4, enforced instead of hoped for — a tracked
# .pyc shows up in source greps and churns every diff)
tracked_pyc=$(git ls-files | grep -E '(^|/)__pycache__/|\.pyc$' || true)
if [ -n "$tracked_pyc" ]; then
    echo "error: bytecode artifacts are tracked by git:" >&2
    echo "$tracked_pyc" >&2
    exit 1
fi

python -m pip install -q -r requirements-dev.txt ||
    echo "warning: dev-dep install failed (offline?); property tests will skip"

# --smoke shrinks every section but keeps prefill chunking > 1 and a
# page-aligned shared prefix, so the chunked path (kernel + pager
# alloc_range + scheduler) and the sharing path (prefix index +
# share_prefix + CoW) really run.  A second, hybrid-family pass keeps the
# recurrent serving path (chunked SSD prefill + page-boundary snapshot
# sharing/restore) continuously exercised alongside the attention one.
#
# Every smoke invocation runs under --audit (repro.analysis's
# jit_cache_audit): a benchmark driver that retraces fails the cell
# instead of reporting bogus tok/s.  --faults adds the pressure cell to
# each pass: a small pool plus a scripted FaultPlan (preemption/host
# spill, cancel, deadline storm) with survivor token-identity and
# pool-conservation asserts — under the paged layout the jitted
# _spill/_restore pair is audited too.  --spec-k 4 adds the speculative-
# decoding ablation (draft + chunked-verify + per-row acceptance, K=0
# baseline token-identity asserted); the hybrid pass drafts with the
# family's own Mamba layers (drafter=hybrid_ssm) so both drafter
# implementations stay exercised.
#
# Paged passes add a third, quantized run (--kv-dtype int8): the same
# sections over int8 page pools with in-kernel dequant — preemption,
# spill/restore, spec and prefix sharing all drive the quantized pool,
# with exact asserts wherever the write grain matches (survivors,
# host-vs-engine) and printed waivers where it can't (cross-grain
# token identity; serve_engine documents why).
smoke() {
    REPRO_BACKEND="${REPRO_BACKEND:-pallas}" \
        PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m benchmarks.serve_engine --smoke --prefill-chunk 8 \
            --layout "$1" --audit --faults --spec-k 4
    echo "== smoke (recurrent): family=hybrid layout=$1 =="
    REPRO_BACKEND="${REPRO_BACKEND:-pallas}" \
        PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m benchmarks.serve_engine --smoke --prefill-chunk 8 \
            --layout "$1" --family hybrid --audit --faults \
            --spec-k 4 --spec-drafter hybrid_ssm
    if [ "$1" != "contiguous" ]; then
        echo "== smoke (quantized): kv_dtype=int8 layout=$1 =="
        REPRO_BACKEND="${REPRO_BACKEND:-pallas}" \
            PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
            python -m benchmarks.serve_engine --smoke --prefill-chunk 8 \
                --layout "$1" --kv-dtype int8 --audit --faults --spec-k 4
    fi
}

case "${1:-}" in
--smoke)
    smoke "${2:-both}"
    ;;
--matrix)
    for backend in reference pallas; do
        for layout in contiguous paged; do
            echo "== smoke: backend=$backend layout=$layout =="
            REPRO_BACKEND=$backend smoke "$layout"
        done
    done
    ;;
--lint)
    # the bytecode-artifact check above already ran (every entry point
    # shares it); this adds the AST rules + the op coverage lint
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/lint.py
    ;;
--bench-check)
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m benchmarks.perf_snapshot --check
    ;;
"")
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q
    ;;
*)
    echo "usage: $0 [--smoke [contiguous|paged|both] | --matrix | --lint |" \
         "--bench-check]" >&2
    exit 2
    ;;
esac
