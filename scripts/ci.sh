#!/usr/bin/env bash
# Tier-1 CI entry point: install dev deps, then run the tier-1 verify
# command from ROADMAP.md verbatim.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt ||
    echo "warning: dev-dep install failed (offline?); property tests will skip"

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q
