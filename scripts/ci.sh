#!/usr/bin/env bash
# Tier-1 CI entry point: install dev deps, then run the tier-1 verify
# command from ROADMAP.md verbatim.
#
#   ./scripts/ci.sh            tier-1 test suite
#   ./scripts/ci.sh --smoke    benchmark-driver smoke: a few serving-engine
#                              steps under PALLAS (interpret off-TPU) —
#                              including the chunked-prefill ablation under
#                              both KV layouts — so the benchmark entry
#                              points can't silently rot
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt ||
    echo "warning: dev-dep install failed (offline?); property tests will skip"

if [[ "${1:-}" == "--smoke" ]]; then
    # --smoke shrinks every section but keeps prefill chunking > 1, so the
    # chunked path (kernel + pager alloc_range + scheduler) really runs
    REPRO_BACKEND=pallas PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m benchmarks.serve_engine --smoke --prefill-chunk 8
    exit 0
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q
