#!/usr/bin/env python3
"""Run the repro invariant linter (R001-R005) + op coverage lint.

Usage:
    python scripts/lint.py [paths...] [--no-coverage]

With no paths, lints ``src/repro``.  Exits nonzero on any finding.  The
rule set and suppression syntax are documented in the ``repro.analysis``
package docstring.
"""
import sys
from pathlib import Path

_SRC = Path(__file__).resolve().parents[1] / "src"
if str(_SRC) not in sys.path:
    sys.path.insert(0, str(_SRC))

from repro.analysis.lint import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
