"""Quickstart — the paper's experiment in 30 lines.

Train the Caffe LeNet on (synthetic) MNIST through the portability core:
the SAME network code runs on the reference backend (CPU/XLA) or the
Pallas-kernel backend, selected by one switch — PHAST's macro, in JAX.

    PYTHONPATH=src python examples/quickstart.py [--backend pallas]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro.caffe import Net, Solver, lenet_mnist, lenet_mnist_solver
from repro.core import use_backend
from repro.data.synthetic import mnist_like


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas", "auto"])
    ap.add_argument("--iters", type=int, default=60)
    args = ap.parse_args()

    net = Net(lenet_mnist())
    solver = Solver(net, lenet_mnist_solver(
        max_iter=args.iters, batch_size=32, test_interval=20, test_batches=2))
    stream = mnist_like(32)

    # the one-line 'Makefile switch': same net, different lowering
    with use_backend(args.backend):
        state, hist = solver.solve(
            jax.random.PRNGKey(0), iter(stream),
            test_iter=lambda: stream.eval_iter(), log=print,
        )
    print(f"[{args.backend}] final loss {hist['loss'][-1]:.4f}, "
          f"test acc {hist['test_acc'][-1][1]:.3f}")


if __name__ == "__main__":
    main()
