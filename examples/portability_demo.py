"""Portability demo — the paper's core claim, made falsifiable.

One source (a conv->pool->relu->ip->softmax-loss block over the portable
ops), three executions:

  1. reference backend (pure jnp / XLA)         = PHAST's CPU target
  2. Pallas-kernel backend (interpret on CPU,    = PHAST's GPU target
     Mosaic on a real TPU — same code)
  3. partial-port mode: reference, but with a host round-trip + layout
     transpose at every layer boundary            = the paper's §4.3 pathology

(1) and (2) must agree to float tolerance — values AND gradients.
(3) agrees too, but the benchmark shows what it costs (see
benchmarks/table2_fwbw.py for the measured slowdown).

    PYTHONPATH=src python examples/portability_demo.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.caffe import Net, lenet_mnist
from repro.core import coverage, use_backend
from repro.data.synthetic import mnist_like


def main():
    net = Net(lenet_mnist())
    params = net.init(jax.random.PRNGKey(0), 8)
    data, label = mnist_like(8).batch(0)

    results = {}
    for backend in ("reference", "pallas"):
        with use_backend(backend):
            loss, grads = jax.value_and_grad(net.forward_loss)(
                params, data, label
            )
            results[backend] = (float(loss), grads)
        print(f"backend={backend:10s} loss={results[backend][0]:.6f}")

    np.testing.assert_allclose(
        results["reference"][0], results["pallas"][0], rtol=1e-4
    )
    for a, b in zip(jax.tree.leaves(results["reference"][1]),
                    jax.tree.leaves(results["pallas"][1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)
    print("values + gradients identical across backends ✓")

    boundary_net = Net(lenet_mnist(), boundary="transfer+transpose")
    loss3 = boundary_net.forward_loss(params, data, label)
    np.testing.assert_allclose(results["reference"][0], float(loss3), rtol=1e-5)
    print("partial-port boundary mode: same result, slower "
          "(measured in benchmarks/table2_fwbw.py) ✓")

    cov = coverage()
    ported = sum(cov.values())
    print(f"op coverage: {ported}/{len(cov)} blocks have a Pallas lowering")
    for name, has in sorted(cov.items()):
        print(f"  {'[ported]  ' if has else '[ref-only]'} {name}")


if __name__ == "__main__":
    main()
