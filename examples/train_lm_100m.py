"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

A qwen2.5-family config scaled to ~100M params, trained on the synthetic
bigram token stream with AdamW + warmup-cosine, gradient accumulation,
checkpointing, and restart — the full production loop at laptop scale.

    PYTHONPATH=src python examples/train_lm_100m.py --steps 200
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.data.synthetic import TokenStream, TokenStreamSpec
from repro.distributed.checkpoint import Checkpointer
from repro.launch.steps import init_train_state, make_train_step
from repro.optim.optimizers import OptConfig


def config_100m():
    base = get_arch("qwen2.5-3b")
    return dataclasses.replace(
        base,
        name="qwen2.5-100m",
        n_layers=10,
        d_model=640,
        n_heads=10,
        n_kv_heads=2,
        d_ff=2560,
        head_dim=64,
        vocab_size=50_000,
        tie_embeddings=True,
        dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/lm100m_ckpt")
    args = ap.parse_args()

    cfg = config_100m()
    n_params = cfg.param_count()
    print(f"config: {cfg.name} ~{n_params/1e6:.0f}M params, "
          f"{args.steps} steps x {args.batch}x{args.seq} tokens")

    opt = OptConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps,
                    weight_decay=0.01)
    stream = TokenStream(TokenStreamSpec(cfg.vocab_size, args.seq, args.batch))
    # no donate here: eagerly-initialized zero moments can share buffers
    # (XLA constant caching) and double-donation is an error; the AOT
    # dry-run path still donates for accurate memory analysis
    step_fn = jax.jit(make_train_step(cfg, opt, microbatches=args.microbatches))
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    ckpt = Checkpointer(args.ckpt_dir, keep=2)

    losses = []
    t0 = time.time()
    for s in range(args.steps):
        inputs, targets = stream.batch(s)
        tokens = jnp.concatenate([inputs, targets[:, -1:]], axis=1)
        state, loss = step_fn(state, {"tokens": tokens})
        losses.append(float(loss))
        if (s + 1) % 20 == 0:
            dt = (time.time() - t0) / (s + 1)
            tput = args.batch * args.seq / dt
            print(f"step {s+1}: loss={losses[-1]:.4f} "
                  f"({dt*1e3:.0f} ms/step, {tput:.0f} tok/s)")
        if (s + 1) % 100 == 0:
            ckpt.save(s + 1, state, blocking=False)
    ckpt.wait()
    first = sum(losses[:10]) / 10
    last = sum(losses[-10:]) / 10
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'LEARNED' if last < first * 0.8 else 'check hyperparams'})")


if __name__ == "__main__":
    main()
