"""Batched serving example — prefill + decode across heterogeneous requests.

Serves a reduced Mamba2 (attention-free: O(1) state per sequence) with a
continuous-batching-style loop: requests arrive with different prompt
lengths, are left-aligned into a batch, decoded greedily; finished rows are
replaced by the next queued request.

    PYTHONPATH=src python examples/serve_batched.py --requests 8 --batch 4
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.models.model import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b-smoke")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    rng = jax.random.PRNGKey(1)
    queue = []
    for r in range(args.requests):
        rng, k = jax.random.split(rng)
        plen = int(jax.random.randint(k, (), 4, 12))
        queue.append(jax.random.randint(k, (plen,), 0, cfg.vocab_size))

    max_len = 12 + args.gen + 1
    state = model.init_decode_state(args.batch, max_len)
    slots = [None] * args.batch          # request id per row
    progress = [0] * args.batch          # tokens consumed/generated per row
    outputs = {}
    done = 0
    next_req = 0
    current = jnp.zeros((args.batch,), jnp.int32)

    t0 = time.time()
    steps = 0
    while done < args.requests:
        # admit new requests into free rows
        for b in range(args.batch):
            if slots[b] is None and next_req < args.requests:
                slots[b] = next_req
                progress[b] = 0
                outputs[next_req] = []
                next_req += 1
        # build the next token per row (prompt feed or generated feed)
        toks = []
        for b in range(args.batch):
            r = slots[b]
            if r is None:
                toks.append(0)
            elif progress[b] < len(queue[r]):
                toks.append(int(queue[r][progress[b]]))
            else:
                toks.append(int(outputs[r][-1]))
        logits, state = decode(params, state, jnp.asarray(toks, jnp.int32))
        steps += 1
        nxt = jnp.argmax(logits, axis=-1)
        for b in range(args.batch):
            r = slots[b]
            if r is None:
                continue
            progress[b] += 1
            if progress[b] >= len(queue[r]):
                outputs[r].append(int(nxt[b]))
                if len(outputs[r]) >= args.gen:
                    done += 1
                    slots[b] = None
    dt = time.time() - t0
    print(f"served {args.requests} requests in {dt:.2f}s "
          f"({steps} decode steps, {args.requests*args.gen/dt:.1f} gen tok/s)")
    for r in range(min(3, args.requests)):
        print(f"req {r}: prompt[:4]={queue[r][:4].tolist()} "
              f"-> gen[:8]={outputs[r][:8]}")


if __name__ == "__main__":
    main()
