"""Batched serving example — continuous batching over heterogeneous requests.

Thin driver over ``repro.serving.ServingEngine``: requests arrive with
different prompt lengths, are admitted into fixed batch slots, decoded
greedily on-device, and finished rows are refilled from the queue — with
one host sync per batch of decode steps instead of the per-row ``int()``
syncs of the old host-side loop.

    PYTHONPATH=src python examples/serve_batched.py --requests 8 --batch 4
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.configs.registry import get_arch
from repro.models.model import build_model
from repro.serving import ServingEngine, configs_from_flags


def make_requests(rng, n, vocab_size, gen):
    """Synthetic requests; length and content drawn from *independent* keys
    (a shared key would correlate request length with token content)."""
    reqs = []
    for _ in range(n):
        rng, k_len, k_toks = jax.random.split(rng, 3)
        plen = int(jax.random.randint(k_len, (), 4, 12))
        toks = jax.random.randint(k_toks, (plen,), 0, vocab_size)
        reqs.append((toks.tolist(), gen))
    return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b-smoke")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--steps-per-sync", type=int, default=8)
    ap.add_argument("--layout", choices=["contiguous", "paged"],
                    default="contiguous",
                    help="KV-cache layout (paged: resident KV tracks live "
                         "tokens, not batch*max_len)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--n-pages", type=int, default=None)
    ap.add_argument("--kv-dtype", choices=["f32", "bf16", "int8"],
                    default="f32",
                    help="KV-pool storage precision (sub-f32 needs --layout "
                         "paged; bf16 = 1/2, int8 = 1/4 the resident bytes)")
    ap.add_argument("--prefill-chunk", type=int, default=1,
                    help="prompt tokens ingested per engine step (chunked "
                         "prefill; 1 = token-by-token)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="page-level prompt prefix sharing (needs --layout "
                         "paged): attention families alias pages with "
                         "copy-on-write; recurrent families (ssm/hybrid) "
                         "restore page-boundary state snapshots")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft K tokens per row per "
                         "step, verified through the chunked prefill path "
                         "(0 = off; needs --prefill-chunk >= 2)")
    ap.add_argument("--spec-drafter", default="prompt_lookup",
                    choices=["prompt_lookup", "hybrid_ssm"])
    ap.add_argument("--spec-ngram", type=int, default=2)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    reqs = make_requests(jax.random.PRNGKey(1), args.requests,
                         cfg.vocab_size, args.gen)

    max_len = 12 + args.gen + 1
    cache, config = configs_from_flags(args)
    eng = ServingEngine(model, params, batch=args.batch, max_len=max_len,
                        cache=cache, config=config)
    rids = [eng.submit(toks, gen) for toks, gen in reqs]

    t0 = time.time()
    outs = eng.run()
    dt = time.time() - t0
    print(f"served {args.requests} requests in {dt:.2f}s "
          f"({eng.steps} decode + {eng.prefill_steps} prefill steps, "
          f"{eng.generated/dt:.1f} gen tok/s)")
    if eng.ttft:
        print(f"mean TTFT {1e3 * sum(eng.ttft.values()) / len(eng.ttft):.1f} "
              f"ms (prefill chunk {args.prefill_chunk})")
    s = eng.stats()
    if "kv_pages" in s:   # attention-free archs have no pages to report
        print(f"paged KV: peak {int(s['kv_pages_peak'])}/{int(s['kv_pages'])} "
              f"pages resident")
    if "snap_slots" in s:   # recurrent families under prefix sharing
        print(f"state snapshots: peak {int(s['snap_slots_peak'])}/"
              f"{int(s['snap_slots'])} page-boundary slots resident")
    if "shared_prompt_tokens" in s:
        print(f"prefix sharing: {int(s['shared_prompt_tokens'])} prompt "
              f"tokens served from shared pages/snapshots "
              f"({int(s['cow_pages'])} CoW copies)")
    if "spec_accept_rate" in s:
        print(f"speculation: {int(s['spec_accepted'])}/"
              f"{int(s['spec_proposed'])} drafts accepted "
              f"({s['spec_accept_rate']:.0%})")
    for i, rid in enumerate(rids[:3]):
        prompt = reqs[i][0]
        print(f"req {rid}: prompt[:4]={prompt[:4]} "
              f"-> gen[:8]={outs[rid][:8].tolist()}")


if __name__ == "__main__":
    main()
